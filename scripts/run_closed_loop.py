"""Closed-loop scenario driver: the full paper loop on one host.

    DAQ triggers -> segmentation -> WAN (loss/dup/reorder) -> LB route
      -> per-member batched reassembly -> telemetry -> CP reweight
      -> hit-less epoch switch -> back around.

Every stage is the batched production path (DESIGN.md §Ingest): one
``segment_bundles`` pass, one ``deliver_batch`` permutation, one
``DataPlane.route`` device call and one sort-based reassembly plan per
member per step. The control plane consumes *real* incomplete-buffer
backlog (``TelemetryHub.report_ingest``) — not synthetic fill numbers.

Scenarios (``--scenario``):
  baseline   clean WAN, static membership
  loss       packet loss -> incomplete buffers -> timeout accounting
  reorder    deep reorder window, duplicates constrained to follow originals
  straggler  one member reports 4x step time; CP must shed its weight
  elastic    members join at 1/3 and leave at 2/3 of the run

Exits non-zero if an invariant breaks: an event split across members, a
corrupt (non-byte-identical) bundle, or unaccounted segments.

    PYTHONPATH=src python scripts/run_closed_loop.py --steps 50
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from collections import defaultdict

import numpy as np

from repro.core import EpochManager, MemberSpec
from repro.core.control_plane import LoadBalancerControlPlane
from repro.core.dataplane import DataPlaneCache
from repro.data.daq import DAQConfig, DAQFleet
from repro.data.segmentation import group_rows, segment_bundles
from repro.data.transport import TransportConfig, WANTransport
from repro.telemetry.metrics import TelemetryHub

SCENARIOS = ("baseline", "loss", "reorder", "straggler", "elastic")


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--scenario", choices=SCENARIOS, default="baseline")
    ap.add_argument("--triggers-per-step", type=int, default=2)
    ap.add_argument("--n-members", type=int, default=6)
    ap.add_argument("--n-daqs", type=int, default=3)
    ap.add_argument("--mean-bundle-bytes", type=int, default=12_000)
    ap.add_argument("--mtu-payload", type=int, default=2048)
    ap.add_argument("--loss", type=float, default=None,
                    help="override the scenario's loss probability")
    ap.add_argument("--dup", type=float, default=None)
    ap.add_argument("--reorder-window", type=int, default=None)
    ap.add_argument("--reweight-every", type=int, default=5)
    ap.add_argument("--timeout-windows", type=int, default=4)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=["loop", "fused", "host"],
                    default="loop",
                    help="loop = this script's inline per-step loop; "
                         "fused/host = run the equivalent virtual-time "
                         "simulation through repro.simnet's fused "
                         "(device-resident superblock) or host engine")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    help="emit a metrics time-series row every N steps "
                         "(enables the live registry; with --engine fused "
                         "this forces the host engine). 0 = off")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="JSONL path for --metrics-interval rows")
    ap.add_argument("--json", default=None, help="write the summary here")
    return ap.parse_args(argv)


def scenario_transport(args) -> TransportConfig:
    loss, dup, window = 0.0, 0.0, 16
    if args.scenario == "loss":
        loss, dup = 0.05, 0.02
    elif args.scenario == "reorder":
        dup, window = 0.05, 256
    cfg = TransportConfig(
        reorder_window=window if args.reorder_window is None else args.reorder_window,
        loss_prob=loss if args.loss is None else args.loss,
        duplicate_prob=dup if args.dup is None else args.dup,
        seed=args.seed,
    )
    return cfg


def run_simulator(args) -> int:
    """--engine fused/host: the same closed loop on the virtual-time
    simulator (repro.simnet), where the engine choice is meaningful. The
    WAN loss/dup knobs map onto the simnet WAN link; ``reorder`` arrives
    via jitter (the simnet WAN has no explicit reorder window)."""
    from repro.simnet import SimConfig, Simulator
    from repro.simnet.links import LinkConfig

    if args.scenario == "elastic":
        print("--engine fused/host does not support the elastic scenario "
              "(membership hooks run per-step on host); use --engine loop",
              file=sys.stderr)
        return 2
    tcfg = scenario_transport(args)
    scale = None
    if args.scenario == "straggler":
        scale = np.ones((args.n_members,))
        scale[0] = 4.0
    cfg = SimConfig(
        steps=args.steps, n_members=args.n_members, n_daqs=args.n_daqs,
        triggers_per_step=args.triggers_per_step,
        mean_bundle_bytes=args.mean_bundle_bytes,
        mtu_payload=args.mtu_payload, seed=args.seed, backend=args.backend,
        wan=LinkConfig(prop_delay_s=1e-3, jitter_s=2e-4,
                       loss_prob=tcfg.loss_prob,
                       duplicate_prob=tcfg.duplicate_prob, seed=args.seed),
        service_scale=scale, reweight_every=args.reweight_every,
        timeout_windows=max(args.timeout_windows, 1), engine=args.engine,
        metrics_every=(max(args.metrics_interval, 1)
                       if args.metrics_interval or args.metrics_jsonl else 0),
        metrics_path=args.metrics_jsonl)
    report = Simulator(cfg).run()
    summary = report.to_dict()
    print(json.dumps(summary, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
    violations = list(report.violations)
    if args.scenario == "straggler" and args.steps >= 20:
        weights = {int(k): v for k, v in report.final_weights.items()}
        w = weights.get(0, 1.0)
        if w >= 1.0:
            violations.append(f"straggler weight not shed (w={w:.2f})")
    if violations:
        print("FAILED: " + "; ".join(violations), file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.engine != "loop":
        return run_simulator(args)
    t_start = time.perf_counter()

    em = EpochManager(max_members=max(64, 4 * args.n_members))
    cp = LoadBalancerControlPlane(em)
    # Event numbers advance ~4 per trigger; place epoch boundaries a couple
    # of steps out so reconfigurations take effect within the run.
    cp.policy.epoch_horizon = max(16, 8 * args.triggers_per_step)
    members = {i: MemberSpec(node_id=i, lane_bits=1)
               for i in range(args.n_members)}
    cp.start(members)
    hub = TelemetryHub(queue_capacity=16)
    fleet = DAQFleet(DAQConfig(
        n_daqs=args.n_daqs, seq_len=32,
        mean_bundle_bytes=args.mean_bundle_bytes, seed=args.seed))
    wan = WANTransport(scenario_transport(args))

    dp_cache = DataPlaneCache(em, backend=args.backend)

    reassemblers: dict[int, object] = {}
    reported_timeouts: dict[int, int] = defaultdict(int)

    def reassembler(member: int):
        if member not in reassemblers:
            reassemblers[member] = dp_cache.get().make_reassembler(
                mtu_payload=args.mtu_payload,
                timeout_windows=args.timeout_windows)
        return reassemblers[member]

    metrics = ts_writer = None
    if args.metrics_interval or args.metrics_jsonl:
        from repro.telemetry.export import TimeSeriesWriter
        from repro.telemetry.registry import MetricsRegistry
        metrics = MetricsRegistry()
        mx_windows = metrics.counter("loop_windows_total",
                                     "Ingest windows completed.")
        mx_step = metrics.histogram("loop_step_seconds",
                                    "Wall time per ingest window.")
        metrics.gauge("loop_bundles_completed", "Bundles fully reassembled."
                      ).set_function(lambda: completed)
        metrics.gauge("loop_epoch_switches",
                      "Hit-less epoch switches scheduled."
                      ).set_function(lambda: epoch_switches)
        if args.metrics_jsonl:
            ts_writer = TimeSeriesWriter(args.metrics_jsonl, metrics)

    straggler = 0 if args.scenario == "straggler" else None
    event_members: dict[int, set[int]] = defaultdict(set)
    sent_bundles = 0
    completed = 0
    corrupt = 0
    discarded = 0
    epoch_switches = 0
    joined: list[int] = []
    removed: list[int] = []

    for step in range(args.steps):
        t_step0 = time.perf_counter()
        # -- elastic membership ------------------------------------------------
        if args.scenario == "elastic":
            if step == args.steps // 3 and not joined:
                new_ids = [max(cp.members) + 1 + k for k in range(2)]
                cp.add_members({i: MemberSpec(node_id=i, lane_bits=1)
                                for i in new_ids})
                cp.schedule_epoch(fleet.event_number)
                joined = new_ids
            if step == (2 * args.steps) // 3 and not removed:
                removed = [min(members)]
                cp.mark_failed(removed)
                cp.schedule_epoch(fleet.event_number)

        # -- one ingest window -------------------------------------------------
        bundles = fleet.bundle_window(args.triggers_per_step)
        sent_bundles += len(bundles)
        expected = {(b.event_number, b.daq_id): b.payload for b in bundles}
        batch = segment_bundles(bundles, args.mtu_payload)
        arrived = wan.deliver_batch(batch)
        if len(arrived) == 0:
            if metrics is not None:
                mx_step.observe(time.perf_counter() - t_step0)
                mx_windows.inc()
            continue
        member, _node, _lane, valid = dp_cache.get().route_window(arrived)
        discarded += int((~valid).sum())
        for ev, m in zip(arrived.event_number[valid].tolist(),
                         member[valid].tolist()):
            event_members[ev].add(m)

        # -- per-member batched reassembly (one grouping pass) ----------------
        rows_ok = np.flatnonzero(valid)
        mem_ids, groups = group_rows(member[rows_ok])
        for m, grp in zip(mem_ids.tolist(), groups):
            sel = rows_ok[grp]
            ra = reassembler(m)
            done = ra.push_batch(arrived.take(sel))
            completed += len(done)
            for key, payload in ra.drain_completed():
                want = expected.get(key)
                if want is not None and not np.array_equal(payload, want):
                    corrupt += 1
            # Synthetic processing-cost model: unit cost per segment, with
            # the straggler running 4x slow — what the CP must detect.
            step_time = 1e-3 * max(len(sel), 1) \
                * (4.0 if m == straggler else 1.0)
            backlog = ra.n_incomplete  # one unique() pass, reported twice
            hub.report_step(m, step_time=step_time,
                            backlog=backlog, processed=len(done))
            new_timeouts = ra.stats.n_timed_out_groups - reported_timeouts[m]
            reported_timeouts[m] = ra.stats.n_timed_out_groups
            hub.report_ingest(m, pending=backlog,
                              completed=len(done), timed_out=new_timeouts)

        # -- control loop ------------------------------------------------------
        if args.reweight_every and (step + 1) % args.reweight_every == 0:
            eid = cp.feedback(hub.snapshot(), fleet.event_number)
            if eid is not None:
                epoch_switches += 1
            cp.garbage_collect(fleet.event_number)

        if metrics is not None:
            mx_step.observe(time.perf_counter() - t_step0)
            mx_windows.inc()
            if (ts_writer is not None
                    and (step + 1) % max(args.metrics_interval, 1) == 0):
                ts_writer.write(step=step)

    if ts_writer is not None:
        ts_writer.close()

    # -- audit ----------------------------------------------------------------
    split_events = sum(1 for ms in event_members.values() if len(ms) > 1)
    pending = sum(ra.n_incomplete for ra in reassemblers.values())
    timed_out = sum(ra.stats.n_timed_out_groups for ra in reassemblers.values())
    dups = sum(ra.stats.n_duplicate for ra in reassemblers.values())
    summary = {
        "scenario": args.scenario,
        "steps": args.steps,
        "bundles_sent": sent_bundles,
        "bundles_completed": completed,
        "bundles_pending": pending,
        "bundles_timed_out": timed_out,
        "segments_lost": wan.n_lost,
        "segments_duplicated": wan.n_dup,
        "duplicates_absorbed": dups,
        "packets_discarded": discarded,
        "split_events": split_events,
        "corrupt_bundles": corrupt,
        "epoch_switches": epoch_switches,
        "final_weights": {str(k): round(v, 4) for k, v in cp.weights.items()},
        "members_joined": joined,
        "members_removed": removed,
        "wall_s": round(time.perf_counter() - t_start, 3),
    }
    violations = []
    if split_events:
        violations.append(f"{split_events} events split across members")
    if corrupt:
        violations.append(f"{corrupt} corrupt bundles")
    if completed + pending + timed_out < sent_bundles and wan.n_lost == 0:
        violations.append("bundles unaccounted with zero loss")
    if straggler is not None and args.steps >= 20:
        w = cp.weights.get(straggler, 1.0)
        if w >= 1.0:
            violations.append(f"straggler weight not shed (w={w:.2f})")
    if joined:
        served = {m for ms in event_members.values() for m in ms}
        if not set(joined) & served:
            violations.append("joined members received no traffic")
    summary["violations"] = violations

    print(json.dumps(summary, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
    if violations:
        print("FAILED: " + "; ".join(violations), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
