"""Virtual-time scenario driver: the paper loop with latency measured.

    DAQ emission (timestamped) -> uplink/WAN serialization + delay + loss
      -> LB route (DataPlane, fixed pipeline latency) -> per-member downlink
      -> bounded CN receive queue (service-rate model) -> reassembly
      -> measured telemetry on the virtual clock -> CP reweight -> around.

Prints a ``SimReport`` (end-to-end latency percentiles, queue-fill trace
summary, loss/timeout accounting, weight trajectory) and audits the paper's
invariants: no event split across members (per LB instance), no corrupt
bundle, everything accounted, and non-degenerate latency percentiles
(p99 > p50 > 0).

``--compare-frozen`` reruns the scenario with feedback disabled and reports
the p99 delta; for scenarios that promise a control-plane gain
(straggler, elephant) a frozen run beating the closed loop is a failure.

    PYTHONPATH=src python scripts/run_simnet.py --scenario elephant
    PYTHONPATH=src python scripts/run_simnet.py --scenario straggler --compare-frozen
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.simnet import SCENARIOS, SimReport, Simulator, get_scenario


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", choices=sorted(SCENARIOS), default="baseline")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--n-members", type=int, default=None)
    ap.add_argument("--triggers-per-step", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--queue-engine", choices=["np", "jnp"], default="np")
    ap.add_argument("--engine", choices=["fused", "host"], default="fused",
                    help="fused = device-resident closed loop (one jitted "
                         "superblock per K windows; falls back to host for "
                         "configs outside its scope); host = per-window "
                         "Python loop (the parity oracle)")
    ap.add_argument("--frozen-weights", action="store_true",
                    help="disable control-plane feedback (control run)")
    ap.add_argument("--compare-frozen", action="store_true",
                    help="also run the frozen-weights control and compare p99")
    ap.add_argument("--controld", action="store_true",
                    help="run the control plane as a session daemon "
                         "(repro.controld): CNs register/heartbeat/lease")
    ap.add_argument("--ha", action="store_true",
                    help="controld HA mode: an HACluster of warm standbys "
                         "behind a failover transport (implies --controld)")
    ap.add_argument("--kill-leader-every", type=int, default=0,
                    metavar="N",
                    help="SIGKILL the controld leader every N windows "
                         "(the nightly soak's failover leg; implies --ha); "
                         "each takeover is digest-audited and duration-"
                         "gated at 1.25x the lease term")
    ap.add_argument("--policy", choices=["proportional", "pid"], default=None,
                    help="controld reweighting policy (implies --controld)")
    ap.add_argument("--compare-policy", action="store_true",
                    help="run the scenario under the PID and proportional "
                         "controld policies; fail if PID p99 is worse")
    ap.add_argument("--tournament", default=None, metavar="P1,P2,...",
                    help="run one controld leg per named policy (aliases: "
                         "prop; the pseudo-policy 'frozen' disables "
                         "feedback) and rank the legs by p99; render the "
                         "table with make_tables.py --tournament")
    ap.add_argument("--traces", action="store_true",
                    help="include full queue/weight traces in the JSON")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    help="emit a metrics time-series row every N windows "
                         "(enables the live registry; works on both "
                         "engines — the fused superblock's returned arrays "
                         "feed the same emission path). 0 = off")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="JSONL path for --metrics-interval rows "
                         "(default: no file, registry only)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record per-bundle stage spans and write Chrome "
                         "trace-event / Perfetto JSON here (open in "
                         "ui.perfetto.dev)")
    ap.add_argument("--trace-summary-json", default=None, metavar="PATH",
                    help="write the lossless trace summary JSON here "
                         "(consumed by scripts/analyze_trace.py --summary "
                         "and trend.py --trace-summary)")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="head-sampling rate for span retention "
                         "(the tail top-k reservoir is always kept)")
    ap.add_argument("--trace-tail-k", type=int, default=64,
                    help="slowest-bundle reservoir size")
    ap.add_argument("--json", default=None, help="write the summary here")
    return ap.parse_args(argv)


def build_and_run(args, frozen: bool, policy: str | None = None,
                  with_metrics: bool = True) -> SimReport:
    scenario = get_scenario(args.scenario)
    extra = dict(steps=args.steps, seed=args.seed, backend=args.backend,
                 queue_engine=args.queue_engine, frozen_weights=frozen,
                 engine=args.engine)
    if args.n_members is not None:
        extra["n_members"] = args.n_members
    if args.triggers_per_step is not None:
        extra["triggers_per_step"] = args.triggers_per_step
    policy = policy if policy is not None else args.policy
    if (args.controld or args.compare_policy or args.tournament
            or policy is not None):
        extra["controld"] = True
    if args.ha or args.kill_leader_every:
        extra["controld"] = True
        extra["ha"] = True
        if args.kill_leader_every:
            extra["ha_kill_every"] = args.kill_leader_every
    if policy is not None:
        extra["controld_policy"] = policy
    if with_metrics and (args.metrics_interval or args.metrics_jsonl):
        # only the primary leg emits: comparison legs (frozen / policy)
        # would interleave their rows into the same JSONL
        extra["metrics_every"] = max(args.metrics_interval, 1)
        extra["metrics_path"] = args.metrics_jsonl
    trace_out = getattr(args, "trace_out", None)
    trace_summary = getattr(args, "trace_summary_json", None)
    if with_metrics and (trace_out or trace_summary):
        # same primary-leg rule as metrics: one trace per invocation
        extra["trace"] = True
        extra["trace_sample"] = args.trace_sample
        extra["trace_tail_k"] = args.trace_tail_k
    cfg = scenario.build_config(**extra)
    sim = Simulator(cfg, dataclasses.replace(scenario))
    report = sim.run()
    if sim.trace is not None and with_metrics:
        if trace_out:
            with open(trace_out, "wb") as f:
                f.write(sim.trace.to_perfetto_json())
        if trace_summary:
            from repro.telemetry.traceview import summary_json
            out = sim.trace.to_summary()
            out["breakdown"] = summary_json(sim.trace)
            with open(trace_summary, "w") as f:
                json.dump(out, f)
    return report


def main(argv=None) -> int:
    args = parse_args(argv)
    scenario = get_scenario(args.scenario)
    report = build_and_run(args, frozen=args.frozen_weights)
    summary = report.to_dict(with_traces=args.traces)

    violations = list(report.violations)
    if report.bundles_completed:
        if not (report.latency_p99_s > report.latency_p50_s > 0):
            violations.append(
                f"degenerate latency percentiles (p50={report.latency_p50_s}, "
                f"p99={report.latency_p99_s})")
    else:
        violations.append("no bundles completed")

    if args.compare_frozen and not args.frozen_weights:
        control = build_and_run(args, frozen=True, with_metrics=False)
        summary["control"] = {
            "latency_p50_s": round(control.latency_p50_s, 9),
            "latency_p99_s": round(control.latency_p99_s, 9),
            "bundles_timed_out": control.bundles_timed_out,
            "packets_dropped_queue": control.packets_dropped_queue,
        }
        gain = (control.latency_p99_s - report.latency_p99_s)
        summary["p99_gain_vs_frozen_s"] = round(gain, 9)
        if scenario.expect_cp_gain and gain <= 0:
            violations.append(
                f"control plane did not reduce p99 latency "
                f"(closed={report.latency_p99_s:.6f}s "
                f"frozen={control.latency_p99_s:.6f}s)")

    if args.compare_policy:
        # --compare-frozen-style gate for the policy layer: the PID fill
        # controller must not lose to the proportional policy on p99
        # the base report already IS one leg when its config matches (same
        # deterministic seed): never run the identical simulation twice
        if args.policy == "pid" and not args.frozen_weights:
            pid = report
        else:
            pid = build_and_run(args, frozen=False, policy="pid",
                                with_metrics=False)
        if args.policy in (None, "proportional") and not args.frozen_weights:
            prop = report
        else:
            prop = build_and_run(args, frozen=False, policy="proportional",
                                 with_metrics=False)
        summary["policy_compare"] = {
            "pid_p99_s": round(pid.latency_p99_s, 9),
            "proportional_p99_s": round(prop.latency_p99_s, 9),
            "pid_gain_s": round(prop.latency_p99_s - pid.latency_p99_s, 9),
        }
        violations.extend(f"pid policy run: {v}" for v in pid.violations)
        violations.extend(f"proportional policy run: {v}"
                          for v in prop.violations)
        if pid.latency_p99_s > prop.latency_p99_s:
            violations.append(
                f"PID policy lost to proportional on p99 "
                f"(pid={pid.latency_p99_s:.6f}s "
                f"prop={prop.latency_p99_s:.6f}s)")

    if args.tournament:
        aliases = {"prop": "proportional"}
        names = [aliases.get(n.strip(), n.strip())
                 for n in args.tournament.split(",") if n.strip()]
        names = list(dict.fromkeys(names))   # dedupe, keep rank-input order
        if len(names) < 2:
            violations.append(
                f"--tournament needs at least two policies, got {names}")
        from repro.controld import POLICIES
        legal = set(POLICIES) | {"frozen"}
        unknown = [n for n in names if n not in legal]
        if unknown:
            violations.append(
                f"unknown tournament policies {unknown}; have {sorted(legal)}")
            names = [n for n in names if n in legal]
        legs = []
        primary_policy = ("frozen" if args.frozen_weights
                          else (args.policy or "proportional"))
        for name in names:
            # the primary report already IS this leg when its config
            # matches (deterministic seed): never run the same sim twice
            if name == primary_policy:
                legs.append((name, report))
            elif name == "frozen":
                legs.append((name, build_and_run(args, frozen=True,
                                                 with_metrics=False)))
            else:
                legs.append((name, build_and_run(args, frozen=False,
                                                 policy=name,
                                                 with_metrics=False)))
        ranked = sorted(legs, key=lambda kv: kv[1].latency_p99_s)
        best = ranked[0][1].latency_p99_s if ranked else 0.0
        summary["tournament"] = {
            "scenario": args.scenario,
            "steps": args.steps,
            "seed": args.seed,
            "ranked": [
                {"rank": i + 1, "policy": name,
                 "latency_p50_s": round(leg.latency_p50_s, 9),
                 "latency_p99_s": round(leg.latency_p99_s, 9),
                 "p99_vs_best_s": round(leg.latency_p99_s - best, 9),
                 "bundles_timed_out": leg.bundles_timed_out,
                 "packets_dropped_queue": leg.packets_dropped_queue}
                for i, (name, leg) in enumerate(ranked)],
        }
        for name, leg in legs:
            if leg is not report:
                violations.extend(
                    f"{name} tournament leg: {v}" for v in leg.violations)

    summary["violations"] = violations
    print(json.dumps(summary, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
    if violations:
        print("FAILED: " + "; ".join(violations), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
