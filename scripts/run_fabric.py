"""Two-tier fabric driver: run the fabric scenarios and enforce their gates.

    DAQ fleet -> VLB spray (random intermediate LB, then the owner)
      -> elephant-aware calendar lanes -> per-member downlink -> CN queues

Each scenario IS a gate (ISSUE acceptance criteria):

* ``vlb_spray``     — runs the skewed-DAQ load under both the two-phase
                      spray and direct per-DAQ hashing; FAILS unless VLB's
                      max-LB load share <= direct's.
* ``elephant_mice`` — runs with reserved-lane isolation ON and OFF; FAILS
                      unless mice p99 is strictly better with isolation.
* ``lb_node_failure`` — kills a tier member mid-run; FAILS on any lost
                      bundle or invariant violation (re-spray is hit-less).

    PYTHONPATH=src python scripts/run_fabric.py --scenario all
    PYTHONPATH=src python scripts/run_fabric.py --scenario elephant_mice --controld
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.fabric import FABRIC_SCENARIOS, FabricSim, get_fabric_scenario


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", default="all",
                    choices=sorted(FABRIC_SCENARIOS) + ["all"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--k-lbs", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--controld", action="store_true",
                    help="run the fabric as a ReserveFabric tenant of the "
                         "control daemon (2K leased sessions, failure drain "
                         "via DeregisterBatch)")
    ap.add_argument("--metrics-registry", action="store_true",
                    help="attach a live MetricsRegistry (fabric_lb_load / "
                         "fabric_elephants gauges) and dump it at the end")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="trace the primary leg of each scenario and write "
                         "Chrome trace-event / Perfetto JSON (multiple "
                         "scenarios get a .<scenario> suffix before the "
                         "extension)")
    ap.add_argument("--json", default=None, help="write the summary here")
    return ap.parse_args(argv)


def _build(sc, args, **extra):
    for k, v in (("steps", args.steps), ("k_lbs", args.k_lbs),
                 ("seed", args.seed)):
        if v is not None:
            extra[k] = v
    if args.controld:
        extra["controld"] = True
    return sc.build_config(**extra)


def _export_trace(sim, name: str, path: str, many: bool) -> None:
    """Perfetto export of the primary leg's span buffer."""
    if sim.trace is None:
        return
    if many:
        stem, dot, ext = path.rpartition(".")
        path = f"{stem}.{name}{dot}{ext}" if dot else f"{path}.{name}"
    with open(path, "wb") as f:
        f.write(sim.trace.to_perfetto_json())
    print(f"# perfetto export: {path}", file=sys.stderr)


def run_scenario(name: str, args, metrics=None, many: bool = False) -> dict:
    sc = get_fabric_scenario(name)
    out: dict = {"scenario": name, "gates": {}, "violations": []}
    # only the primary leg records spans: comparison legs (direct hashing,
    # isolation-off) would double every bundle key in one buffer
    tr = {"trace": True} if args.trace_out else {}

    if name == "vlb_spray":
        prim = FabricSim(_build(sc, args, mode="vlb", **tr), scenario=sc,
                         metrics=metrics)
        vlb = prim.run()
        direct = FabricSim(_build(sc, args, mode="direct"),
                           scenario=sc).run()
        out["vlb"] = vlb.to_dict()
        out["direct"] = {"max_lb_load_frac": direct.max_lb_load_frac,
                         "lb_load_bytes": direct.lb_load_bytes,
                         "latency_p99_s": direct.latency_p99_s}
        out["violations"] = list(vlb.violations) + [
            f"direct leg: {v}" for v in direct.violations]
        ok = vlb.max_lb_load_frac <= direct.max_lb_load_frac
        out["gates"]["vlb_max_load_le_direct"] = ok
        if not ok:
            out["violations"].append(
                f"VLB spray lost to direct hashing on max-LB load "
                f"({vlb.max_lb_load_frac:.3f} > "
                f"{direct.max_lb_load_frac:.3f})")

    elif name == "elephant_mice":
        prim = FabricSim(_build(sc, args, isolate=True, **tr), scenario=sc,
                         metrics=metrics)
        on = prim.run()
        off = FabricSim(_build(sc, args, isolate=False), scenario=sc).run()
        out["isolated"] = on.to_dict()
        out["shared"] = {"mice_p99_s": off.mice_p99_s,
                         "elephant_p99_s": off.elephant_p99_s,
                         "elephants_detected": off.elephants_detected}
        out["violations"] = list(on.violations) + [
            f"shared leg: {v}" for v in off.violations]
        ok = on.mice_p99_s < off.mice_p99_s
        out["gates"]["isolation_cuts_mice_p99"] = ok
        if not ok:
            out["violations"].append(
                f"reserved-lane isolation did not cut mice p99 "
                f"(on={on.mice_p99_s:.6f}s off={off.mice_p99_s:.6f}s)")
        if on.elephants_detected == 0:
            out["violations"].append("no elephant was ever detected")

    else:  # lb_node_failure
        prim = FabricSim(_build(sc, args, **tr), scenario=sc,
                         metrics=metrics)
        r = prim.run()
        out["report"] = r.to_dict()
        out["violations"] = list(r.violations)
        ok = bool(r.lbs_killed) and r.bundles_lost == 0
        out["gates"]["hitless_respray"] = ok
        if not r.lbs_killed:
            out["violations"].append("no LB was killed (scenario hook lost)")
        if r.bundles_lost:
            out["violations"].append(
                f"{r.bundles_lost} bundles lost across the LB failure")
    if args.trace_out:
        _export_trace(prim, name, args.trace_out, many)
    return out


def main(argv=None) -> int:
    args = parse_args(argv)
    metrics = None
    if args.metrics_registry:
        from repro.telemetry.registry import MetricsRegistry
        metrics = MetricsRegistry()
    names = (sorted(FABRIC_SCENARIOS) if args.scenario == "all"
             else [args.scenario])
    summary = {"scenarios": [run_scenario(n, args, metrics,
                                          many=len(names) > 1)
                             for n in names]}
    failures = [v for s in summary["scenarios"] for v in s["violations"]]
    if metrics is not None:
        summary["metrics"] = {
            name: {",".join(lv) or "_": child.value()
                   for lv, child in fam.samples()}
            for name, fam in metrics._families.items()
            if name.startswith("fabric_")}
    print(json.dumps(summary, indent=2, default=str))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2, default=str)
    if failures:
        print("FAILED: " + "; ".join(failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
