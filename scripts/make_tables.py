"""Render EXPERIMENTS.md tables from dry-run artifacts.

    PYTHONPATH=src python scripts/make_tables.py artifacts/dryrun > /tmp/tables.md

``--tournament`` renders ranked policy-tournament tables instead, from the
JSON summaries ``run_simnet.py --tournament ... --json`` writes:

    PYTHONPATH=src python scripts/make_tables.py --tournament t1.json t2.json

``--bench`` renders a benchmark-artifacts directory (the ``BENCH_*.json``
files ``python -m benchmarks.run`` emits) as one markdown table per bench,
flagged against the committed floors:

    PYTHONPATH=src python scripts/make_tables.py --bench bench-out
"""
import json
import sys

sys.path.insert(0, "src")
from repro.analysis import roofline as RL  # noqa: E402


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def main(art_dir):
    arts = RL.load_artifacts(art_dir)
    skips = [a for a in arts if "skipped" in a]
    cells = [a for a in arts if "skipped" not in a]
    base = [a for a in cells if a.get("variant", "baseline") == "baseline"]
    vari = [a for a in cells if a.get("variant", "baseline") != "baseline"]

    # ---- Dry-run table -------------------------------------------------------
    print("### Dry-run compilation matrix\n")
    print("| arch | shape | mesh | chips | compile s | HLO args/dev "
          "| temps/dev | collective ops (static) |")
    print("|---|---|---|---|---|---|---|---|")
    for a in sorted(base, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        mem = a.get("memory", {})
        args = fmt_bytes(mem.get("argument_size_in_bytes", 0))
        temps = fmt_bytes(mem.get("temp_size_in_bytes", 0))
        ops = sum(a["collectives"]["ops"].values())
        print(f"| {a['arch']} | {a['shape']} | {a['mesh']} | {a['chips']} | "
              f"{a.get('lower_compile_s', 0):.1f} | {args} | {temps} | {ops} |")
    print("\n**Documented skips** (DESIGN.md §4):\n")
    seen = set()
    for a in sorted(skips, key=lambda x: (x["arch"], x["shape"])):
        key = (a["arch"], a["shape"])
        if key in seen:
            continue
        seen.add(key)
        print(f"- {a['arch']} x {a['shape']}: {a['skipped']}")

    # ---- Roofline tables ------------------------------------------------------
    for mesh_kind in ("single", "multi"):
        rows = [RL.analyze(a) for a in base if a["mesh"] == mesh_kind]
        rows.sort(key=lambda r: (r.arch, r.shape))
        print(f"\n### Roofline — baseline, {mesh_kind} pod "
              f"({'256' if mesh_kind == 'single' else '512'} chips)\n")
        print(RL.markdown_table(rows))

    # ---- Variants -------------------------------------------------------------
    if vari:
        print("\n### Perf variants (beyond-paper)\n")
        print("| arch | shape | mesh | variant | collective s | step s | util | vs baseline |")
        print("|---|---|---|---|---|---|---|---|")
        base_by = {(a["arch"], a["shape"], a["mesh"]): RL.analyze(a) for a in base}
        for a in sorted(vari, key=lambda x: (x["arch"], x["shape"], x["variant"])):
            r = RL.analyze(a)
            b = base_by.get((a["arch"], a["shape"], a["mesh"]))
            speed = f"{b.step_time_s / r.step_time_s:.2f}x" if b else "-"
            print(f"| {r.arch} | {r.shape} | {r.mesh} | {a['variant']} | "
                  f"{r.collective_s:.4g} | {r.step_time_s:.4g} | "
                  f"{r.hw_utilization:.3f} | {speed} |")


def tournament_tables(paths):
    """Ranked-p99 tables from run_simnet.py --tournament JSON summaries."""
    if not paths:
        print("usage: make_tables.py --tournament summary.json [...]",
              file=sys.stderr)
        return 2
    for path in paths:
        with open(path) as f:
            summary = json.load(f)
        t = summary.get("tournament")
        if not t:
            print(f"{path}: no 'tournament' block "
                  f"(run run_simnet.py --tournament ... --json)",
                  file=sys.stderr)
            return 1
        print(f"### Policy tournament — scenario `{t['scenario']}` "
              f"({t['steps']} steps, seed {t['seed']})\n")
        print("| rank | policy | p50 (ms) | p99 (ms) | vs best (ms) "
              "| timeouts | queue drops |")
        print("|---|---|---|---|---|---|---|")
        for leg in t["ranked"]:
            print(f"| {leg['rank']} | {leg['policy']} "
                  f"| {leg['latency_p50_s'] * 1e3:.3f} "
                  f"| {leg['latency_p99_s'] * 1e3:.3f} "
                  f"| +{leg['p99_vs_best_s'] * 1e3:.3f} "
                  f"| {leg['bundles_timed_out']} "
                  f"| {leg['packets_dropped_queue']} |")
        print()
    return 0


def bench_tables(argv):
    """Markdown tables from BENCH_*.json artifacts, floors alongside."""
    if not argv:
        print("usage: make_tables.py --bench bench-dir [baselines.json]",
              file=sys.stderr)
        return 2
    bench_dir = argv[0]
    baseline_path = (argv[1] if len(argv) > 1
                     else "benchmarks/baselines/baselines.json")
    sys.path.insert(0, ".")
    from benchmarks.trend import fmt, load_dir
    cur = load_dir(bench_dir)
    if not cur:
        print(f"no BENCH_*.json under {bench_dir}", file=sys.stderr)
        return 1
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except OSError:
        baseline = {}
    for bench, rec in sorted(cur.items()):
        print(f"### Bench `{bench}`\n")
        print("| metric | value | committed floor | direction |")
        print("|---|---|---|---|")
        for metric, value in sorted(rec.get("metrics", {}).items()):
            if not isinstance(value, (int, float)) or isinstance(value, bool):
                continue
            spec = baseline.get(bench, {}).get(metric)
            floor = fmt(float(spec["value"])) if spec else "-"
            direction = spec.get("better", "higher") if spec else "-"
            print(f"| {metric} | {fmt(value)} | {floor} | {direction} |")
        print()
    return 0


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--tournament":
        sys.exit(tournament_tables(sys.argv[2:]))
    if len(sys.argv) > 1 and sys.argv[1] == "--bench":
        sys.exit(bench_tables(sys.argv[2:]))
    main(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
