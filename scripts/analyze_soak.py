"""Soak-trend analyzer: slope-gate long-horizon state growth.

Reads the metrics time-series JSONL a soak run emits
(``run_simnet.py --metrics-interval N --metrics-jsonl PATH``) and fits a
trend to each state-growth series:

* ``process_rss_bytes``        — resident memory must not creep: the
  second-half mean may exceed the first-half mean by at most
  ``--rss-growth-frac`` (default 35%, generous for allocator warmup).
* ``simnet_bundles_pending``   — reassembly/pending state must stay
  bounded: the least-squares slope must be <= ``--pending-slope``
  bundles/window (default 0.01 — flat).
* ``simnet_epoch_switches``    — calendar churn must stay rate-bounded:
  the control loop schedules at most one switch per window per instance,
  so the end-to-end switch rate must be <= ``--churn-rate``/window.
* ``controld_ha_failovers`` / ``controld_ha_last_failover_s`` — present
  only in the HA failover leg (``run_simnet --kill-leader-every N``):
  every observed takeover must complete within ``--max-failover-s`` of
  sim time, and the RSS / pending-bundle trends *after the last
  failover* must satisfy the same bounds as the whole run (a takeover
  must not change the growth regime).

Any violated bound FAILS the run (exit 1) — this is the nightly soak's
hard gate, not a dashboard. ``--json`` writes the full trend report.

    PYTHONPATH=src python scripts/analyze_soak.py soak-out/baseline_metrics.jsonl
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("jsonl", nargs="+", help="metrics JSONL file(s)")
    ap.add_argument("--rss-growth-frac", type=float, default=0.35,
                    help="max fractional RSS growth, 2nd-half mean vs 1st")
    ap.add_argument("--pending-slope", type=float, default=0.01,
                    help="max pending-bundles slope (bundles per window)")
    ap.add_argument("--churn-rate", type=float, default=None,
                    help="max epoch switches per window (default: "
                         "n_instances read from the rows, else 1.0)")
    ap.add_argument("--max-failover-s", type=float, default=0.5,
                    help="max leader-failover duration in sim seconds "
                         "(gated only when the HA failover series are "
                         "present in the rows)")
    ap.add_argument("--min-rows", type=int, default=8,
                    help="fewer sampled rows than this is itself a failure")
    ap.add_argument("--json", default=None, help="write the trend report")
    return ap.parse_args(argv)


def _series(rows, name):
    """(step, value) arrays for one metric, skipping rows without it."""
    pts = [(r["step"], r["metrics"][name]) for r in rows
           if name in r.get("metrics", {})]
    if not pts:
        return None, None
    s, v = zip(*pts)
    return np.asarray(s, np.float64), np.asarray(v, np.float64)


def _slope(steps, vals):
    """Least-squares dv/dstep (value units per window)."""
    if len(steps) < 2 or steps[-1] == steps[0]:
        return 0.0
    return float(np.polyfit(steps, vals, 1)[0])


def analyze(rows, args) -> dict:
    report: dict = {"rows": len(rows), "series": {}, "violations": []}
    if len(rows) < args.min_rows:
        report["violations"].append(
            f"only {len(rows)} sampled rows (< {args.min_rows}) — the soak "
            "did not run long enough to trend")
        return report

    def record(name, steps, vals, **extra):
        report["series"][name] = dict(
            n=len(vals), first=float(vals[0]), last=float(vals[-1]),
            mean=float(vals.mean()), max=float(vals.max()),
            slope_per_window=_slope(steps, vals), **extra)

    # -- memory: halves comparison (robust to sawtooth GC noise) -----------
    steps, rss = _series(rows, "process_rss_bytes")
    if rss is None:
        report["violations"].append("process_rss_bytes missing from rows")
    else:
        half = len(rss) // 2
        first, second = rss[:half].mean(), rss[half:].mean()
        growth = (second - first) / first if first > 0 else 0.0
        record("process_rss_bytes", steps, rss, growth_frac=float(growth))
        if growth > args.rss_growth_frac:
            report["violations"].append(
                f"RSS grew {growth * 100:.1f}% between run halves "
                f"(bound {args.rss_growth_frac * 100:.1f}%) — "
                f"{first / 1e6:.1f}MB -> {second / 1e6:.1f}MB")

    # -- pending state: slope must be flat ---------------------------------
    steps, pend = _series(rows, "simnet_bundles_pending")
    if pend is None:
        report["violations"].append(
            "simnet_bundles_pending missing from rows")
    else:
        sl = _slope(steps, pend)
        record("simnet_bundles_pending", steps, pend)
        if sl > args.pending_slope:
            report["violations"].append(
                f"pending-bundle state grows {sl:.4f}/window "
                f"(bound {args.pending_slope:.4f}) — reassembly or emit "
                "bookkeeping is leaking")

    # -- calendar churn: switches per window must stay rate-bounded --------
    steps, sw = _series(rows, "simnet_epoch_switches")
    if sw is None:
        report["violations"].append("simnet_epoch_switches missing from rows")
    else:
        span = float(steps[-1] - steps[0]) if len(steps) > 1 else 1.0
        rate = float(sw[-1] - sw[0]) / span if span > 0 else 0.0
        bound = args.churn_rate if args.churn_rate is not None else 1.0
        record("simnet_epoch_switches", steps, sw,
               rate_per_window=rate, bound=bound)
        if rate > bound:
            report["violations"].append(
                f"calendar churn {rate:.3f} switches/window exceeds "
                f"{bound:.3f} — the control loop is thrashing epochs")

    # -- HA failover leg (rows carry the HA gauges only under --ha) --------
    fsteps, fcount = _series(rows, "controld_ha_failovers")
    if fcount is not None and fcount[-1] > 0:
        _, fdur = _series(rows, "controld_ha_last_failover_s")
        worst = float(fdur.max()) if fdur is not None else 0.0
        record("controld_ha_failovers", fsteps, fcount,
               worst_failover_s=worst, bound_s=args.max_failover_s)
        if worst > args.max_failover_s:
            report["violations"].append(
                f"leader failover took {worst:.3f}s of sim time "
                f"(bound {args.max_failover_s:.3f}s) — takeover is not "
                "bounded by the lease term")
        # the growth regime must not change after a takeover: re-apply
        # the RSS and pending bounds to the tail after the last failover
        last_fo = float(fsteps[np.flatnonzero(np.diff(fcount) > 0)[-1] + 1]
                        if (np.diff(fcount) > 0).any() else fsteps[0])
        steps, pend = _series(rows, "simnet_bundles_pending")
        if pend is not None:
            tail = steps >= last_fo
            if tail.sum() >= max(4, args.min_rows // 2):
                sl = _slope(steps[tail], pend[tail])
                report["series"]["simnet_bundles_pending"][
                    "post_failover_slope"] = sl
                if sl > args.pending_slope:
                    report["violations"].append(
                        f"pending-bundle state grows {sl:.4f}/window after "
                        f"the last failover (bound {args.pending_slope:.4f})"
                        " — takeover changed the growth regime")
        steps, rss = _series(rows, "process_rss_bytes")
        if rss is not None:
            tail = steps >= last_fo
            if tail.sum() >= max(4, args.min_rows // 2):
                r = rss[tail]
                half = len(r) // 2
                first, second = r[:half].mean(), r[half:].mean()
                growth = (second - first) / first if first > 0 else 0.0
                report["series"]["process_rss_bytes"][
                    "post_failover_growth_frac"] = float(growth)
                if growth > args.rss_growth_frac:
                    report["violations"].append(
                        f"RSS grew {growth * 100:.1f}% after the last "
                        f"failover (bound {args.rss_growth_frac * 100:.1f}%)"
                        " — takeover changed the growth regime")
    return report


def main(argv=None) -> int:
    args = parse_args(argv)
    failures = []
    out = {"files": {}}
    for path in args.jsonl:
        with open(path) as f:
            rows = [json.loads(line) for line in f if line.strip()]
        rep = analyze(rows, args)
        out["files"][path] = rep
        print(f"== {path}: {rep['rows']} rows")
        for name, s in rep["series"].items():
            extra = ""
            if "growth_frac" in s:
                extra = f"  growth={s['growth_frac'] * 100:+.1f}%"
            if "rate_per_window" in s:
                extra = f"  rate={s['rate_per_window']:.3f}/window"
            print(f"  {name:<28} first={s['first']:.6g} last={s['last']:.6g} "
                  f"slope={s['slope_per_window']:+.4g}/window{extra}")
        for v in rep["violations"]:
            print(f"  VIOLATION: {v}")
        failures.extend(f"{path}: {v}" for v in rep["violations"])
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
    if failures:
        print("FAILED: " + "; ".join(failures), file=sys.stderr)
        return 1
    print("soak trends OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
