"""Critical-path analyzer over per-bundle traces.

Runs a traced scenario (or loads a saved trace summary JSON) and prints the
stage-decomposition table for the requested latency percentile: which stage
— uplink serialization, WAN, LB hop, fabric hop, downlink, farm queue wait,
service, reassembly — the percentile bundle actually spent its E2E latency
in, plus the mean decomposition over the whole tail band. The stage sums
must reconcile with the measured E2E latency to < 1% (``--max-rel-err``) or
the run FAILS — the waterfall is an accounting identity, not an estimate.

    PYTHONPATH=src python scripts/analyze_trace.py --percentile 99
    PYTHONPATH=src python scripts/analyze_trace.py --scenario straggler \
        --engine host --percentile 99.9 --perfetto trace.json
    PYTHONPATH=src python scripts/analyze_trace.py --fabric elephant_mice \
        --percentile 99
    PYTHONPATH=src python scripts/analyze_trace.py --summary trace_summary.json

``--perfetto`` exports Chrome trace-event JSON (open in ui.perfetto.dev);
``--summary-json`` persists the lossless span/completion summary that
``--summary`` reloads and ``trend.py --trace-summary`` renders.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.telemetry.trace import TraceBuffer
from repro.telemetry.traceview import (format_table, stage_decomposition,
                                       summary_json)


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--scenario", default="baseline",
                     help="simnet scenario to run traced (default: baseline)")
    src.add_argument("--fabric", default=None, metavar="SCENARIO",
                     help="run a fabric scenario instead of a simnet one")
    src.add_argument("--summary", default=None, metavar="JSON",
                     help="load a saved trace summary instead of running")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=["fused", "host"], default="fused",
                    help="simnet engine (fused materializes the identical "
                         "span set post-hoc from the device program)")
    ap.add_argument("--percentile", type=float, action="append", default=None,
                    help="latency percentile(s) to decompose (default: 99)")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="head-sampling rate (tail top-k always retained)")
    ap.add_argument("--trace-tail-k", type=int, default=64)
    ap.add_argument("--max-rel-err", type=float, default=0.01,
                    help="FAIL if |stage sum - e2e| / e2e exceeds this")
    ap.add_argument("--perfetto", default=None, metavar="OUT",
                    help="write Chrome trace-event / Perfetto JSON here")
    ap.add_argument("--summary-json", default=None, metavar="OUT",
                    help="write the per-stage summary JSON here (the "
                         "payload trend.py --trace-summary renders)")
    return ap.parse_args(argv)


def _run_simnet(args) -> TraceBuffer:
    from repro.simnet import Simulator, get_scenario
    scenario = get_scenario(args.scenario)
    cfg = scenario.build_config(
        steps=args.steps, seed=args.seed, engine=args.engine, trace=True,
        trace_sample=args.trace_sample, trace_tail_k=args.trace_tail_k)
    sim = Simulator(cfg, scenario)
    report = sim.run()
    print(f"# simnet {args.scenario} steps={args.steps} "
          f"engine={report.engine} bundles={report.bundles_completed} "
          f"p99={report.latency_p99_s * 1e3:.3f}ms", file=sys.stderr)
    if report.violations:
        print("FAILED: " + "; ".join(report.violations), file=sys.stderr)
        raise SystemExit(1)
    return sim.trace


def _run_fabric(args) -> TraceBuffer:
    from repro.fabric import FabricSim, get_fabric_scenario
    sc = get_fabric_scenario(args.fabric)
    extra = dict(seed=args.seed, trace=True,
                 trace_sample=args.trace_sample,
                 trace_tail_k=args.trace_tail_k)
    if args.steps:
        extra["steps"] = args.steps
    sim = FabricSim(sc.build_config(**extra), scenario=sc)
    report = sim.run()
    print(f"# fabric {args.fabric} steps={report.steps} "
          f"bundles={report.bundles_completed} "
          f"p99={report.latency_p99_s * 1e3:.3f}ms", file=sys.stderr)
    if report.violations:
        print("FAILED: " + "; ".join(report.violations), file=sys.stderr)
        raise SystemExit(1)
    return sim.trace


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.summary:
        with open(args.summary) as f:
            tb = TraceBuffer.from_summary(json.load(f))
    elif args.fabric:
        tb = _run_fabric(args)
    else:
        tb = _run_simnet(args)

    percentiles = args.percentile or [99.0]
    failures = []
    for p in percentiles:
        d = stage_decomposition(tb, p)
        if d is None:
            failures.append(f"no retained bundle found for p{p:g}")
            continue
        print(format_table(d))
        print()
        if d["reconcile_rel_err"] > args.max_rel_err:
            failures.append(
                f"p{p:g} stage sum does not reconcile with e2e "
                f"({d['reconcile_rel_err'] * 100:.3f}% > "
                f"{args.max_rel_err * 100:.3f}%)")

    if args.perfetto:
        with open(args.perfetto, "wb") as f:
            f.write(tb.to_perfetto_json())
        print(f"# perfetto export: {args.perfetto} "
              f"({len(tb.spans()['key'])} spans)", file=sys.stderr)
    if args.summary_json:
        # lossless spans/completions (reloadable via --summary) plus the
        # compact per-stage breakdown trend.py --trace-summary renders
        out = tb.to_summary()
        out["breakdown"] = summary_json(tb, tuple(percentiles))
        with open(args.summary_json, "w") as f:
            json.dump(out, f)
        print(f"# trace summary: {args.summary_json}", file=sys.stderr)

    if failures:
        print("FAILED: " + "; ".join(failures), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
