"""controld driver: the control plane as a long-running socket service.

``--demo`` (the default, CI-smoked) exercises the full story end to end over
a real length-prefixed socket:

    reserve -> register members -> heartbeat/tick rounds (a straggler member
    reports high fill and sheds calendar slots) -> one member goes silent
    (lease lapses -> hit-less drain) -> status -> kill the daemon ->
    recover a fresh one from the JSONL journal -> byte-identical state
    digest -> snapshot + restore (ckpt-idiom atomic dirs) -> same digest.

Exit 0 iff every check holds. ``--serve`` runs the daemon until killed, for
real CN-daemon clients:

    PYTHONPATH=src python scripts/run_controld.py --demo
    PYTHONPATH=src python scripts/run_controld.py --serve --port 18070 \\
        --journal /tmp/controld/journal.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

from repro.controld import (ControlDaemon, ControldClient, Journal,
                            SocketClient, SocketServer)


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--demo", action="store_true", default=None,
                    help="run the self-checking socket demo (default)")
    ap.add_argument("--serve", action="store_true",
                    help="serve until killed instead of the demo")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (the bound port is printed)")
    ap.add_argument("--n-instances", type=int, default=2)
    ap.add_argument("--n-members", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--lease-s", type=float, default=0.25)
    ap.add_argument("--policy", choices=["proportional", "pid"],
                    default="pid")
    ap.add_argument("--journal", default=None,
                    help="JSONL journal path (demo default: a tempdir)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="auto-compaction snapshot directory: with "
                         "--compact-every the WAL rolls into snapshots and "
                         "the live file stays bounded")
    ap.add_argument("--compact-every", type=int, default=0,
                    help="roll the WAL into a snapshot every N entries "
                         "(0 = never; requires --snapshot-dir)")
    ap.add_argument("--quota-msgs-per-s", type=float, default=None,
                    help="per-reservation message-rate quota (token bucket; "
                         "over-quota member messages are rejected)")
    ap.add_argument("--quota-burst", type=float, default=None,
                    help="quota bucket depth (default: max(16, 2*rate))")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="with --serve: expose Prometheus text on "
                         "http://HOST:PORT/metrics (0 = ephemeral, the "
                         "bound port is printed)")
    ap.add_argument("--json", default=None, help="write the summary here")
    return ap.parse_args(argv)


def serve(args) -> int:
    recovered = 0
    metrics = None
    quota = dict(quota_msgs_per_s=args.quota_msgs_per_s,
                 quota_burst=args.quota_burst)
    if args.metrics_port is not None:
        from repro.telemetry.registry import MetricsRegistry
        metrics = MetricsRegistry()
    snap_dir = args.snapshot_dir
    compact = args.compact_every if snap_dir else 0
    has_snap = (snap_dir is not None and args.journal is not None
                and Journal.latest_snapshot(snap_dir) is not None)
    if has_snap:
        # compacted restart: the snapshot holds the WAL prefix, the journal
        # file only the tail — replay both, then resume the tail in place
        history = Journal.restore(snap_dir, tail_path=args.journal)
        recovered = history.seq + 1
        daemon = ControlDaemon.recover(
            history, n_instances=args.n_instances, lease_s=args.lease_s,
            metrics=metrics, **quota,
            live_journal=Journal.resume(args.journal, history.seq,
                                        snapshot_dir=snap_dir,
                                        compact_every=compact))
    elif args.journal and os.path.exists(args.journal):
        # hit-less restart: replay the existing journal and keep appending
        # to it seq-contiguously (never start a second seq-0 history)
        journal = Journal.load(args.journal)
        journal.snapshot_dir = snap_dir
        journal.compact_every = compact
        recovered = journal.seq + 1
        daemon = ControlDaemon.recover(journal,
                                       n_instances=args.n_instances,
                                       lease_s=args.lease_s,
                                       metrics=metrics, **quota)
    else:
        # no --journal: run journal-less — an in-memory journal dies with
        # the process anyway and would grow by one entry per heartbeat
        journal = (Journal(args.journal, snapshot_dir=snap_dir,
                           compact_every=compact) if args.journal else None)
        daemon = ControlDaemon(n_instances=args.n_instances,
                               lease_s=args.lease_s, journal=journal,
                               metrics=metrics, **quota)
    server = SocketServer(daemon, host=args.host, port=args.port,
                          metrics=metrics)
    host, port = server.start()
    print(f"controld serving on {host}:{port} "
          f"(journal={args.journal or 'in-memory'}, "
          f"replayed {recovered} entries)", flush=True)
    if metrics is not None:
        from repro.telemetry.export import start_http_server
        _, mport = start_http_server(metrics, host=args.host,
                                     port=args.metrics_port)
        print(f"metrics on http://{args.host}:{mport}/metrics", flush=True)
    try:
        while True:
            time.sleep(1.0)
    except KeyboardInterrupt:
        return 0
    finally:
        server.stop()


def demo(args) -> int:
    workdir = None
    if args.journal is None:
        workdir = tempfile.mkdtemp(prefix="controld_demo_")
        args.journal = os.path.join(workdir, "journal.jsonl")
    snap_dir = args.snapshot_dir or os.path.join(
        os.path.dirname(args.journal), "snapshots")

    # --compact-every turns the demo into compaction churn: the WAL rolls
    # into snapshots mid-run and the recovery below must stitch snapshot
    # prefix + live tail back together (the nightly soak exercises this)
    daemon = ControlDaemon(n_instances=args.n_instances,
                           lease_s=args.lease_s,
                           epoch_horizon=256,
                           journal=Journal(
                               args.journal,
                               snapshot_dir=(snap_dir if args.compact_every
                                             else None),
                               compact_every=args.compact_every))
    server = SocketServer(daemon, host=args.host, port=args.port)
    host, port = server.start()
    client = ControldClient(SocketClient(host, port))
    checks: dict[str, bool] = {}
    n = args.n_members

    # -- session lifecycle over the wire --------------------------------------
    r = client.reserve(policy=args.policy)
    token = r["token"]
    for m in range(n):
        client.register(token, member_id=m, node_id=m, lane_bits=1)
    client.tick(current_event=0)

    ev = 0
    checks["batched_heartbeats_accepted"] = True
    for _ in range(args.rounds):
        # one SendStateBatch frame per round: the whole window of heartbeats
        # in a single wire round trip (member 0 is the straggler:
        # persistently over-target fill)
        reply = client.send_state_batch(
            token, list(range(n)), [0.9 if m == 0 else 0.3 for m in range(n)])
        if reply["n_accepted"] != n or reply["rejected"]:
            checks["batched_heartbeats_accepted"] = False
        ev += 400
        client.tick(current_event=ev)
    status = client.status(token)
    sess = status["sessions"][token]
    w = {int(k): v["weight"] for k, v in sess["members"].items()}
    checks["straggler_shed_weight"] = w[0] < min(w[m] for m in range(1, n))

    # -- lease expiry == the hit-less drain path ------------------------------
    time.sleep(args.lease_s * 1.2)  # every lease lapses; late heartbeats
    for m in range(1, n):           # are *rejected* (protocol rule) and the
        try:                        # tick below reaps the leases
            client.send_state(token, m, fill=0.3)
        except Exception:
            pass
    ev += 400
    tick = client.tick(current_event=ev)
    expired = tick["sessions"][token]["expired"]
    checks["silent_member_lease_expired"] = 0 in expired
    checks["heartbeat_rejected_after_expiry"] = False
    try:
        client.send_state(token, 0, fill=0.3)
    except Exception:
        checks["heartbeat_rejected_after_expiry"] = True
    client.register(token, member_id=0, node_id=0, lane_bits=1)  # rejoin
    ev += 400
    client.tick(current_event=ev)

    # -- kill the daemon; recover from the journal ----------------------------
    digest = daemon.state_digest()
    seq = daemon.journal.seq
    server.stop()
    client.close()

    if args.compact_every and Journal.latest_snapshot(snap_dir) is not None:
        # part of the history already rolled into snapshots: replay the
        # snapshot prefix + the live WAL tail (what a compacted restart does)
        history = Journal.restore(snap_dir, tail_path=args.journal)
    else:
        history = Journal.load(args.journal)
    recovered = ControlDaemon.recover(
        history,
        n_instances=args.n_instances, lease_s=args.lease_s,
        epoch_horizon=256)
    checks["journal_replay_digest_identical"] = (
        recovered.state_digest() == digest)

    # -- snapshot + restore (ckpt-idiom atomic directories) -------------------
    recovered.journal.snapshot(snap_dir)
    restored = ControlDaemon.recover(
        Journal.restore(snap_dir),
        n_instances=args.n_instances, lease_s=args.lease_s,
        epoch_horizon=256)
    checks["snapshot_restore_digest_identical"] = (
        restored.state_digest() == digest)

    summary = {
        "transport": f"socket {host}:{port}",
        "journal": args.journal,
        "journal_entries": seq + 1,
        "final_weights": {str(k): round(v, 4) for k, v in sorted(w.items())},
        "checks": checks,
    }
    print(json.dumps(summary, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
    failed = [k for k, ok in checks.items() if not ok]
    if failed:
        print("FAILED: " + ", ".join(failed), file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.serve:
        return serve(args)
    return demo(args)


if __name__ == "__main__":
    sys.exit(main())
