"""controld driver: the control plane as a long-running socket service.

``--demo`` (the default, CI-smoked) exercises the full story end to end over
a real length-prefixed socket:

    reserve -> register members -> heartbeat/tick rounds (a straggler member
    reports high fill and sheds calendar slots) -> one member goes silent
    (lease lapses -> hit-less drain) -> status -> kill the daemon ->
    recover a fresh one from the JSONL journal -> byte-identical state
    digest -> snapshot + restore (ckpt-idiom atomic dirs) -> same digest.

Exit 0 iff every check holds. ``--serve`` runs the daemon until killed, for
real CN-daemon clients:

    PYTHONPATH=src python scripts/run_controld.py --demo
    PYTHONPATH=src python scripts/run_controld.py --serve --port 18070 \\
        --journal /tmp/controld/journal.jsonl

HA (DESIGN.md §Controld-HA): ``--serve`` plus ``--node-id``/``--lease-store``
wraps the daemon in an ``HANode`` — leadership is a term-bounded lease in
the shared file arbiter, ``--replicate-to`` names the standby endpoints the
leader WAL-ships to, and ``--standby`` starts without claiming the lease.
``--ha-demo`` (CI's failover smoke) spawns a leader + standby as real
subprocesses, SIGKILLs the leader, and proves a retrying client completes
reserve/heartbeat/Tick rounds against the promoted successor with the state
digest intact:

    PYTHONPATH=src python scripts/run_controld.py --ha-demo
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import socket as socketlib
import subprocess
import sys
import tempfile
import threading
import time

from repro.controld import (ControlDaemon, ControldClient, FailoverTransport,
                            FileLeaseStore, HANode, Journal, RetryPolicy,
                            SocketClient, SocketServer, TransportError)


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--demo", action="store_true", default=None,
                    help="run the self-checking socket demo (default)")
    ap.add_argument("--serve", action="store_true",
                    help="serve until killed instead of the demo")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = ephemeral (the bound port is printed)")
    ap.add_argument("--n-instances", type=int, default=2)
    ap.add_argument("--n-members", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--lease-s", type=float, default=0.25)
    ap.add_argument("--policy", choices=["proportional", "pid"],
                    default="pid")
    ap.add_argument("--journal", default=None,
                    help="JSONL journal path (demo default: a tempdir)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="auto-compaction snapshot directory: with "
                         "--compact-every the WAL rolls into snapshots and "
                         "the live file stays bounded")
    ap.add_argument("--compact-every", type=int, default=0,
                    help="roll the WAL into a snapshot every N entries "
                         "(0 = never; requires --snapshot-dir)")
    ap.add_argument("--quota-msgs-per-s", type=float, default=None,
                    help="per-reservation message-rate quota (token bucket; "
                         "over-quota member messages are rejected)")
    ap.add_argument("--quota-burst", type=float, default=None,
                    help="quota bucket depth (default: max(16, 2*rate))")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="with --serve: expose Prometheus text on "
                         "http://HOST:PORT/metrics (0 = ephemeral, the "
                         "bound port is printed)")
    ap.add_argument("--json", default=None, help="write the summary here")
    # -- HA (DESIGN.md §Controld-HA) ------------------------------------------
    ap.add_argument("--ha-demo", action="store_true",
                    help="failover smoke: subprocess leader + standby, "
                         "SIGKILL the leader, client completes its rounds "
                         "against the promoted successor (digest audited)")
    ap.add_argument("--node-id", default=None,
                    help="with --serve: run as HA node NAME (requires "
                         "--lease-store)")
    ap.add_argument("--lease-store", default=None,
                    help="shared lease-arbiter file (FileLeaseStore)")
    ap.add_argument("--lease-term-s", type=float, default=1.0,
                    help="leadership lease term; a dead leader is taken "
                         "over within ~one term")
    ap.add_argument("--replicate-to", action="append", default=[],
                    metavar="NAME=HOST:PORT",
                    help="standby endpoint to WAL-ship to (repeatable)")
    ap.add_argument("--standby", action="store_true",
                    help="start as a warm standby (do not claim the lease "
                         "at startup; promote only after it lapses)")
    return ap.parse_args(argv)


class _LazyPeer:
    """Replication transport to a peer that (re)connects on demand: at
    startup or across a standby restart the endpoint may be down — every
    failure surfaces as ``TransportError`` (the replicator marks the peer
    dead; the serve ticker's ``reattach_dead_peers`` retries later)."""

    def __init__(self, host: str, port: int):
        self.host, self.port = host, int(port)
        self._c = None

    def call(self, msg):
        try:
            if self._c is None:
                self._c = SocketClient(self.host, self.port, timeout_s=5.0)
            return self._c.call(msg)
        except (OSError, TransportError) as e:
            if self._c is not None:
                self._c.close()
                self._c = None
            raise TransportError(
                f"peer {self.host}:{self.port}: {e}") from e

    def close(self) -> None:
        if self._c is not None:
            self._c.close()
            self._c = None


def serve(args) -> int:
    recovered = 0
    metrics = None
    quota = dict(quota_msgs_per_s=args.quota_msgs_per_s,
                 quota_burst=args.quota_burst)
    if args.node_id and not args.lease_store:
        print("--node-id requires --lease-store", file=sys.stderr)
        return 2
    if args.node_id and not args.journal:
        # HA replication mirrors the WAL into the standby's journal; a
        # journal-less HA node would re-apply every shipment from seq 0
        args.journal = os.path.join(
            tempfile.mkdtemp(prefix=f"controld_{args.node_id}_"),
            "journal.jsonl")
    if args.metrics_port is not None:
        from repro.telemetry.registry import MetricsRegistry
        metrics = MetricsRegistry()
    snap_dir = args.snapshot_dir
    compact = args.compact_every if snap_dir else 0
    has_snap = (snap_dir is not None and args.journal is not None
                and Journal.latest_snapshot(snap_dir) is not None)
    if has_snap:
        # compacted restart: the snapshot holds the WAL prefix, the journal
        # file only the tail — replay both, then resume the tail in place
        history = Journal.restore(snap_dir, tail_path=args.journal)
        recovered = history.seq + 1
        daemon = ControlDaemon.recover(
            history, n_instances=args.n_instances, lease_s=args.lease_s,
            metrics=metrics, **quota,
            live_journal=Journal.resume(args.journal, history.seq,
                                        snapshot_dir=snap_dir,
                                        compact_every=compact))
    elif args.journal and os.path.exists(args.journal):
        # hit-less restart: replay the existing journal and keep appending
        # to it seq-contiguously (never start a second seq-0 history)
        journal = Journal.load(args.journal)
        journal.snapshot_dir = snap_dir
        journal.compact_every = compact
        recovered = journal.seq + 1
        daemon = ControlDaemon.recover(journal,
                                       n_instances=args.n_instances,
                                       lease_s=args.lease_s,
                                       metrics=metrics, **quota)
    else:
        # no --journal: run journal-less — an in-memory journal dies with
        # the process anyway and would grow by one entry per heartbeat
        journal = (Journal(args.journal, snapshot_dir=snap_dir,
                           compact_every=compact) if args.journal else None)
        daemon = ControlDaemon(n_instances=args.n_instances,
                               lease_s=args.lease_s, journal=journal,
                               metrics=metrics, **quota)
    handler, node, stop_beat = daemon, None, threading.Event()
    if args.node_id:
        store = FileLeaseStore(args.lease_store, term_s=args.lease_term_s)
        node = HANode(args.node_id, daemon, store, metrics=metrics)
        for spec in args.replicate_to:
            name, addr = spec.split("=", 1)
            peer_host, peer_port = addr.rsplit(":", 1)
            node.peers[name] = _LazyPeer(peer_host, int(peer_port))
        if not args.standby:
            node.step()  # claim the lease now -> leader; attach peers
        handler = node
    server = SocketServer(handler, host=args.host, port=args.port,
                          metrics=metrics)
    host, port = server.start()
    role = f", ha-node {args.node_id} role={node.role}" if node else ""
    print(f"controld serving on {host}:{port} "
          f"(journal={args.journal or 'in-memory'}, "
          f"replayed {recovered} entries{role})", flush=True)
    if node is not None:
        # lease beat: the leader renews (and repairs dead standbys), a
        # standby claims within ~term/4 of the lease lapsing — failover
        # does not have to wait for client traffic
        def _beat():
            period = max(0.02, args.lease_term_s / 4.0)
            while not stop_beat.wait(period):
                node.step()
                node.reattach_dead_peers()
        threading.Thread(target=_beat, daemon=True).start()
    if metrics is not None:
        from repro.telemetry.export import start_http_server
        _, mport = start_http_server(metrics, host=args.host,
                                     port=args.metrics_port)
        print(f"metrics on http://{args.host}:{mport}/metrics", flush=True)
    try:
        while True:
            time.sleep(1.0)
    except KeyboardInterrupt:
        return 0
    finally:
        stop_beat.set()
        server.stop()


def demo(args) -> int:
    workdir = None
    if args.journal is None:
        workdir = tempfile.mkdtemp(prefix="controld_demo_")
        args.journal = os.path.join(workdir, "journal.jsonl")
    snap_dir = args.snapshot_dir or os.path.join(
        os.path.dirname(args.journal), "snapshots")

    # --compact-every turns the demo into compaction churn: the WAL rolls
    # into snapshots mid-run and the recovery below must stitch snapshot
    # prefix + live tail back together (the nightly soak exercises this)
    daemon = ControlDaemon(n_instances=args.n_instances,
                           lease_s=args.lease_s,
                           epoch_horizon=256,
                           journal=Journal(
                               args.journal,
                               snapshot_dir=(snap_dir if args.compact_every
                                             else None),
                               compact_every=args.compact_every))
    server = SocketServer(daemon, host=args.host, port=args.port)
    host, port = server.start()
    client = ControldClient(SocketClient(host, port))
    checks: dict[str, bool] = {}
    n = args.n_members

    # -- session lifecycle over the wire --------------------------------------
    r = client.reserve(policy=args.policy)
    token = r["token"]
    for m in range(n):
        client.register(token, member_id=m, node_id=m, lane_bits=1)
    client.tick(current_event=0)

    ev = 0
    checks["batched_heartbeats_accepted"] = True
    for _ in range(args.rounds):
        # one SendStateBatch frame per round: the whole window of heartbeats
        # in a single wire round trip (member 0 is the straggler:
        # persistently over-target fill)
        reply = client.send_state_batch(
            token, list(range(n)), [0.9 if m == 0 else 0.3 for m in range(n)])
        if reply["n_accepted"] != n or reply["rejected"]:
            checks["batched_heartbeats_accepted"] = False
        ev += 400
        client.tick(current_event=ev)
    status = client.status(token)
    sess = status["sessions"][token]
    w = {int(k): v["weight"] for k, v in sess["members"].items()}
    checks["straggler_shed_weight"] = w[0] < min(w[m] for m in range(1, n))

    # -- lease expiry == the hit-less drain path ------------------------------
    time.sleep(args.lease_s * 1.2)  # every lease lapses; late heartbeats
    for m in range(1, n):           # are *rejected* (protocol rule) and the
        try:                        # tick below reaps the leases
            client.send_state(token, m, fill=0.3)
        except Exception:
            pass
    ev += 400
    tick = client.tick(current_event=ev)
    expired = tick["sessions"][token]["expired"]
    checks["silent_member_lease_expired"] = 0 in expired
    checks["heartbeat_rejected_after_expiry"] = False
    try:
        client.send_state(token, 0, fill=0.3)
    except Exception:
        checks["heartbeat_rejected_after_expiry"] = True
    client.register(token, member_id=0, node_id=0, lane_bits=1)  # rejoin
    ev += 400
    client.tick(current_event=ev)

    # -- kill the daemon; recover from the journal ----------------------------
    digest = daemon.state_digest()
    seq = daemon.journal.seq
    server.stop()
    client.close()

    if args.compact_every and Journal.latest_snapshot(snap_dir) is not None:
        # part of the history already rolled into snapshots: replay the
        # snapshot prefix + the live WAL tail (what a compacted restart does)
        history = Journal.restore(snap_dir, tail_path=args.journal)
    else:
        history = Journal.load(args.journal)
    recovered = ControlDaemon.recover(
        history,
        n_instances=args.n_instances, lease_s=args.lease_s,
        epoch_horizon=256)
    checks["journal_replay_digest_identical"] = (
        recovered.state_digest() == digest)

    # -- snapshot + restore (ckpt-idiom atomic directories) -------------------
    recovered.journal.snapshot(snap_dir)
    restored = ControlDaemon.recover(
        Journal.restore(snap_dir),
        n_instances=args.n_instances, lease_s=args.lease_s,
        epoch_horizon=256)
    checks["snapshot_restore_digest_identical"] = (
        restored.state_digest() == digest)

    summary = {
        "transport": f"socket {host}:{port}",
        "journal": args.journal,
        "journal_entries": seq + 1,
        "final_weights": {str(k): round(v, 4) for k, v in sorted(w.items())},
        "checks": checks,
    }
    print(json.dumps(summary, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
    failed = [k for k, ok in checks.items() if not ok]
    if failed:
        print("FAILED: " + ", ".join(failed), file=sys.stderr)
        return 1
    return 0


def _free_port() -> int:
    s = socketlib.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _wait_port(port: int, timeout_s: float = 15.0) -> bool:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        try:
            socketlib.create_connection(("127.0.0.1", port),
                                        timeout=0.5).close()
            return True
        except OSError:
            time.sleep(0.05)
    return False


def ha_demo(args) -> int:
    """The failover smoke CI runs: leader + warm standby as real
    subprocesses over one file lease arbiter, a client doing
    reserve/register/heartbeat/Tick rounds, SIGKILL the leader mid-run —
    the retrying client must complete its rounds against the promoted
    successor, and the standby's pre-kill digest must equal the leader's
    (the WAL-shipping audit: the successor resumes byte-identical)."""
    import repro.controld as _pkg
    workdir = tempfile.mkdtemp(prefix="controld_ha_demo_")
    lease = os.path.join(workdir, "lease.json")
    ports = {"cd0": _free_port(), "cd1": _free_port()}
    term = args.lease_term_s
    cn_lease = max(args.lease_s, 4.0 * term)  # CN leases outlive a failover
    env = dict(os.environ)
    src = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(_pkg.__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")

    def spawn(name: str, peer: str, standby: bool) -> subprocess.Popen:
        cmd = [sys.executable, os.path.abspath(__file__), "--serve",
               "--host", "127.0.0.1", "--port", str(ports[name]),
               "--node-id", name, "--lease-store", lease,
               "--lease-term-s", str(term),
               "--replicate-to", f"{peer}=127.0.0.1:{ports[peer]}",
               "--journal", os.path.join(workdir, f"{name}.jsonl"),
               "--lease-s", str(cn_lease),
               "--n-instances", str(args.n_instances)]
        if standby:
            cmd.append("--standby")
        return subprocess.Popen(cmd, env=env)

    def node_status(port: int) -> dict:
        c = ControldClient(SocketClient("127.0.0.1", port, timeout_s=5.0))
        try:
            return c.status()
        finally:
            c.close()

    n = args.n_members
    checks: dict[str, bool] = {}
    procs = {"cd1": spawn("cd1", "cd0", standby=True),
             "cd0": spawn("cd0", "cd1", standby=False)}
    try:
        for name, port in ports.items():
            if not _wait_port(port):
                print(f"node {name} never came up", file=sys.stderr)
                return 1
        time.sleep(max(0.1, term / 2.0))  # let the lease beat attach peers

        def connect(port):
            def factory():
                return SocketClient("127.0.0.1", port, timeout_s=5.0)
            return factory

        retry = RetryPolicy(base_s=term / 16.0, cap_s=term / 8.0,
                            max_elapsed_s=30.0 * term, seed=0)
        client = ControldClient(
            FailoverTransport([connect(ports["cd0"]), connect(ports["cd1"])],
                              retry=retry),
            client_id="hademo")
        token = client.reserve(policy=args.policy)["token"]
        reg = client.register_batch(token, list(range(n)), lane_bits=1)
        checks["members_registered"] = not reg["rejected"]
        client.tick(current_event=0)
        for _ in range(4):
            client.send_state_batch(token, list(range(n)), [0.4] * n)

        st = {name: node_status(port) for name, port in ports.items()}
        roles = {name: s["ha"]["role"] for name, s in st.items()}
        checks["one_leader_one_standby"] = (
            sorted(roles.values()) == ["leader", "standby"])
        checks["standby_digest_tracks_leader"] = (
            st["cd0"]["state_digest"] == st["cd1"]["state_digest"])

        leader = next(name for name, r in roles.items() if r == "leader")
        successor = "cd1" if leader == "cd0" else "cd0"
        os.kill(procs[leader].pid, signal.SIGKILL)
        procs[leader].wait()
        t_kill = time.monotonic()

        # the retrying client alone completes the failover
        ok_hb = 0
        for _ in range(3):
            reply = client.send_state_batch(token, list(range(n)),
                                            [0.5] * n)
            ok_hb += int(reply["n_accepted"] == n and not reply["rejected"])
        tick = client.tick(current_event=400)
        failover_s = time.monotonic() - t_kill
        checks["heartbeats_accepted_after_failover"] = ok_hb == 3
        checks["tick_completed_after_failover"] = token in tick["sessions"]

        after = node_status(ports[successor])
        checks["successor_promoted"] = after["ha"]["role"] == "leader"
        checks["generation_fenced"] = after["ha"]["generation"] >= 2
        checks["failover_bounded"] = failover_s < 5.0 * term
        summary = {
            "workdir": workdir,
            "leader_killed": leader,
            "successor": successor,
            "failover_s": round(failover_s, 3),
            "lease_term_s": term,
            "pre_kill_digest": st["cd0"]["state_digest"][:16],
            "checks": checks,
        }
        print(json.dumps(summary, indent=2))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(summary, f, indent=2)
        failed = [k for k, ok in checks.items() if not ok]
        if failed:
            print("FAILED: " + ", ".join(failed), file=sys.stderr)
            return 1
        return 0
    finally:
        for p in procs.values():
            if p.poll() is None:
                p.kill()
                p.wait()


def main(argv=None) -> int:
    args = parse_args(argv)
    if args.ha_demo:
        return ha_demo(args)
    if args.serve:
        return serve(args)
    return demo(args)


if __name__ == "__main__":
    sys.exit(main())
