"""Paper fig. 7c + §IV-C accounting: hit-less epoch switching. Streams three
epochs of traffic (1 CN -> 3 CNs -> 10 CNs with CN-5 at 2x weight) through
the full pipeline with WAN reorder, then audits: zero packets dropped, zero
events split across members — the paper's acceptance criteria, measured the
same way (full input/output accounting)."""
from __future__ import annotations


from benchmarks.common import emit_json, row
from repro.core import EpochManager, MemberSpec
from repro.data.daq import DAQConfig
from repro.data.pipeline import StreamingPipeline
from repro.data.transport import TransportConfig


def run():
    em = EpochManager(max_members=64)
    em.initialize({0: MemberSpec(node_id=0, lane_bits=2)}, {0: 1.0})
    pipe = StreamingPipeline(
        DAQConfig(n_daqs=5, seq_len=64, mean_bundle_bytes=18_000, seed=11),
        TransportConfig(reorder_window=48, seed=11), em)

    import time
    t0 = time.perf_counter()
    pipe.pump(20)
    b1 = pipe.fleet.event_number + 40
    em.reconfigure({i: MemberSpec(node_id=i, lane_bits=2) for i in (4, 5, 6)},
                   {i: 1.0 for i in (4, 5, 6)}, boundary_event=b1)
    pipe.pump(40)
    b2 = pipe.fleet.event_number + 40
    em.reconfigure({i: MemberSpec(node_id=i, lane_bits=2) for i in range(10)},
                   {i: 2.0 if i == 5 else 1.0 for i in range(10)},
                   boundary_event=b2)
    pipe.pump(80)
    em.quiesce(0)
    em.quiesce(1)
    dt_us = (time.perf_counter() - t0) * 1e6

    emap = pipe.event_member_map()
    split = sum(1 for ms in emap.values() if len(ms) > 1)
    row("epoch_switch_accounting", dt_us / max(pipe.stats.n_packets, 1),
        f"packets={pipe.stats.n_packets} dropped={pipe.stats.n_discarded} "
        f"split_events={split} (paper: 0 loss, 0 splits across 3 epochs)")
    assert pipe.stats.n_discarded == 0 and split == 0
    emit_json("epoch_switch", metrics={
        "us_per_packet": dt_us / max(pipe.stats.n_packets, 1),
        "packets": pipe.stats.n_packets,
        "dropped": pipe.stats.n_discarded,
        "split_events": split,
    }, params={"epochs": 3, "reorder_window": 48})


if __name__ == "__main__":
    run()
