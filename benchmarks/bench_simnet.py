"""Table: virtual-time simulator throughput (simulated packets per second).

Two figures:

* ``simnet_core`` — the simulator's *transport core* per window: per-DAQ
  uplink serialization, the WAN hop (loss/dup/jitter, one permutation),
  the per-member downlink bank, and the bounded farm-queue scan
  (``simnet.links`` + ``simnet.queues`` — the code this subsystem adds).
  Both queue engines (numpy scan and the jitted ``lax.scan``) are timed.
  **CI gate: >= 100k simulated packets/sec on the batched (np) path.**
* ``simnet_closed_loop`` — the full scenario loop (DAQ generation,
  segmentation, routing through ``DataPlane``, reassembly, telemetry, CP
  feedback), timed on BOTH engines: the fused device-resident superblock
  path (``simnet.fused``, the default — **CI gate: >= 100k pkt/s**) and the
  per-window host loop (the parity oracle, kept for the trend table). The
  fused figure also asserts the jit-discipline invariants: one trace for
  the whole run and one jitted dispatch per superblock.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit_json, row
from repro.simnet import LinkConfig, Simulator, SimConfig
from repro.simnet.links import Link, LinkSet
from repro.simnet.queues import FarmConfig, FarmQueues

N = 16_384          # packets per window
M = 16              # members
N_DAQS = 8
WINDOW_S = 0.02
MEAN_BYTES = 2048


def _core_window(queue_engine: str, n_windows: int = 5) -> float:
    """Packets/sec through uplinks -> WAN -> downlinks -> farm queues."""
    rng = np.random.default_rng(0)
    daq = rng.integers(0, N_DAQS, N).astype(np.int64)
    member = rng.integers(0, M, N).astype(np.int64)
    nbytes = np.full((N,), MEAN_BYTES, np.float64)

    uplinks = LinkSet([LinkConfig(rate_Bps=400e6, jitter_s=1e-5, seed=1)
                       for _ in range(N_DAQS)])
    wan = Link(LinkConfig(prop_delay_s=1e-3, jitter_s=2e-4, loss_prob=0.01,
                          duplicate_prob=0.01, seed=2))
    downlinks = LinkSet([LinkConfig(rate_Bps=400e6, prop_delay_s=5e-5,
                                    jitter_s=1e-5, seed=3)
                         for _ in range(M)])
    farm = FarmQueues(FarmConfig.uniform(M, per_packet_s=1e-7,
                                         per_byte_s=5e-10, capacity_s=1.0),
                      backend=queue_engine)

    def one_window(w: int) -> None:
        t_emit = w * WINDOW_S + np.sort(rng.uniform(0, WINDOW_S, N))
        t_up, keep_up = uplinks.transit(daq, t_emit, nbytes)
        rows = np.flatnonzero(keep_up)
        d = wan.transit(t_up[rows], nbytes[rows])
        src = rows[d.src]
        t_cn, keep_dl = downlinks.transit(member[src], d.t_arrive, nbytes[src])
        rows2 = np.flatnonzero(keep_dl)
        farm.serve(member[src[rows2]], t_cn[rows2], nbytes[src[rows2]])

    one_window(0)  # warm (jit compile for the jnp engine)
    t0 = time.perf_counter()
    for w in range(1, n_windows + 1):
        one_window(w)
    dt = time.perf_counter() - t0
    return n_windows * N / dt


def _closed_loop(engine: str) -> float:
    kw = dict(triggers_per_step=64, n_daqs=4, n_members=16,
              mean_bundle_bytes=12_000, engine=engine)
    Simulator(SimConfig(steps=20, **kw)).run()  # warm the jit caches
    r = Simulator(SimConfig(steps=40, **kw)).run()
    assert not r.violations, r.violations
    assert r.engine == engine, (r.engine, engine)
    return r.packets_per_sec


def run():
    pps_np = _core_window("np")
    row("simnet_core_np", 1e6 / pps_np,
        f"{pps_np:,.0f} simulated pkt/s (links + farm scan, want >= 100k)")
    pps_jnp = _core_window("jnp")
    row("simnet_core_jnp", 1e6 / pps_jnp,
        f"{pps_jnp:,.0f} simulated pkt/s (lax.scan farm engine)")

    from repro.simnet import fused
    calls0, traces0 = fused.FUSED_STEP_CALLS, fused.FUSED_TRACES
    pps_fused = _closed_loop("fused")
    calls = fused.FUSED_STEP_CALLS - calls0
    traces = fused.FUSED_TRACES - traces0
    # jit discipline: one trace for both runs (same shapes), one jitted
    # dispatch per superblock (20+40 windows / 8-window superblocks = 8)
    assert traces == 1, f"retrace: {traces} traces for same-shape configs"
    assert calls == 8, f"{calls} dispatches for 8 superblocks"
    row("simnet_closed_loop_fused", 1e6 / pps_fused,
        f"{pps_fused:,.0f} pkt/s fused loop (want >= 100k; "
        f"{calls} dispatches, {traces} trace)")
    pps_host = _closed_loop("host")
    row("simnet_closed_loop_host", 1e6 / pps_host,
        f"{pps_host:,.0f} pkt/s host loop (the parity oracle)")

    emit_json("simnet", metrics={
        "core_np_pkts_per_s": pps_np,
        "core_jnp_pkts_per_s": pps_jnp,
        # the default engine's figure is THE closed-loop number
        "closed_loop_pkts_per_s": pps_fused,
        "fused_loop_pkts_per_s": pps_fused,
        "host_loop_pkts_per_s": pps_host,
        "fused_speedup_vs_host": pps_fused / pps_host,
        "fused_device_calls_per_superblock": 1.0,
        "fused_retraces": float(traces),
    }, params={
        "n_packets_per_window": N, "n_members": M, "n_daqs": N_DAQS,
        "closed_loop": {"steps": 40, "triggers_per_step": 64, "n_daqs": 4,
                        "n_members": 16},
        "fused_dispatches": calls,
    })
    return pps_np


if __name__ == "__main__":
    print(f"core path: {run():,.0f} simulated packets/sec")
