"""Table: virtual-time simulator throughput (simulated packets per second).

Two figures:

* ``simnet_core`` — the simulator's *transport core* per window: per-DAQ
  uplink serialization, the WAN hop (loss/dup/jitter, one permutation),
  the per-member downlink bank, and the bounded farm-queue scan
  (``simnet.links`` + ``simnet.queues`` — the code this subsystem adds).
  Both queue engines (numpy scan and the jitted ``lax.scan``) are timed.
  **CI gate: >= 100k simulated packets/sec on the batched (np) path.**
* ``simnet_closed_loop`` — the full scenario loop (DAQ generation,
  segmentation, routing through ``DataPlane``, reassembly, telemetry, CP
  feedback). Reported for the trend table; the pre-existing stages have
  their own gated benches (dispatch, ingest, route_throughput).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit_json, row
from repro.simnet import LinkConfig, Simulator, SimConfig
from repro.simnet.links import Link, LinkSet
from repro.simnet.queues import FarmConfig, FarmQueues

N = 16_384          # packets per window
M = 16              # members
N_DAQS = 8
WINDOW_S = 0.02
MEAN_BYTES = 2048


def _core_window(queue_engine: str, n_windows: int = 5) -> float:
    """Packets/sec through uplinks -> WAN -> downlinks -> farm queues."""
    rng = np.random.default_rng(0)
    daq = rng.integers(0, N_DAQS, N).astype(np.int64)
    member = rng.integers(0, M, N).astype(np.int64)
    nbytes = np.full((N,), MEAN_BYTES, np.float64)

    uplinks = LinkSet([LinkConfig(rate_Bps=400e6, jitter_s=1e-5, seed=1)
                       for _ in range(N_DAQS)])
    wan = Link(LinkConfig(prop_delay_s=1e-3, jitter_s=2e-4, loss_prob=0.01,
                          duplicate_prob=0.01, seed=2))
    downlinks = LinkSet([LinkConfig(rate_Bps=400e6, prop_delay_s=5e-5,
                                    jitter_s=1e-5, seed=3)
                         for _ in range(M)])
    farm = FarmQueues(FarmConfig.uniform(M, per_packet_s=1e-7,
                                         per_byte_s=5e-10, capacity_s=1.0),
                      backend=queue_engine)

    def one_window(w: int) -> None:
        t_emit = w * WINDOW_S + np.sort(rng.uniform(0, WINDOW_S, N))
        t_up, keep_up = uplinks.transit(daq, t_emit, nbytes)
        rows = np.flatnonzero(keep_up)
        d = wan.transit(t_up[rows], nbytes[rows])
        src = rows[d.src]
        t_cn, keep_dl = downlinks.transit(member[src], d.t_arrive, nbytes[src])
        rows2 = np.flatnonzero(keep_dl)
        farm.serve(member[src[rows2]], t_cn[rows2], nbytes[src[rows2]])

    one_window(0)  # warm (jit compile for the jnp engine)
    t0 = time.perf_counter()
    for w in range(1, n_windows + 1):
        one_window(w)
    dt = time.perf_counter() - t0
    return n_windows * N / dt


def _closed_loop() -> float:
    cfg = SimConfig(steps=20, triggers_per_step=64, n_daqs=4, n_members=16,
                    mean_bundle_bytes=12_000)
    Simulator(cfg).run()  # warm the jit caches
    r = Simulator(SimConfig(steps=40, triggers_per_step=64, n_daqs=4,
                            n_members=16, mean_bundle_bytes=12_000)).run()
    assert not r.violations, r.violations
    return r.packets_per_sec


def run():
    pps_np = _core_window("np")
    row("simnet_core_np", 1e6 / pps_np,
        f"{pps_np:,.0f} simulated pkt/s (links + farm scan, want >= 100k)")
    pps_jnp = _core_window("jnp")
    row("simnet_core_jnp", 1e6 / pps_jnp,
        f"{pps_jnp:,.0f} simulated pkt/s (lax.scan farm engine)")
    pps_loop = _closed_loop()
    row("simnet_closed_loop", 1e6 / pps_loop,
        f"{pps_loop:,.0f} pkt/s full loop (DAQ+route+reassembly+CP)")

    emit_json("simnet", metrics={
        "core_np_pkts_per_s": pps_np,
        "core_jnp_pkts_per_s": pps_jnp,
        "closed_loop_pkts_per_s": pps_loop,
    }, params={
        "n_packets_per_window": N, "n_members": M, "n_daqs": N_DAQS,
        "closed_loop": {"steps": 40, "triggers_per_step": 64, "n_daqs": 4,
                        "n_members": 16},
    })
    return pps_np


if __name__ == "__main__":
    print(f"core path: {run():,.0f} simulated packets/sec")
