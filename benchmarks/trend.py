"""Render the BENCH_*.json artifacts as a trend table.

Each bench emits ``BENCH_<name>.json`` (benchmarks/common.emit_json). CI
uploads them as workflow artifacts, so the run-over-run trajectory lives in
the artifact history; this script prints one directory's snapshot — or, given
several directories (e.g. a previous run's downloaded artifacts next to the
current ones), a side-by-side table with the relative change.

    python -m benchmarks.trend bench-out [previous-bench-out]
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load_dir(d: str) -> dict[str, dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(d, "BENCH_*.json"))):
        try:
            with open(path) as f:
                rec = json.load(f)
            out[rec.get("bench", os.path.basename(path))] = rec
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping {path}: {e}", file=sys.stderr)
    return out


def fmt(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:,.2f}" if abs(v) >= 0.01 else f"{v:.3g}"
    return str(v)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    cur_dir = argv[0] if argv else "."
    prev_dir = argv[1] if len(argv) > 1 else None
    cur = load_dir(cur_dir)
    prev = load_dir(prev_dir) if prev_dir else {}
    if not cur:
        print(f"no BENCH_*.json under {cur_dir}")
        return 1
    rows = []
    for bench, rec in sorted(cur.items()):
        for metric, value in sorted(rec.get("metrics", {}).items()):
            if not isinstance(value, (int, float)) or isinstance(value, bool):
                continue
            delta = ""
            pv = prev.get(bench, {}).get("metrics", {}).get(metric)
            if isinstance(pv, (int, float)) and pv:
                delta = f"{(value - pv) / abs(pv) * 100:+.1f}%"
            rows.append((bench, metric, fmt(value), delta))
    w0 = max(len(r[0]) for r in rows)
    w1 = max(len(r[1]) for r in rows)
    w2 = max(len(r[2]) for r in rows)
    print(f"{'bench':<{w0}}  {'metric':<{w1}}  {'value':>{w2}}  trend")
    print("-" * (w0 + w1 + w2 + 12))
    for b, m, v, d in rows:
        print(f"{b:<{w0}}  {m:<{w1}}  {v:>{w2}}  {d}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
