"""Render BENCH_*.json artifacts as a trend table, gate regressions, and
build the bench-trend dashboard.

Each bench emits ``BENCH_<name>.json`` (benchmarks/common.emit_json). CI
keeps a rolling *bench-history* directory (one stamped subdirectory per run,
``<utc>_<sha12>/BENCH_*.json``) so the run-over-run trajectory survives
between workflow runs; this script is the whole toolchain over those files:

    # one directory's snapshot (optionally vs a previous run's directory)
    python -m benchmarks.trend bench-out [previous-bench-out]

    # gate: fail (>20% past the committed floor) with full history + a
    # machine-readable TREND-CHECK: line CI can grep
    python -m benchmarks.trend bench-out \\
        --check benchmarks/baselines/baselines.json --history bench-history

    # append this run to the rolling history (CI does this every bench run)
    python -m benchmarks.trend bench-out --append-history bench-history \\
        --sha "$GITHUB_SHA"

    # render the static HTML dashboard (inline SVG, no JS libraries)
    python -m benchmarks.trend bench-out --history bench-history \\
        --check benchmarks/baselines/baselines.json --html dashboard.html

``--check`` compares the snapshot against the *committed* baseline
(``benchmarks/baselines/baselines.json``: curated metrics with explicit
better-direction and conservative floor/ceiling values) and exits non-zero
if any checked metric regresses more than ``--threshold`` (default 20%)
past its baseline, or if a baselined bench didn't produce a JSON at all
(a silently vanished bench is a regression).
"""
from __future__ import annotations

import argparse
import glob
import html
import json
import os
import re
import shutil
import sys
import time

_STAMP_RE = re.compile(r"^(?P<date>[0-9TZ]+)_(?P<sha>[0-9a-f]{4,40})$")


def load_dir(d: str) -> dict[str, dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(d, "BENCH_*.json"))):
        try:
            with open(path) as f:
                rec = json.load(f)
            out[rec.get("bench", os.path.basename(path))] = rec
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping {path}: {e}", file=sys.stderr)
    return out


def fmt(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:,.2f}" if abs(v) >= 0.01 else f"{v:.3g}"
    return str(v)


# ---------------------------------------------------------------------------
# bench history: one stamped subdirectory per run
# ---------------------------------------------------------------------------

def load_history(history_dir: str) -> list[dict]:
    """Stamped runs, oldest first. Each entry: ``{"stamp", "sha", "date",
    "benches": {bench: record}}``. Stamps are ``<utc>_<sha12>`` so the
    lexicographic sort IS chronological order."""
    entries = []
    if not history_dir or not os.path.isdir(history_dir):
        return entries
    for name in sorted(os.listdir(history_dir)):
        sub = os.path.join(history_dir, name)
        if not os.path.isdir(sub):
            continue
        m = _STAMP_RE.match(name)
        benches = load_dir(sub)
        if not benches:
            continue
        entries.append({
            "stamp": name,
            "sha": m.group("sha") if m else name,
            "date": m.group("date") if m else "",
            "benches": benches,
        })
    return entries


def append_history(cur_dir: str, history_dir: str, sha: str,
                   date: str | None = None, keep: int = 60) -> str:
    """Copy ``cur_dir``'s BENCH_*.json into a new stamped subdirectory and
    prune the history to the newest ``keep`` runs. Returns the new stamp."""
    date = date or time.strftime("%Y%m%dT%H%M%SZ", time.gmtime())
    sha = (sha or "unknown")[:12]
    stamp = f"{date}_{sha}"
    dst = os.path.join(history_dir, stamp)
    os.makedirs(dst, exist_ok=True)
    n = 0
    for path in sorted(glob.glob(os.path.join(cur_dir, "BENCH_*.json"))):
        shutil.copy(path, dst)
        n += 1
    if n == 0:
        print(f"warning: no BENCH_*.json in {cur_dir} to append",
              file=sys.stderr)
    stamps = sorted(d for d in os.listdir(history_dir)
                    if os.path.isdir(os.path.join(history_dir, d)))
    for old in stamps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(history_dir, old), ignore_errors=True)
    return stamp


def metric_series(history: list[dict], bench: str,
                  metric: str) -> list[tuple[str, float]]:
    """[(stamp, value)] for one metric across the history, skipping runs
    where the bench/metric is absent."""
    out = []
    for e in history:
        v = e["benches"].get(bench, {}).get("metrics", {}).get(metric)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            out.append((e["stamp"], float(v)))
    return out


# ---------------------------------------------------------------------------
# the committed-baseline gate
# ---------------------------------------------------------------------------

def check_against_baseline(cur: dict[str, dict], baseline_path: str,
                           threshold: float,
                           history: list[dict] | None = None) -> list[str]:
    """Returns a list of human-readable regression strings (empty = pass).

    Baseline entries: ``{bench: {metric: {"value": v, "better": "higher" |
    "lower"}}}``. A metric regresses when it moves more than ``threshold``
    (fractional) past the baseline in the *worse* direction; moves in the
    better direction never fail. A missing bench JSON or metric fails too.
    When ``history`` is given, each failure carries the metric's recorded
    trajectory so the regression is diagnosable from the CI log alone.
    """
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []

    def trail(bench: str, metric: str) -> str:
        if not history:
            return ""
        series = metric_series(history, bench, metric)[-8:]
        if not series:
            return ""
        steps = " -> ".join(f"{fmt(v)} @{s.split('_')[-1][:7]}"
                            for s, v in series)
        return f"\n      history({len(series)} runs): {steps}"

    for bench, metrics in sorted(baseline.items()):
        rec = cur.get(bench)
        if rec is None:
            failures.append(f"{bench}: no BENCH_{bench}.json produced")
            continue
        for metric, spec in sorted(metrics.items()):
            got = rec.get("metrics", {}).get(metric)
            if not isinstance(got, (int, float)) or isinstance(got, bool):
                failures.append(f"{bench}.{metric}: missing from the run"
                                + trail(bench, metric))
                continue
            base = float(spec["value"])
            higher_better = spec.get("better", "higher") == "higher"
            if base == 0:
                # a zero baseline can never flag anything — that's a broken
                # config, not a pass
                failures.append(f"{bench}.{metric}: baseline value is 0 "
                                "(check disabled — fix baselines.json)")
                continue
            change = (got - base) / abs(base)
            regression = -change if higher_better else change
            if regression > threshold:
                failures.append(
                    f"{bench}.{metric}: {fmt(got)} vs baseline {fmt(base)} "
                    f"({'-' if higher_better else '+'}{regression*100:.1f}% "
                    f"past the floor, allowed {threshold*100:.0f}%)"
                    + trail(bench, metric))
    return failures


def failed_metric_names(failures: list[str]) -> list[str]:
    """The ``bench.metric`` (or ``bench``) keys out of failure strings,
    for the machine-readable summary line."""
    names = []
    for f_ in failures:
        head = f_.split(":", 1)[0].strip()
        names.append(head)
    return names


# ---------------------------------------------------------------------------
# the static HTML dashboard (inline SVG, no JS libraries)
# ---------------------------------------------------------------------------

_DASH_CSS = """
:root { color-scheme: light dark; }
.viz-root {
  --surface-1: #fcfcfb; --page: #f9f9f7;
  --text-primary: #0b0b0b; --text-secondary: #52514e; --muted: #898781;
  --grid: #e1e0d9; --axis: #c3c2b7;
  --series-1: #2a78d6; --critical: #d03b3b;
  --border: rgba(11,11,11,0.10);
  background: var(--page); color: var(--text-primary);
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
  margin: 0; padding: 24px; min-height: 100vh;
}
@media (prefers-color-scheme: dark) {
  :root:where(:not([data-theme="light"])) .viz-root {
    --surface-1: #1a1a19; --page: #0d0d0d;
    --text-primary: #ffffff; --text-secondary: #c3c2b7; --muted: #898781;
    --grid: #2c2c2a; --axis: #383835;
    --series-1: #3987e5; --critical: #d03b3b;
    --border: rgba(255,255,255,0.10);
  }
}
:root[data-theme="dark"] .viz-root {
  --surface-1: #1a1a19; --page: #0d0d0d;
  --text-primary: #ffffff; --text-secondary: #c3c2b7; --muted: #898781;
  --grid: #2c2c2a; --axis: #383835;
  --series-1: #3987e5; --critical: #d03b3b;
  --border: rgba(255,255,255,0.10);
}
.viz-root h1 { font-size: 18px; margin: 0 0 4px; }
.viz-root p.sub { color: var(--text-secondary); font-size: 13px; margin: 0 0 20px; }
.viz-root h2 { font-size: 14px; margin: 24px 0 8px; }
.grid { display: flex; flex-wrap: wrap; gap: 16px; }
.card {
  background: var(--surface-1); border: 1px solid var(--border);
  border-radius: 8px; padding: 12px 14px 8px;
}
.card .name { font-size: 12px; color: var(--text-secondary); margin: 0 0 2px; }
.card .val { font-size: 16px; font-weight: 600; margin: 0 0 6px; }
.card .val.bad { color: var(--critical); }
.card svg text {
  font-family: inherit; font-size: 10px; fill: var(--muted);
  font-variant-numeric: tabular-nums;
}
.card svg text.last { fill: var(--text-primary); font-weight: 600; }
.card svg text.last.bad { fill: var(--critical); }
.card svg text.floor { fill: var(--muted); }
"""

_W, _H = 340, 120
_PAD_L, _PAD_R, _PAD_T, _PAD_B = 44, 54, 8, 18


def _svg_chart(series: list[tuple[str, float]], baseline: float | None,
               higher_better: bool, threshold: float) -> tuple[str, bool]:
    """One small-multiple line chart (inline SVG). Returns (svg, last point
    regressed?). Single series: no legend (the card title names it); the
    committed floor is a dashed reference line; the last value is
    direct-labeled; native ``<title>`` tooltips per point."""
    vals = [v for _, v in series]
    lo_candidates = vals + ([baseline] if baseline is not None else [])
    lo, hi = min(lo_candidates), max(lo_candidates)
    span = (hi - lo) or max(abs(hi), 1.0)
    lo, hi = lo - 0.1 * span, hi + 0.1 * span
    plot_w = _W - _PAD_L - _PAD_R
    plot_h = _H - _PAD_T - _PAD_B

    def x(i: int) -> float:
        n = max(len(series) - 1, 1)
        return _PAD_L + plot_w * (i / n if len(series) > 1 else 0.5)

    def y(v: float) -> float:
        return _PAD_T + plot_h * (1 - (v - lo) / (hi - lo))

    last_bad = False
    if baseline is not None and baseline != 0:
        change = (vals[-1] - baseline) / abs(baseline)
        regression = -change if higher_better else change
        last_bad = regression > threshold

    parts = [f'<svg viewBox="0 0 {_W} {_H}" width="{_W}" height="{_H}" '
             'role="img">']
    # recessive grid: 3 hairlines with y labels in muted ink
    for frac in (0.0, 0.5, 1.0):
        gy = _PAD_T + plot_h * frac
        gv = hi - (hi - lo) * frac
        parts.append(f'<line x1="{_PAD_L}" y1="{gy:.1f}" x2="{_W - _PAD_R}" '
                     f'y2="{gy:.1f}" stroke="var(--grid)" stroke-width="1"/>')
        parts.append(f'<text x="{_PAD_L - 4}" y="{gy + 3:.1f}" '
                     f'text-anchor="end">{html.escape(fmt(gv))}</text>')
    # the committed floor: dashed reference, labeled in muted ink
    if baseline is not None:
        by = y(baseline)
        parts.append(f'<line x1="{_PAD_L}" y1="{by:.1f}" x2="{_W - _PAD_R}" '
                     f'y2="{by:.1f}" stroke="var(--muted)" stroke-width="1" '
                     'stroke-dasharray="4 3"/>')
        parts.append(f'<text class="floor" x="{_W - _PAD_R + 4}" '
                     f'y="{by + 3:.1f}">floor</text>')
    # the series: 2px line + hoverable points
    pts = " ".join(f"{x(i):.1f},{y(v):.1f}" for i, (_, v) in enumerate(series))
    if len(series) > 1:
        parts.append(f'<polyline points="{pts}" fill="none" '
                     'stroke="var(--series-1)" stroke-width="2" '
                     'stroke-linejoin="round" stroke-linecap="round"/>')
    for i, (stamp, v) in enumerate(series):
        is_last = i == len(series) - 1
        color = ("var(--critical)" if (is_last and last_bad)
                 else "var(--series-1)")
        r = 4 if is_last else 3
        parts.append(
            f'<circle cx="{x(i):.1f}" cy="{y(v):.1f}" r="{r}" '
            f'fill="{color}" stroke="var(--surface-1)" stroke-width="2">'
            f'<title>{html.escape(stamp)}: {html.escape(fmt(v))}</title>'
            '</circle>')
    # direct label on the last point (text wears ink, not series color —
    # unless it marks a regression, which is a status, not a series)
    lx, ly = x(len(series) - 1), y(vals[-1])
    cls = "last bad" if last_bad else "last"
    parts.append(f'<text class="{cls}" x="{min(lx + 7, _W - 2):.1f}" '
                 f'y="{ly + 3:.1f}">{html.escape(fmt(vals[-1]))}</text>')
    # x extent labels: first/last run stamp (sha short)
    def stamp_label(s: str) -> str:
        return s.split("_")[-1][:7]
    parts.append(f'<text x="{_PAD_L}" y="{_H - 4}">'
                 f'{html.escape(stamp_label(series[0][0]))}</text>')
    if len(series) > 1:
        parts.append(f'<text x="{_W - _PAD_R}" y="{_H - 4}" '
                     'text-anchor="end">'
                     f'{html.escape(stamp_label(series[-1][0]))}</text>')
    parts.append("</svg>")
    return "".join(parts), last_bad


#: critical-path stage order for the trace panel (mirrors traceview.PATH_STAGES
#: without importing repro — trend.py must run from a bare artifacts checkout)
_TRACE_STAGES = ("uplink", "wan", "lb", "fabric", "downlink",
                 "farm_wait", "service", "reassembly")


def render_trace_panel(summary: dict) -> str:
    """Per-stage latency waterfall cards from a trace summary JSON
    (``run_simnet.py --trace-summary-json`` / ``analyze_trace.py
    --summary-json``): one card per exported percentile, horizontal bars
    sized by the stage's share of the percentile bundle's E2E, the
    dominant stage direct-labeled. Feeds ``--html`` via
    ``--trace-summary``."""
    breakdown = summary.get("breakdown", summary)
    pcts = breakdown.get("percentiles", {})
    if not pcts:
        return ""
    cards = []
    bar_w, bar_h, lab_w = 210, 13, 78
    for pname, d in sorted(pcts.items(),
                           key=lambda kv: float(kv[0].lstrip("p"))):
        stages = d.get("stages", {})
        e2e = float(d.get("e2e_s", 0.0)) or 1.0
        rows = [(s, float(stages[s])) for s in _TRACE_STAGES if s in stages]
        rows += sorted((s, float(v)) for s, v in stages.items()
                       if s not in _TRACE_STAGES)
        h = bar_h * len(rows) + 16
        parts = [f'<svg viewBox="0 0 {lab_w + bar_w + 52} {h}" '
                 f'width="{lab_w + bar_w + 52}" height="{h}" role="img">']
        for i, (s, dur) in enumerate(rows):
            y0 = i * bar_h + 2
            frac = max(min(dur / e2e, 1.0), 0.0)
            color = ("var(--critical)" if s == d.get("dominant")
                     else "var(--series-1)")
            parts.append(f'<text x="{lab_w - 4}" y="{y0 + 9}" '
                         f'text-anchor="end">{html.escape(s)}</text>')
            parts.append(f'<rect x="{lab_w}" y="{y0}" '
                         f'width="{max(frac * bar_w, 1):.1f}" height="10" '
                         f'fill="{color}" rx="1">'
                         f'<title>{html.escape(s)}: {dur * 1e3:.4f}ms '
                         f'({frac * 100:.1f}% of e2e)</title></rect>')
            parts.append(f'<text x="{lab_w + max(frac * bar_w, 1) + 4:.1f}" '
                         f'y="{y0 + 9}">{dur * 1e3:.3f}ms</text>')
        parts.append("</svg>")
        tid = d.get("trace_id", "")
        cards.append(
            f'<div class="card"><p class="name">{html.escape(pname)} '
            f'stage waterfall · bundle {html.escape(str(tid))}</p>'
            f'<p class="val">{e2e * 1e3:,.3f}ms e2e · dominant '
            f'{html.escape(str(d.get("dominant", "?")))}</p>'
            f'{"".join(parts)}</div>')
    meta = (f"{summary.get('windows', '?')} windows · "
            f"{breakdown.get('n_spans', summary.get('n_spans', '?'))} spans · "
            f"{breakdown.get('n_completions', '?')} completed bundles")
    return (f"<h2>trace: per-stage critical path</h2>"
            f'<p class="sub">{html.escape(meta)}</p>'
            f'<div class="grid">{"".join(cards)}</div>')


def render_html(cur: dict[str, dict], history: list[dict],
                baseline_path: str | None, threshold: float,
                cur_stamp: str = "current", extra_html: str = "") -> str:
    """The dashboard: one small-multiple card per bench metric, history
    series against the committed floor. ``cur`` is appended as the newest
    point when it is not already the history's tail."""
    baseline = {}
    if baseline_path and os.path.exists(baseline_path):
        with open(baseline_path) as f:
            baseline = json.load(f)

    entries = list(history)
    if cur:
        tail = entries[-1]["benches"] if entries else None
        if tail != cur:
            entries = entries + [{"stamp": cur_stamp, "sha": cur_stamp,
                                  "date": "", "benches": cur}]

    # every (bench, metric) seen anywhere, baselined metrics first
    keys: list[tuple[str, str]] = []
    for bench in sorted(baseline):
        for metric in sorted(baseline[bench]):
            keys.append((bench, metric))
    for e in entries:
        for bench, rec in sorted(e["benches"].items()):
            for metric, v in sorted(rec.get("metrics", {}).items()):
                if (isinstance(v, (int, float)) and not isinstance(v, bool)
                        and (bench, metric) not in keys):
                    keys.append((bench, metric))

    n_runs = len(entries)
    cards_by_bench: dict[str, list[str]] = {}
    n_bad = 0
    for bench, metric in keys:
        series = metric_series(entries, bench, metric)
        if not series:
            continue
        spec = baseline.get(bench, {}).get(metric)
        base = float(spec["value"]) if spec else None
        higher = (spec or {}).get("better", "higher") == "higher"
        svg, bad = _svg_chart(series, base, higher, threshold)
        n_bad += bad
        val_cls = "val bad" if bad else "val"
        card = (f'<div class="card"><p class="name">{html.escape(metric)}'
                '</p>'
                f'<p class="{val_cls}">{html.escape(fmt(series[-1][1]))}</p>'
                f'{svg}</div>')
        cards_by_bench.setdefault(bench, []).append(card)

    sections = []
    for bench, cards in cards_by_bench.items():
        sections.append(f"<h2>{html.escape(bench)}</h2>"
                        f'<div class="grid">{"".join(cards)}</div>')
    status = (f"{n_bad} metric(s) past the committed floor" if n_bad
              else "all tracked metrics within the committed floors")
    return (
        "<!DOCTYPE html>\n<html><head><meta charset=\"utf-8\">"
        "<title>bench trend dashboard</title>"
        f"<style>{_DASH_CSS}</style></head>"
        '<body class="viz-root">'
        "<h1>Bench trend dashboard</h1>"
        f'<p class="sub">{n_runs} run(s) · threshold '
        f"{threshold * 100:.0f}% · {html.escape(status)} · dashed line = "
        "committed baseline floor</p>"
        f'{"".join(sections)}'
        f"{extra_html}"
        "</body></html>\n")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("cur_dir", nargs="?", default=".")
    ap.add_argument("prev_dir", nargs="?", default=None)
    ap.add_argument("--check", default=None, metavar="BASELINES_JSON",
                    help="fail on >threshold regressions vs this baseline")
    ap.add_argument("--threshold", type=float, default=0.2)
    ap.add_argument("--history", default=None, metavar="DIR",
                    help="rolling bench-history directory (stamped "
                         "subdirectories of BENCH_*.json)")
    ap.add_argument("--append-history", default=None, metavar="DIR",
                    help="append cur_dir's BENCH_*.json to this history "
                         "directory as a stamped run, then prune")
    ap.add_argument("--sha", default="",
                    help="commit SHA stamped onto --append-history runs")
    ap.add_argument("--date", default=None,
                    help="UTC stamp override for --append-history "
                         "(default: now, %%Y%%m%%dT%%H%%M%%SZ)")
    ap.add_argument("--keep", type=int, default=60,
                    help="history runs to keep when appending")
    ap.add_argument("--html", default=None, metavar="OUT",
                    help="render the static dashboard here")
    ap.add_argument("--trace-summary", default=None, metavar="JSON",
                    help="trace summary JSON (run_simnet.py "
                         "--trace-summary-json) rendered as a per-stage "
                         "p50/p99 waterfall panel in the --html dashboard")
    args = ap.parse_args(argv)

    cur = load_dir(args.cur_dir)
    prev = load_dir(args.prev_dir) if args.prev_dir else {}
    if not cur:
        print(f"no BENCH_*.json under {args.cur_dir}")
        return 1

    if args.append_history:
        stamp = append_history(args.cur_dir, args.append_history, args.sha,
                               date=args.date, keep=args.keep)
        print(f"appended history run {stamp} -> {args.append_history}")

    history = load_history(args.history or args.append_history)

    rows = []
    for bench, rec in sorted(cur.items()):
        for metric, value in sorted(rec.get("metrics", {}).items()):
            if not isinstance(value, (int, float)) or isinstance(value, bool):
                continue
            delta = ""
            pv = prev.get(bench, {}).get("metrics", {}).get(metric)
            if isinstance(pv, (int, float)) and pv:
                delta = f"{(value - pv) / abs(pv) * 100:+.1f}%"
            rows.append((bench, metric, fmt(value), delta))
    w0 = max(len(r[0]) for r in rows)
    w1 = max(len(r[1]) for r in rows)
    w2 = max(len(r[2]) for r in rows)
    print(f"{'bench':<{w0}}  {'metric':<{w1}}  {'value':>{w2}}  trend")
    print("-" * (w0 + w1 + w2 + 12))
    for b, m, v, d in rows:
        print(f"{b:<{w0}}  {m:<{w1}}  {v:>{w2}}  {d}")

    if args.html:
        trace_html = ""
        if args.trace_summary:
            try:
                with open(args.trace_summary) as f:
                    trace_html = render_trace_panel(json.load(f))
            except (OSError, json.JSONDecodeError) as e:
                print(f"warning: skipping --trace-summary "
                      f"{args.trace_summary}: {e}", file=sys.stderr)
        doc = render_html(cur, history, args.check, args.threshold,
                          extra_html=trace_html)
        with open(args.html, "w") as f:
            f.write(doc)
        print(f"dashboard -> {args.html} "
              f"({len(history)} history run(s) + current)")

    if args.check:
        failures = check_against_baseline(cur, args.check, args.threshold,
                                          history=history)
        if failures:
            print("\nREGRESSIONS vs committed baseline:", file=sys.stderr)
            for f_ in failures:
                print(f"  {f_}", file=sys.stderr)
            names = ",".join(failed_metric_names(failures))
            print(f"TREND-CHECK: FAIL n={len(failures)} metrics={names}")
            return 1
        print(f"\nbaseline check OK ({args.check}, "
              f"threshold {args.threshold*100:.0f}%)")
        print(f"TREND-CHECK: OK benches={len(cur)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
