"""Render the BENCH_*.json artifacts as a trend table, and gate regressions.

Each bench emits ``BENCH_<name>.json`` (benchmarks/common.emit_json). CI
uploads them as workflow artifacts, so the run-over-run trajectory lives in
the artifact history; this script prints one directory's snapshot — or, given
several directories (e.g. a previous run's downloaded artifacts next to the
current ones), a side-by-side table with the relative change.

    python -m benchmarks.trend bench-out [previous-bench-out]

``--check`` compares the snapshot against the *committed* baseline
(``benchmarks/baselines/baselines.json``: curated metrics with explicit
better-direction and conservative floor/ceiling values — see the README
there) and exits non-zero if any checked metric regresses more than
``--threshold`` (default 20%) past its baseline, or if a baselined bench
didn't produce a JSON at all (a silently vanished bench is a regression):

    python -m benchmarks.trend bench-out --check benchmarks/baselines/baselines.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_dir(d: str) -> dict[str, dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(d, "BENCH_*.json"))):
        try:
            with open(path) as f:
                rec = json.load(f)
            out[rec.get("bench", os.path.basename(path))] = rec
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping {path}: {e}", file=sys.stderr)
    return out


def fmt(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:,.2f}" if abs(v) >= 0.01 else f"{v:.3g}"
    return str(v)


def check_against_baseline(cur: dict[str, dict], baseline_path: str,
                           threshold: float) -> list[str]:
    """Returns a list of human-readable regression strings (empty = pass).

    Baseline entries: ``{bench: {metric: {"value": v, "better": "higher" |
    "lower"}}}``. A metric regresses when it moves more than ``threshold``
    (fractional) past the baseline in the *worse* direction; moves in the
    better direction never fail. A missing bench JSON or metric fails too.
    """
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []
    for bench, metrics in sorted(baseline.items()):
        rec = cur.get(bench)
        if rec is None:
            failures.append(f"{bench}: no BENCH_{bench}.json produced")
            continue
        for metric, spec in sorted(metrics.items()):
            got = rec.get("metrics", {}).get(metric)
            if not isinstance(got, (int, float)) or isinstance(got, bool):
                failures.append(f"{bench}.{metric}: missing from the run")
                continue
            base = float(spec["value"])
            higher_better = spec.get("better", "higher") == "higher"
            if base == 0:
                # a zero baseline can never flag anything — that's a broken
                # config, not a pass
                failures.append(f"{bench}.{metric}: baseline value is 0 "
                                "(check disabled — fix baselines.json)")
                continue
            change = (got - base) / abs(base)
            regression = -change if higher_better else change
            if regression > threshold:
                failures.append(
                    f"{bench}.{metric}: {fmt(got)} vs baseline {fmt(base)} "
                    f"({'-' if higher_better else '+'}{regression*100:.1f}%, "
                    f"allowed {threshold*100:.0f}%)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("cur_dir", nargs="?", default=".")
    ap.add_argument("prev_dir", nargs="?", default=None)
    ap.add_argument("--check", default=None, metavar="BASELINES_JSON",
                    help="fail on >threshold regressions vs this baseline")
    ap.add_argument("--threshold", type=float, default=0.2)
    args = ap.parse_args(argv)
    cur = load_dir(args.cur_dir)
    prev = load_dir(args.prev_dir) if args.prev_dir else {}
    if not cur:
        print(f"no BENCH_*.json under {args.cur_dir}")
        return 1
    rows = []
    for bench, rec in sorted(cur.items()):
        for metric, value in sorted(rec.get("metrics", {}).items()):
            if not isinstance(value, (int, float)) or isinstance(value, bool):
                continue
            delta = ""
            pv = prev.get(bench, {}).get("metrics", {}).get(metric)
            if isinstance(pv, (int, float)) and pv:
                delta = f"{(value - pv) / abs(pv) * 100:+.1f}%"
            rows.append((bench, metric, fmt(value), delta))
    w0 = max(len(r[0]) for r in rows)
    w1 = max(len(r[1]) for r in rows)
    w2 = max(len(r[2]) for r in rows)
    print(f"{'bench':<{w0}}  {'metric':<{w1}}  {'value':>{w2}}  trend")
    print("-" * (w0 + w1 + w2 + 12))
    for b, m, v, d in rows:
        print(f"{b:<{w0}}  {m:<{w1}}  {v:>{w2}}  {d}")

    if args.check:
        failures = check_against_baseline(cur, args.check, args.threshold)
        if failures:
            print("\nREGRESSIONS vs committed baseline:", file=sys.stderr)
            for f_ in failures:
                print(f"  {f_}", file=sys.stderr)
            return 1
        print(f"\nbaseline check OK ({args.check}, "
              f"threshold {args.threshold*100:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
