"""Shared benchmark utilities. Every bench prints ``name,us_per_call,derived``
CSV rows (derived = the paper-comparable figure)."""
from __future__ import annotations

import time


def timeit(fn, *, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
