"""Shared benchmark utilities. Every bench prints ``name,us_per_call,derived``
CSV rows (derived = the paper-comparable figure) and emits a machine-readable
``BENCH_<name>.json`` next to them (``emit_json``) so CI can upload the whole
set as workflow artifacts and track the trend run over run.
"""
from __future__ import annotations

import json
import os
import time


def timeit(fn, *, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line


def emit_json(bench: str, metrics: dict, params: dict | None = None) -> str:
    """Write ``BENCH_<bench>.json`` into ``$BENCH_DIR`` (default: CWD).

    ``metrics`` holds the paper-comparable figures (ops/s, speedups, …);
    ``params`` the workload shape that produced them. CI uploads these as
    artifacts and ``benchmarks/trend.py`` renders the table.
    """
    out_dir = os.environ.get("BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    payload = {
        "bench": bench,
        "unix_time": round(time.time(), 1),
        "metrics": metrics,
        "params": params or {},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    return path
