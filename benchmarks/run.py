"""Benchmark harness — one bench per paper table/figure (+ the roofline
table from the dry-run artifacts). Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_controld, bench_dispatch,
                            bench_epoch_switch, bench_fabric, bench_fairness,
                            bench_ha, bench_ingest, bench_metrics,
                            bench_reassembly, bench_route_throughput,
                            bench_roofline, bench_simnet, bench_trace)

    print("name,us_per_call,derived")
    failed = []
    for mod in (bench_route_throughput, bench_epoch_switch, bench_fairness,
                bench_reassembly, bench_ingest, bench_dispatch,
                bench_simnet, bench_fabric, bench_controld, bench_ha,
                bench_metrics, bench_trace, bench_roofline):
        try:
            mod.run()
        except Exception:  # pragma: no cover
            failed.append(mod.__name__)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
