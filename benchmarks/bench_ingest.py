"""Table: ingest-path throughput — batched segmentation + reassembly vs the
per-packet host loop (paper §II-C; DESIGN.md §Ingest).

Workload: 4096 events x 8 segments each. The per-packet baseline is the
reference path (``segment_bundle`` objects + dict-buffer ``Reassembler``);
the batched path is one ``segment_bundles`` array pass + one sort-based
``BatchReassembler.push_batch`` per window. Acceptance bar (CI-gated
alongside the dispatch gate): batched >= 5x the host loop end to end. Also
reports the vectorized WAN hop (masked gather/permutation over the whole
batch) which has no per-packet equivalent timing-wise.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit_json, row
from repro.data.daq import EventBundle
from repro.data.reassembly import BatchReassembler
from repro.data.segmentation import Reassembler, segment_bundle, segment_bundles
from repro.data.transport import TransportConfig, WANTransport

N_EVENTS = 4096
N_SEGS = 8
MTU_PAYLOAD = 512
N_DAQS = 2  # events split across DAQs; every bundle still N_SEGS segments


def _bundles() -> list[EventBundle]:
    rng = np.random.default_rng(7)
    nbytes = N_SEGS * MTU_PAYLOAD  # exactly N_SEGS full segments
    payload = rng.integers(0, 256, (N_EVENTS, nbytes)).astype(np.uint8)
    evs = np.cumsum(rng.integers(1, 7, N_EVENTS))
    ents = rng.integers(0, 1 << 16, N_EVENTS)
    return [
        EventBundle(int(evs[i]), int(i % N_DAQS), int(ents[i]), payload[i])
        for i in range(N_EVENTS)
    ]


def _best_of(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    bundles = _bundles()
    n_packets = N_EVENTS * N_SEGS

    # -- per-packet host loop (reference baseline) ----------------------------
    def loop_path():
        segs = []
        for b in bundles:
            segs.extend(segment_bundle(b, MTU_PAYLOAD))
        ra = Reassembler()
        for s in segs:
            ra.push(s)
        assert len(ra.completed) == N_EVENTS

    dt_loop = _best_of(loop_path)
    row("ingest_perpacket_loop", dt_loop * 1e6 / n_packets,
        f"{n_packets/dt_loop:.0f} seg/s host loop "
        f"({N_EVENTS} events x {N_SEGS} segs)")

    # -- batched path ---------------------------------------------------------
    def batched_path():
        bra = BatchReassembler(MTU_PAYLOAD)
        done = bra.push_batch(segment_bundles(bundles, MTU_PAYLOAD))
        assert len(done) == N_EVENTS

    dt_batch = _best_of(batched_path)
    speedup = dt_loop / max(dt_batch, 1e-12)
    row("ingest_batched", dt_batch * 1e6 / n_packets,
        f"{n_packets/dt_batch:.0f} seg/s = {speedup:.2f}x per-packet loop "
        f"(want >= 5x)")

    # -- vectorized WAN hop ---------------------------------------------------
    batch = segment_bundles(bundles, MTU_PAYLOAD)
    wan = WANTransport(TransportConfig(reorder_window=64, loss_prob=0.01,
                                       duplicate_prob=0.01, seed=7))
    wan.deliver_batch(batch)  # warm
    t0 = time.perf_counter()
    out = wan.deliver_batch(batch)
    dt_wan = time.perf_counter() - t0
    row("ingest_wan_batch", dt_wan * 1e6 / n_packets,
        f"{n_packets/dt_wan:.0f} seg/s loss/dup/reorder as one permutation "
        f"({len(out)} delivered)")

    emit_json("ingest", metrics={
        "perpacket_seg_per_s": n_packets / dt_loop,
        "batched_seg_per_s": n_packets / dt_batch,
        "wan_seg_per_s": n_packets / dt_wan,
        "speedup_batched_vs_loop": speedup,
    }, params={
        "n_events": N_EVENTS, "n_segs": N_SEGS,
        "mtu_payload": MTU_PAYLOAD, "n_daqs": N_DAQS,
    })
    return speedup


if __name__ == "__main__":
    print(f"speedup: {run():.2f}x")
