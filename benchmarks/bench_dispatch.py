"""Table: dispatch-plan throughput (the TPU-side hot path: per-packet buffer
positions + scatter) — this is the ingest path of every training step and
the MoE dispatch.

Compares the data plane's sort-based pack (argsort by member +
segment-offset subtraction, O(N log N)) against the historical
one-hot-cumsum baseline (O(N*M)) at N=8192 packets, M=64 members, plus the
Pallas plan kernel (interpret mode = CPU functional model). Acceptance bar:
sort-based >= 2x the one-hot baseline on CPU (DESIGN.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json, row, timeit
from repro.core.dataplane import DataPlane

N, M, CAP = 8192, 64, 512


def _onehot_baseline(member, n_members: int):
    """The pre-refactor cumsum-of-one-hot plan (kept here as the baseline)."""
    onehot = jax.nn.one_hot(member, n_members, dtype=jnp.int32)  # [N, M]
    pos_in_member = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(pos_in_member * onehot, axis=-1)
    counts = jnp.sum(onehot, axis=0)
    pos = jnp.where(member >= 0, pos, -1)
    return pos, counts


def run():
    rng = np.random.default_rng(0)
    member = jnp.asarray(rng.integers(0, M, N).astype(np.int32))
    payload = jnp.asarray(rng.normal(size=(N, 64)).astype(np.float32))

    baseline = jax.jit(lambda mm: _onehot_baseline(mm, M))
    jax.block_until_ready(baseline(member))
    us_base = timeit(lambda: jax.block_until_ready(baseline(member)))
    row("dispatch_plan_onehot_baseline", us_base,
        f"{N/(us_base/1e6)/1e6:.2f} M-events/s (O(N*M) cumsum-of-one-hot)")

    from repro.kernels import ref

    plan_sort = jax.jit(lambda mm: ref.dispatch_plan_ref(mm, n_members=M))
    jax.block_until_ready(plan_sort(member))
    us_sort = timeit(lambda: jax.block_until_ready(plan_sort(member)))
    speedup = us_base / max(us_sort, 1e-9)
    row("dispatch_plan_sort_jnp_xla", us_sort,
        f"{N/(us_sort/1e6)/1e6:.2f} M-events/s = {speedup:.2f}x one-hot baseline "
        f"(want >= 2x)")

    from repro.core.dataplane import combine_payloads

    combine = jax.jit(lambda p, mm, pos: combine_payloads(
        p, mm, pos, n_members=M, capacity=CAP))
    pos, _ = plan_sort(member)
    jax.block_until_ready(combine(payload, member, pos))
    us2 = timeit(lambda: jax.block_until_ready(combine(payload, member, pos)))
    gb = payload.size * 4 / 1e9
    row("dispatch_combine_scatter", us2,
        f"{gb/(us2/1e6):.2f} GB/s payload scatter")

    from repro.core import EpochManager, MemberSpec

    em = EpochManager(max_members=M)
    em.initialize({i: MemberSpec(node_id=i) for i in range(M)},
                  {i: 1.0 for i in range(M)})
    dpp = DataPlane.from_manager(em, backend="pallas", interpret=True)
    us3 = timeit(lambda: jax.block_until_ready(dpp.plan(member, M)), iters=3)
    row("dispatch_plan_pallas_interpret", us3,
        f"{N/(us3/1e6)/1e6:.3f} M-events/s (functional model)")
    emit_json("dispatch", metrics={
        "onehot_mevents_per_s": N / us_base,
        "sort_mevents_per_s": N / us_sort,
        "speedup_sort_vs_onehot": speedup,
        "combine_gb_per_s": gb / (us2 / 1e6),
        "pallas_interpret_mevents_per_s": N / us3,
    }, params={"n": N, "m": M, "capacity": CAP})
    return speedup


if __name__ == "__main__":
    run()
