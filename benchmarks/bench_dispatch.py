"""Table: dispatch-plan throughput (the TPU-side hot path: cumsum-of-one-hot
positions + scatter), jnp/XLA vs Pallas interpret — this is the ingest path
of every training step and the MoE dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.kernels import ops, ref


def run():
    rng = np.random.default_rng(0)
    n, m, cap = 8192, 32, 512
    member = jnp.asarray(rng.integers(0, m, n).astype(np.int32))
    payload = jnp.asarray(rng.normal(size=(n, 64)).astype(np.float32))

    plan_ref = jax.jit(lambda mm: ref.dispatch_plan_ref(mm, n_members=m))
    jax.block_until_ready(plan_ref(member))
    us = timeit(lambda: jax.block_until_ready(plan_ref(member)))
    row("dispatch_plan_jnp_xla", us, f"{n/(us/1e6)/1e6:.2f} M-events/s")

    combine = jax.jit(lambda p, mm, pos: ops.combine_payloads(
        p, mm, pos, n_members=m, capacity=cap))
    pos, _ = plan_ref(member)
    jax.block_until_ready(combine(payload, member, pos))
    us2 = timeit(lambda: jax.block_until_ready(combine(payload, member, pos)))
    gb = payload.size * 4 / 1e9
    row("dispatch_combine_scatter", us2,
        f"{gb/(us2/1e6):.2f} GB/s payload scatter")

    us3 = timeit(lambda: jax.block_until_ready(
        ops.plan_dispatch(member, m, use_pallas=True, interpret=True)), iters=3)
    row("dispatch_plan_pallas_interpret", us3,
        f"{n/(us3/1e6)/1e6:.3f} M-events/s (functional model)")


if __name__ == "__main__":
    run()
