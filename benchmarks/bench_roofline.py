"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline source).
Prints one CSV row per compiled (arch x shape x mesh) cell; us_per_call is
the projected step time (max of the three terms) in microseconds."""
from __future__ import annotations

import os

from benchmarks.common import emit_json, row
from repro.analysis import roofline as RL

ART_DIR = os.environ.get("DRYRUN_ARTIFACTS", "artifacts/dryrun")


def run():
    if not os.path.isdir(ART_DIR):
        row("roofline", 0.0, f"no artifacts under {ART_DIR}; run "
            "`python -m repro.launch.dryrun --all --mesh both` first")
        emit_json("roofline", metrics={"n_cells": 0},
                  params={"artifacts_dir": ART_DIR})
        return
    arts = [a for a in RL.load_artifacts(ART_DIR) if "skipped" not in a]
    cells = {}
    for a in sorted(arts, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        r = RL.analyze(a)
        name = f"roofline_{r.arch}_{r.shape}_{r.mesh}"
        if a.get("variant", "baseline") != "baseline":
            name += f"_{a['variant']}"
        row(name, r.step_time_s * 1e6,
            f"bottleneck={r.bottleneck} util={r.hw_utilization:.3f} "
            f"compute_s={r.compute_s:.4g} memory_s={r.memory_s:.4g} "
            f"collective_s={r.collective_s:.4g}")
        cells[name] = {"step_time_us": r.step_time_s * 1e6,
                       "bottleneck": r.bottleneck,
                       "utilization": r.hw_utilization}
    emit_json("roofline", metrics={"n_cells": len(cells), **cells},
              params={"artifacts_dir": ART_DIR})


if __name__ == "__main__":
    run()
