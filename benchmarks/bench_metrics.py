"""Metrics-registry cost: instrumentation must be ~free on the hot path.

Two claims are gated:

* the registry primitives themselves are cheap (counter inc, pre-resolved
  labeled inc, histogram observe, vectorized ``observe_many``, full-page
  ``render``);
* wiring a live ``MetricsRegistry`` into controld adds **< 5%** to the hot
  batched-heartbeat path (``SendStateBatch``, M=1024) vs the identical
  daemon with ``metrics=None`` — the per-batch instrumentation discipline
  (one counter add + one histogram observe per *window*, never per member)
  is what makes this hold;
* metrics emission no longer forces the host engine: the fused superblock's
  returned arrays feed the same per-window emission path, and the
  ``fused_metrics_overhead_pct`` lane gates that cost vs the bare fused
  loop (same <5% discipline, no retrace).

CI gates ``instrumented_overhead_pct`` via trend.py against the committed
baseline ceiling.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit_json, row, timeit
from repro.controld import ControlDaemon, ControldClient, InProcTransport
from repro.telemetry.registry import (LATENCY_BUCKETS_S, MetricsRegistry)

M_BATCH = 1024   # batched-window lane width (matches bench_controld)
N_SERIES = 64    # labeled children on the render page
N_OBS = 1024     # observe_many vector width


def _make_daemon(metrics: MetricsRegistry | None):
    daemon = ControlDaemon(n_instances=1, lease_s=1e9, epoch_horizon=256,
                           max_members=M_BATCH, journal=None,
                           metrics=metrics)
    client = ControldClient(InProcTransport(daemon))
    token = client.reserve(policy="pid")["token"]
    for m in range(M_BATCH):
        client.register(token, member_id=m, node_id=m, lane_bits=1)
    return client, token


def run() -> dict:
    # -- registry primitives --------------------------------------------------
    reg = MetricsRegistry()
    c = reg.counter("bench_ops_total", "ops")
    us = timeit(lambda: [c.inc() for _ in range(1000)], warmup=2, iters=20)
    inc_rate = 1000 / us * 1e6
    row("metrics_counter_inc", us / 1000, f"{inc_rate:,.0f} inc/s (unlabeled)")

    fam = reg.counter("bench_labeled_total", "ops", labelnames=("kind",))
    children = [fam.labels(kind=f"k{i}") for i in range(8)]
    us = timeit(lambda: [ch.inc() for ch in children * 125],
                warmup=2, iters=20)
    labeled_rate = 1000 / us * 1e6
    row("metrics_labeled_inc", us / 1000,
        f"{labeled_rate:,.0f} inc/s (pre-resolved children)")

    h = reg.histogram("bench_lat_seconds", "lat", buckets=LATENCY_BUCKETS_S)
    us = timeit(lambda: [h.observe(1e-4) for _ in range(1000)],
                warmup=2, iters=20)
    obs_rate = 1000 / us * 1e6
    row("metrics_observe", us / 1000, f"{obs_rate:,.0f} observe/s (bisect)")

    vals = np.abs(np.random.default_rng(0).normal(1e-3, 5e-4, N_OBS))
    us = timeit(lambda: h.observe_many(vals), warmup=2, iters=50)
    many_rate = N_OBS / us * 1e6
    row("metrics_observe_many", us / N_OBS,
        f"{many_rate:,.0f} samples/s vectorized ({N_OBS}/call)")

    g = reg.gauge("bench_series", "series", labelnames=("i",))
    for i in range(N_SERIES):
        g.labels(i=str(i)).set(float(i))
    us = timeit(lambda: reg.render(), warmup=2, iters=20)
    page_us = us
    row("metrics_render", us,
        f"full text page, {N_SERIES}+ series in {us:.0f}us")

    # -- the <5% claim: batched heartbeats, instrumented vs bare --------------
    ids = list(range(M_BATCH))
    fills = [0.25 + 0.05 * (m % 16) for m in ids]

    client0, token0 = _make_daemon(metrics=None)
    us_bare = timeit(lambda: client0.send_state_batch(token0, ids, fills),
                     warmup=5, iters=40)
    row("metrics_hb_bare", us_bare / M_BATCH,
        f"{M_BATCH / us_bare * 1e6:,.0f} hb/s, metrics=None")

    client1, token1 = _make_daemon(metrics=MetricsRegistry())
    us_inst = timeit(lambda: client1.send_state_batch(token1, ids, fills),
                     warmup=5, iters=40)
    overhead_pct = (us_inst - us_bare) / us_bare * 100.0
    row("metrics_hb_instrumented", us_inst / M_BATCH,
        f"{M_BATCH / us_inst * 1e6:,.0f} hb/s live registry "
        f"({overhead_pct:+.2f}% vs bare)")

    # -- metrics on the fused engine: emission from returned arrays -----------
    from repro.simnet import SimConfig, Simulator
    loop_kw = dict(triggers_per_step=64, n_daqs=4, n_members=16,
                   mean_bundle_bytes=12_000, engine="fused")

    def _loop(metrics_every: int) -> None:
        cfg = SimConfig(steps=40, metrics_every=metrics_every, **loop_kw)
        r = Simulator(cfg).run()
        assert not r.violations, r.violations
        assert r.engine == "fused", r.engine

    us_loop_bare = timeit(lambda: _loop(0), warmup=2, iters=7)
    us_loop_inst = timeit(lambda: _loop(1), warmup=2, iters=7)
    fused_overhead_pct = (us_loop_inst - us_loop_bare) / us_loop_bare * 100.0
    row("metrics_fused_loop", us_loop_inst,
        f"40-window fused loop, registry row every window "
        f"({fused_overhead_pct:+.2f}% vs bare fused)")

    emit_json("metrics", metrics={
        "counter_incs_per_s": inc_rate,
        "labeled_incs_per_s": labeled_rate,
        "observes_per_s": obs_rate,
        "observe_many_samples_per_s": many_rate,
        "render_page_us": page_us,
        "instrumented_overhead_pct": overhead_pct,
        "fused_metrics_overhead_pct": fused_overhead_pct,
    }, params={"m_batch": M_BATCH, "n_series": N_SERIES, "n_obs": N_OBS,
               "fused_loop": {"steps": 40, **loop_kw}})
    return {"instrumented_overhead_pct": overhead_pct,
            "fused_metrics_overhead_pct": fused_overhead_pct}


if __name__ == "__main__":
    run()
