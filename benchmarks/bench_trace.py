"""Per-bundle tracing cost: the flight recorder must be ~free when on.

Three claims are gated:

* ``record_window`` is vectorized — one call per stage per window appends
  thousands of spans at array speed (no per-packet Python);
* turning tracing ON for the full closed loop (fused engine, every stage
  recorded, spans materialized host-side from the superblock's returned
  arrays) costs **< 5%** wall time vs the identical untraced run — and
  does not add a single retrace (``FUSED_TRACES`` delta stays 0 between
  the untraced and traced legs: the donated program is byte-identical);
* Perfetto export renders the whole buffer at millions of events/sec.

CI gates ``trace_overhead_pct`` via trend.py against the committed
baseline ceiling.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit_json, row, timeit
from repro.simnet import SimConfig, Simulator
from repro.telemetry.trace import TraceBuffer, TraceConfig

N_SPANS = 16_384     # spans per record_window call
LOOP_KW = dict(triggers_per_step=64, n_daqs=4, n_members=16,
               mean_bundle_bytes=12_000, engine="fused")


def _record_bench() -> float:
    tb = TraceBuffer(TraceConfig(head_rate=1.0, tail_k=64, seed=0))
    keys = np.arange(N_SPANS, dtype=np.uint64)
    t0 = np.linspace(0.0, 1.0, N_SPANS)
    t1 = t0 + 1e-3
    pid = np.arange(N_SPANS, dtype=np.uint64)

    def one() -> None:
        tb.record_window("uplink", keys, t0, t1, pid=pid)
        tb.end_window()

    return timeit(one, warmup=3, iters=30)


def _closed_loop(trace: bool) -> float:
    """Median wall us for a 40-window fused run, traced or not."""
    def one() -> None:
        cfg = SimConfig(steps=40, trace=trace, **LOOP_KW)
        r = Simulator(cfg).run()
        assert not r.violations, r.violations
        assert r.engine == "fused", r.engine

    return timeit(one, warmup=2, iters=7)


def run() -> dict:
    us_rec = _record_bench()
    rec_rate = N_SPANS / us_rec * 1e6
    row("trace_record_window", us_rec / N_SPANS,
        f"{rec_rate:,.0f} spans/s appended ({N_SPANS}/call, SoA)")

    from repro.simnet import fused
    traces0 = fused.FUSED_TRACES
    us_bare = _closed_loop(trace=False)
    traces_bare = fused.FUSED_TRACES - traces0
    us_traced = _closed_loop(trace=True)
    traces_on = fused.FUSED_TRACES - traces0 - traces_bare
    overhead_pct = (us_traced - us_bare) / us_bare * 100.0
    # retrace discipline: the traced run reuses the untraced run's compiled
    # superblock — tracing lives entirely outside the donated program
    assert traces_on == 0, \
        f"tracing forced {traces_on} retrace(s) of the fused superblock"
    row("trace_loop_bare", us_bare, "40-window fused loop, tracing off")
    row("trace_loop_traced", us_traced,
        f"same loop, every stage recorded ({overhead_pct:+.2f}% vs bare)")

    # export throughput on a real buffer (rerun once, keep the spans)
    sim = Simulator(SimConfig(steps=40, trace=True, **LOOP_KW))
    sim.run()
    n_events = len(sim.trace.to_perfetto()["traceEvents"])
    us_exp = timeit(lambda: sim.trace.to_perfetto_json(), warmup=2, iters=10)
    exp_rate = n_events / us_exp * 1e6
    row("trace_perfetto_export", us_exp / max(n_events, 1),
        f"{exp_rate:,.0f} events/s rendered ({n_events} events)")

    emit_json("trace", metrics={
        "record_spans_per_s": rec_rate,
        "trace_overhead_pct": overhead_pct,
        "traced_retraces": float(traces_on),
        "perfetto_events_per_s": exp_rate,
    }, params={"n_spans": N_SPANS, "closed_loop": {"steps": 40, **LOOP_KW},
               "n_perfetto_events": n_events})
    return {"trace_overhead_pct": overhead_pct}


if __name__ == "__main__":
    run()
