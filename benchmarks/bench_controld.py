"""controld message-path throughput: the ops/s ceiling of the control plane.

The paper's CP must absorb heartbeat telemetry from every CN at the reweight
cadence; this bench measures the daemon's message path (SendState round
trips) over both transports — in-process (what simnet and the serving
engine embed) and the length-prefixed socket (what real CN daemons speak) —
plus the journal-replay rate that bounds recovery time after a restart.

CI gates the in-proc rate (a regression here slows every closed-loop driver)
and trend.py tracks all three against committed floors.
"""
from __future__ import annotations

from benchmarks.common import emit_json, row, timeit
from repro.controld import (ControlDaemon, ControldClient, InProcTransport,
                            Journal, SocketClient, SocketServer)

N_MEMBERS = 8
HB_ROUNDS = 16  # heartbeats per timed call = N_MEMBERS * HB_ROUNDS


def _make(journal: bool):
    daemon = ControlDaemon(n_instances=1, lease_s=1e9, epoch_horizon=256,
                           journal=Journal() if journal else None)
    client = ControldClient(InProcTransport(daemon))
    token = client.reserve(policy="pid")["token"]
    for m in range(N_MEMBERS):
        client.register(token, member_id=m, node_id=m, lane_bits=1)
    client.tick(current_event=0)
    return daemon, client, token


def _hb_burst(client, token):
    def fn():
        for _ in range(HB_ROUNDS):
            for m in range(N_MEMBERS):
                client.send_state(token, m, fill=0.25 + 0.05 * m)
    return fn


def run() -> float:
    msgs = N_MEMBERS * HB_ROUNDS

    # -- in-process transport (journal off / on) ------------------------------
    _, client, token = _make(journal=False)
    us = timeit(_hb_burst(client, token), warmup=2, iters=20)
    inproc = msgs / us * 1e6
    row("controld_inproc_heartbeat", us / msgs,
        f"{inproc:,.0f} msg/s over InProcTransport ({msgs}/burst)")

    daemon_j, client_j, token_j = _make(journal=True)
    us = timeit(_hb_burst(client_j, token_j), warmup=2, iters=20)
    inproc_j = msgs / us * 1e6
    row("controld_inproc_journaled", us / msgs,
        f"{inproc_j:,.0f} msg/s with the WAL journal on")

    # -- journal replay (recovery-time bound) ---------------------------------
    n_entries = daemon_j.journal.seq + 1
    import time as _t
    t0 = _t.perf_counter()
    ControlDaemon.recover(daemon_j.journal, n_instances=1, lease_s=1e9,
                          epoch_horizon=256)
    replay_s = _t.perf_counter() - t0
    replay = n_entries / replay_s if replay_s > 0 else 0.0
    row("controld_journal_replay", replay_s * 1e6 / max(n_entries, 1),
        f"{replay:,.0f} entries/s over {n_entries} entries")

    # -- socket transport -----------------------------------------------------
    daemon_s = ControlDaemon(n_instances=1, lease_s=1e9, epoch_horizon=256)
    server = SocketServer(daemon_s)
    host, port = server.start()
    sclient = ControldClient(SocketClient(host, port))
    stoken = sclient.reserve(policy="pid")["token"]
    for m in range(N_MEMBERS):
        sclient.register(stoken, member_id=m, node_id=m, lane_bits=1)
    sclient.tick(current_event=0)
    us = timeit(_hb_burst(sclient, stoken), warmup=2, iters=10)
    sock = msgs / us * 1e6
    row("controld_socket_heartbeat", us / msgs,
        f"{sock:,.0f} msg/s over the length-prefixed socket")
    sclient.close()
    server.stop()

    emit_json("controld", metrics={
        "inproc_msgs_per_s": inproc,
        "inproc_journaled_msgs_per_s": inproc_j,
        "socket_msgs_per_s": sock,
        "replay_entries_per_s": replay,
    }, params={"n_members": N_MEMBERS, "hb_rounds": HB_ROUNDS})
    return inproc


if __name__ == "__main__":
    run()
