"""controld message-path throughput: the ops/s ceiling of the control plane.

The paper's CP must absorb heartbeat telemetry from every CN at the reweight
cadence. Lanes:

* per-message heartbeats over both transports (in-proc / socket), journal
  on and off, plus the journal-replay rate that bounds recovery time;
* **batched** heartbeats (``SendStateBatch``, M=1024): one frame, one
  journal entry, one telemetry scatter per window — gated >= 10x the
  per-message in-proc path and >= 5x the per-message socket path;
* the **fused policy** path: one ``update_lanes`` pass over [M] lanes vs M
  scalar dict updates, and the 10k-member scaling case — one window of
  telemetry ingested by a single ``SendStateBatch`` scatter plus ONE fused
  jnp device call for the whole policy update (``FUSED_KERNEL_CALLS``
  proves the single-dispatch claim).

CI gates the in-proc rate, both batch speedups and the single-device-call
invariant; trend.py tracks every metric against committed floors.
"""
from __future__ import annotations

import time as _t

import numpy as np

from benchmarks.common import emit_json, row, timeit
from repro.controld import (ControlDaemon, ControldClient, InProcTransport,
                            Journal, SocketClient, SocketServer)
from repro.controld import messages as M
from repro.controld import policy as P
from repro.core.control_plane import MemberTelemetry

N_MEMBERS = 8
HB_ROUNDS = 16   # heartbeats per timed call = N_MEMBERS * HB_ROUNDS
M_BATCH = 1024   # batched-window lane width
M_FARM = 10240   # the 10k-member single-device-call scaling case


def _make(journal: bool, n_members: int = N_MEMBERS, tick: bool = True,
          max_members: int = 64):
    daemon = ControlDaemon(n_instances=1, lease_s=1e9, epoch_horizon=256,
                           max_members=max_members,
                           journal=Journal() if journal else None)
    client = ControldClient(InProcTransport(daemon))
    token = client.reserve(policy="pid")["token"]
    for m in range(n_members):
        client.register(token, member_id=m, node_id=m, lane_bits=1)
    if tick:
        client.tick(current_event=0)
    return daemon, client, token


def _hb_burst(client, token):
    def fn():
        for _ in range(HB_ROUNDS):
            for m in range(N_MEMBERS):
                client.send_state(token, m, fill=0.25 + 0.05 * m)
    return fn


def run() -> dict:
    msgs = N_MEMBERS * HB_ROUNDS

    # -- in-process transport (journal off / on) ------------------------------
    _, client, token = _make(journal=False)
    us = timeit(_hb_burst(client, token), warmup=2, iters=20)
    inproc = msgs / us * 1e6
    row("controld_inproc_heartbeat", us / msgs,
        f"{inproc:,.0f} msg/s over InProcTransport ({msgs}/burst)")

    daemon_j, client_j, token_j = _make(journal=True)
    us = timeit(_hb_burst(client_j, token_j), warmup=2, iters=20)
    inproc_j = msgs / us * 1e6
    row("controld_inproc_journaled", us / msgs,
        f"{inproc_j:,.0f} msg/s with the WAL journal on")

    # -- journal replay (recovery-time bound) ---------------------------------
    n_entries = daemon_j.journal.seq + 1
    t0 = _t.perf_counter()
    ControlDaemon.recover(daemon_j.journal, n_instances=1, lease_s=1e9,
                          epoch_horizon=256)
    replay_s = _t.perf_counter() - t0
    replay = n_entries / replay_s if replay_s > 0 else 0.0
    row("controld_journal_replay", replay_s * 1e6 / max(n_entries, 1),
        f"{replay:,.0f} entries/s over {n_entries} entries")

    # -- batched heartbeats, in-proc (one frame per window, M=1024) -----------
    _, client_b, token_b = _make(journal=False, n_members=M_BATCH,
                                 tick=False, max_members=M_BATCH)
    ids = list(range(M_BATCH))
    fills = [0.25 + 0.05 * (m % 16) for m in ids]
    us = timeit(lambda: client_b.send_state_batch(token_b, ids, fills),
                warmup=2, iters=20)
    batched = M_BATCH / us * 1e6
    row("controld_batched_inproc", us / M_BATCH,
        f"{batched:,.0f} hb/s via one SendStateBatch of {M_BATCH}")

    # per-message baseline over the SAME daemon and member count
    def permsg_window():
        for m in ids:
            client_b.send_state(token_b, m, fill=fills[m])
    us = timeit(permsg_window, warmup=1, iters=5)
    permsg = M_BATCH / us * 1e6
    batched_speedup = batched / permsg if permsg > 0 else 0.0
    row("controld_batched_speedup", us / M_BATCH,
        f"batched in-proc = {batched_speedup:.1f}x the per-message path")

    # -- socket transport: per-message, then batched --------------------------
    daemon_s = ControlDaemon(n_instances=1, lease_s=1e9, epoch_horizon=256,
                             max_members=M_BATCH)
    server = SocketServer(daemon_s)
    host, port = server.start()
    sclient = ControldClient(SocketClient(host, port))
    stoken = sclient.reserve(policy="pid")["token"]
    # pipelined registration burst (also exercises frame pipelining)
    replies = sclient.call_many(
        [M.Register(token=stoken, member_id=m, node_id=m, lane_bits=1)
         for m in range(M_BATCH)])
    assert all(r.ok for r in replies)

    def sock_permsg():
        for _ in range(HB_ROUNDS):
            for m in range(N_MEMBERS):
                sclient.send_state(stoken, m, fill=0.25 + 0.05 * m)
    us = timeit(sock_permsg, warmup=2, iters=10)
    sock = msgs / us * 1e6
    row("controld_socket_heartbeat", us / msgs,
        f"{sock:,.0f} msg/s over the length-prefixed socket")

    us = timeit(lambda: sclient.send_state_batch(stoken, ids, fills),
                warmup=2, iters=10)
    sock_batched = M_BATCH / us * 1e6
    sock_speedup = sock_batched / sock if sock > 0 else 0.0
    row("controld_batched_socket", us / M_BATCH,
        f"{sock_batched:,.0f} hb/s batched = {sock_speedup:.1f}x per-message")
    sclient.close()
    server.stop()

    # -- fused policy update vs M scalar dict updates (M=512) -----------------
    m_pol = 512
    scalar_pol = P.PIDFillPolicy()
    scalar_pol.reset(range(m_pol))
    w_dict = {m: 1.0 for m in range(m_pol)}
    tele = {m: MemberTelemetry(fill=0.25 + 0.001 * m) for m in range(m_pol)}
    us_scalar = timeit(lambda: scalar_pol.update(dict(w_dict), tele),
                       warmup=2, iters=20)
    lane_pol = P.PIDFillPolicy()
    lane_pol.reset(range(m_pol))
    lane_ids = np.arange(m_pol)
    lane_w = np.ones(m_pol)
    lane_fill = 0.25 + 0.001 * np.arange(m_pol)
    lane_healthy = np.ones(m_pol, bool)
    us_lanes = timeit(lambda: lane_pol.update_lanes(
        lane_ids, lane_w, lane_fill, lane_healthy), warmup=2, iters=20)
    fused_speedup = us_scalar / us_lanes if us_lanes > 0 else 0.0
    row("controld_fused_policy", us_lanes / m_pol,
        f"update_lanes[{m_pol}] = {fused_speedup:.1f}x the scalar dict loop")

    # -- the 10k-member farm: one scatter + ONE device call -------------------
    _, client_f, token_f = _make(journal=False, n_members=M_FARM,
                                 tick=False, max_members=M_FARM)
    farm_ids = list(range(M_FARM))
    farm_fills = (0.5 + 0.4 * np.sin(np.arange(M_FARM) / 37.0)).tolist()
    farm_pol = P.PIDFillPolicy()
    farm_pol.reset(range(M_FARM))
    sess = next(iter(client_f.transport.daemon.sessions.values()))
    ids_np = np.arange(M_FARM)
    w_np = np.ones(M_FARM)

    def farm_window():
        client_f.send_state_batch(token_f, farm_ids, farm_fills)
        farm_pol.update_lanes(ids_np, w_np, sess.lanes.fill[:M_FARM],
                              sess.lanes.healthy[:M_FARM], engine="jnp")

    farm_window()  # warm the jit cache before counting dispatches
    calls0 = P.FUSED_KERNEL_CALLS
    us = timeit(farm_window, warmup=1, iters=10)
    calls_per_window = (P.FUSED_KERNEL_CALLS - calls0) / 11  # warmup+iters
    farm_rate = M_FARM / us * 1e6
    row("controld_fused_10k", us / M_FARM,
        f"{farm_rate:,.0f} member-updates/s; {calls_per_window:.0f} device "
        f"call(s) per 10k-member window")

    emit_json("controld", metrics={
        "inproc_msgs_per_s": inproc,
        "inproc_journaled_msgs_per_s": inproc_j,
        "socket_msgs_per_s": sock,
        "replay_entries_per_s": replay,
        "batched_inproc_hb_per_s": batched,
        "batched_inproc_speedup": batched_speedup,
        "batched_socket_hb_per_s": sock_batched,
        "batched_socket_speedup": sock_speedup,
        "fused_policy_speedup_vs_scalar": fused_speedup,
        "fused_10k_members_per_s": farm_rate,
        "fused_10k_device_calls": calls_per_window,
    }, params={"n_members": N_MEMBERS, "hb_rounds": HB_ROUNDS,
               "m_batch": M_BATCH, "m_farm": M_FARM, "m_policy": m_pol})
    return {
        "inproc_msgs_per_s": inproc,
        "batched_inproc_speedup": batched_speedup,
        "batched_socket_speedup": sock_speedup,
        "fused_10k_device_calls": calls_per_window,
    }


if __name__ == "__main__":
    run()
