"""Paper §II-C / fig 7a: segmentation + reassembly throughput under WAN
reorder, including the RSS effect — lanes (entropy) parallelize reassembly,
the paper's fix for the single-core bottleneck. Reports the per-packet
reference loop, the batched sort-based path (one plan per lane per window),
and the per-lane scaling available to RSS."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit_json, row
from repro.data.daq import DAQConfig, DAQFleet
from repro.data.reassembly import BatchReassembler
from repro.data.segmentation import (
    Reassembler,
    batch_from_segments,
    segment_bundle,
)
from repro.data.transport import TransportConfig, WANTransport

N_LANES = 4


def _segments(n_triggers=60, n_daqs=5):
    fleet = DAQFleet(DAQConfig(n_daqs=n_daqs, mean_bundle_bytes=30_000, seed=3))
    segs = []
    for bundles in fleet.stream(n_triggers):
        for b in bundles:
            segs.extend(segment_bundle(b))
    wan = WANTransport(TransportConfig(reorder_window=64, seed=3))
    return wan.deliver(segs)


def run():
    segs = _segments()
    nbytes = sum(len(s.payload) for s in segs)

    # single reassembler (1 lane — the bottleneck case)
    t0 = time.perf_counter()
    ra = Reassembler()
    for s in segs:
        ra.push(s)
    dt1 = time.perf_counter() - t0
    row("reassembly_single_lane", dt1 * 1e6 / len(segs),
        f"{len(segs)/dt1:.0f} seg/s = {nbytes*8/dt1/1e9:.2f} Gbps")

    # 4 lanes keyed by entropy (RSS): independent reassemblers
    t0 = time.perf_counter()
    lanes = [Reassembler() for _ in range(N_LANES)]
    for s in segs:
        lanes[s.entropy % N_LANES].push(s)
    dt4 = time.perf_counter() - t0
    done = sum(len(l.completed) for l in lanes)
    row("reassembly_rss_4lane", dt4 * 1e6 / len(segs),
        f"{len(segs)/dt4:.0f} seg/s, completed={done}, "
        f"lane_parallel_speedup_available={dt1/dt4:.2f}x-per-core")

    # batched sort-based path over the same lanes (one plan per lane)
    batch = batch_from_segments(segs)
    lane_of = batch.entropy % N_LANES
    sels = [np.flatnonzero(lane_of == l) for l in range(N_LANES)]
    t0 = time.perf_counter()
    blanes = [BatchReassembler() for _ in range(N_LANES)]
    bdone = 0
    for l in range(N_LANES):
        bdone += len(blanes[l].push_batch(batch.take(sels[l])))
    dtb = time.perf_counter() - t0
    assert bdone == done
    row("reassembly_batched_4lane", dtb * 1e6 / len(segs),
        f"{len(segs)/dtb:.0f} seg/s sort-based = {dt4/dtb:.2f}x the "
        f"per-packet lanes (9KB rows: memcpy-bound either way; the "
        f"orchestration-bound regime is gated in bench_ingest)")

    emit_json("reassembly", metrics={
        "single_lane_seg_per_s": len(segs) / dt1,
        "rss_4lane_seg_per_s": len(segs) / dt4,
        "batched_4lane_seg_per_s": len(segs) / dtb,
        "batched_vs_perpacket_lanes": dt4 / dtb,
        "gbps_single_lane": nbytes * 8 / dt1 / 1e9,
    }, params={"n_segments": len(segs), "n_lanes": N_LANES})


if __name__ == "__main__":
    run()
