"""Paper §II-C / fig 7a: segmentation + reassembly throughput under WAN
reorder, including the RSS effect — lanes (entropy) parallelize reassembly,
the paper's fix for the single-core bottleneck. Reports per-lane scaling."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.data.daq import DAQConfig, DAQFleet
from repro.data.segmentation import Reassembler, segment_bundle
from repro.data.transport import TransportConfig, WANTransport


def _segments(n_triggers=60, n_daqs=5):
    fleet = DAQFleet(DAQConfig(n_daqs=n_daqs, mean_bundle_bytes=30_000, seed=3))
    segs = []
    for bundles in fleet.stream(n_triggers):
        for b in bundles:
            segs.extend(segment_bundle(b))
    wan = WANTransport(TransportConfig(reorder_window=64, seed=3))
    return wan.deliver(segs)


def run():
    segs = _segments()
    nbytes = sum(len(s.payload) for s in segs)

    # single reassembler (1 lane — the bottleneck case)
    t0 = time.perf_counter()
    ra = Reassembler()
    for s in segs:
        ra.push(s)
    dt1 = time.perf_counter() - t0
    row("reassembly_single_lane", dt1 * 1e6 / len(segs),
        f"{len(segs)/dt1:.0f} seg/s = {nbytes*8/dt1/1e9:.2f} Gbps")

    # 4 lanes keyed by entropy (RSS): independent reassemblers
    t0 = time.perf_counter()
    lanes = [Reassembler() for _ in range(4)]
    for s in segs:
        lanes[s.entropy % 4].push(s)
    dt4 = time.perf_counter() - t0
    done = sum(len(l.completed) for l in lanes)
    row("reassembly_rss_4lane", dt4 * 1e6 / len(segs),
        f"{len(segs)/dt4:.0f} seg/s, completed={done}, "
        f"lane_parallel_speedup_available={dt1/dt4:.2f}x-per-core")


if __name__ == "__main__":
    run()
