"""Table: two-tier LB fabric — spray throughput vs tier size + the
isolation and balance gates as numbers.

Three figures:

* ``fabric_k{2,4,8}`` — aggregate simulated packets/sec through the full
  two-hop plant (uplink -> intermediate LB -> fabric hop -> owner calendar
  -> downlink -> farm) as the tier widens. The fabric is embarrassingly
  array-parallel, so pkt/s should hold roughly flat with K.
* ``isolation_ratio`` — mice p99 with isolation OFF over ON on the
  ``elephant_mice`` scenario. **CI gate: > 1 (isolation must help), floor
  committed in baselines.json.**
* ``vlb_balance_gain`` — direct-hash max-LB load share over VLB's on the
  skewed ``vlb_spray`` scenario. **CI gate: >= 1 (spray must not lose).**
"""
from __future__ import annotations

import time

from benchmarks.common import emit_json, row
from repro.fabric import FabricSim, get_fabric_scenario


def _tier_throughput(k: int) -> float:
    sc = get_fabric_scenario("vlb_spray")
    cfg = sc.build_config(steps=20, k_lbs=k)
    sim = FabricSim(cfg, scenario=sc)
    t0 = time.perf_counter()
    r = sim.run()
    dt = time.perf_counter() - t0
    assert not r.violations, r.violations
    return r.segments_sent / dt


def run():
    _tier_throughput(2)   # warm the routing jit caches off the clock
    pps = {}
    for k in (2, 4, 8):
        pps[k] = _tier_throughput(k)
        row(f"fabric_k{k}", 1e6 / pps[k],
            f"{pps[k]:,.0f} simulated pkt/s through a {k}-LB tier")

    sc = get_fabric_scenario("elephant_mice")
    on = FabricSim(sc.build_config(isolate=True), scenario=sc).run()
    off = FabricSim(sc.build_config(isolate=False), scenario=sc).run()
    assert not on.violations and not off.violations
    iso_ratio = off.mice_p99_s / on.mice_p99_s
    row("fabric_isolation", on.mice_p99_s * 1e6,
        f"mice p99 {on.mice_p99_s * 1e3:.3f}ms isolated vs "
        f"{off.mice_p99_s * 1e3:.3f}ms shared ({iso_ratio:.2f}x, want > 1)")

    sc = get_fabric_scenario("vlb_spray")
    vlb = FabricSim(sc.build_config(mode="vlb"), scenario=sc).run()
    direct = FabricSim(sc.build_config(mode="direct"), scenario=sc).run()
    assert not vlb.violations and not direct.violations
    balance_gain = direct.max_lb_load_frac / vlb.max_lb_load_frac
    row("fabric_vlb_balance", vlb.max_lb_load_frac * 1e6,
        f"max-LB load share {vlb.max_lb_load_frac:.3f} VLB vs "
        f"{direct.max_lb_load_frac:.3f} direct ({balance_gain:.2f}x)")

    metrics = {
        "k2_pkts_per_s": pps[2],
        "k4_pkts_per_s": pps[4],
        "k8_pkts_per_s": pps[8],
        "isolation_ratio_off_over_on": iso_ratio,
        "mice_p99_isolated_s": on.mice_p99_s,
        "mice_p99_shared_s": off.mice_p99_s,
        "vlb_balance_gain": balance_gain,
        "vlb_max_lb_load_frac": vlb.max_lb_load_frac,
        "direct_max_lb_load_frac": direct.max_lb_load_frac,
    }
    emit_json("fabric", metrics=metrics, params={
        "tier_sizes": [2, 4, 8],
        "throughput_scenario": "vlb_spray (20 steps)",
        "isolation_scenario": "elephant_mice",
        "balance_scenario": "vlb_spray",
    })
    return metrics


if __name__ == "__main__":
    m = run()
    print(f"isolation ratio: {m['isolation_ratio_off_over_on']:.2f}x, "
          f"VLB balance gain: {m['vlb_balance_gain']:.2f}x")
