"""Paper fig. 5/7: LB data-plane line rate (98 Gbps at 9KB packets on the
U280). Here: routed packets/s through the unified DataPlane facade —
backend="jnp" (XLA-jitted reference) and backend="pallas" (interpret mode —
CPU functional model; the TPU-projected figure uses the kernel's
VMEM-resident table reads, see EXPERIMENTS.md). Also measures the fused
multi-instance path (4 virtual LBs, one gather pass)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json, row, timeit
from repro.core import DataPlane, EpochManager, MemberSpec, encode_headers
from repro.core.instance import VirtualLoadBalancer

N_PACKETS = 16_384
PACKET_BYTES = 9000


def _setup():
    em = EpochManager(max_members=64)
    em.initialize({i: MemberSpec(node_id=i, lane_bits=2) for i in range(10)},
                  {i: 1.0 for i in range(10)})
    rng = np.random.default_rng(0)
    ev = rng.integers(0, 1 << 48, N_PACKETS).astype(np.uint64)
    en = rng.integers(0, 1 << 16, N_PACKETS).astype(np.uint32)
    return em, jnp.asarray(encode_headers(ev, en))


def run():
    em, headers = _setup()

    dp = DataPlane.from_manager(em, backend="jnp")
    jit_route = jax.jit(lambda h: dp.route(h).member)
    jax.block_until_ready(jit_route(headers))
    us = timeit(lambda: jax.block_until_ready(jit_route(headers)))
    pps = N_PACKETS / (us / 1e6)
    gbps = pps * PACKET_BYTES * 8 / 1e9
    row("route_throughput_jnp_xla", us,
        f"{pps/1e6:.2f} Mpps = {gbps:.1f} Gbps at 9KB (paper: 98 Gbps line rate)")

    vlb = VirtualLoadBalancer(max_members=64)
    for k in range(4):
        vlb.instances[k].initialize(
            {i: MemberSpec(node_id=i, lane_bits=2) for i in range(10)},
            {i: 1.0 for i in range(10)})
    dpm = DataPlane(vlb.device_tables(), backend="jnp")
    iid = jnp.asarray(np.random.default_rng(1).integers(0, 4, N_PACKETS),
                      jnp.int32)
    jit_mi = jax.jit(lambda h, i: dpm.route(h, i).member)
    jax.block_until_ready(jit_mi(headers, iid))
    us_mi = timeit(lambda: jax.block_until_ready(jit_mi(headers, iid)))
    row("route_throughput_4instance_fused", us_mi,
        f"{N_PACKETS/(us_mi/1e6)/1e6:.2f} Mpps across 4 virtual LBs "
        f"(single fused gather pass)")

    dpp = DataPlane.from_manager(em, backend="pallas", interpret=True)
    out = dpp.route(headers)
    jax.block_until_ready(out.member)
    us2 = timeit(lambda: jax.block_until_ready(dpp.route(headers).member),
                 iters=3)
    row("route_throughput_pallas_interpret", us2,
        f"{N_PACKETS/(us2/1e6)/1e6:.3f} Mpps (functional model on CPU)")

    emit_json("route_throughput", metrics={
        "jnp_mpps": N_PACKETS / us,
        "jnp_gbps_9kb": gbps,
        "fused_4instance_mpps": N_PACKETS / us_mi,
        "pallas_interpret_mpps": N_PACKETS / us2,
    }, params={"n_packets": N_PACKETS, "packet_bytes": PACKET_BYTES,
               "n_instances": 4})


if __name__ == "__main__":
    run()
