"""Paper fig. 5/7: LB data-plane line rate (98 Gbps at 9KB packets on the
U280). Here: routed packets/s through the jnp data plane and the Pallas
kernel (interpret mode — CPU functional model; the TPU-projected figure uses
the kernel's VMEM-resident table reads, see EXPERIMENTS.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import EpochManager, MemberSpec, encode_headers
from repro.kernels import ops, ref

N_PACKETS = 16_384
PACKET_BYTES = 9000


def _setup():
    em = EpochManager(max_members=64)
    em.initialize({i: MemberSpec(node_id=i, lane_bits=2) for i in range(10)},
                  {i: 1.0 for i in range(10)})
    t = em.device_tables()
    rng = np.random.default_rng(0)
    ev = rng.integers(0, 1 << 48, N_PACKETS).astype(np.uint64)
    en = rng.integers(0, 1 << 16, N_PACKETS).astype(np.uint32)
    return t, jnp.asarray(encode_headers(ev, en))


def run():
    tables, headers = _setup()
    tt = ref.tables_tuple(tables)

    jit_ref = jax.jit(lambda h: ref.lb_route_ref(h, tt))
    out = jit_ref(headers)
    jax.block_until_ready(out)
    us = timeit(lambda: jax.block_until_ready(jit_ref(headers)))
    pps = N_PACKETS / (us / 1e6)
    gbps = pps * PACKET_BYTES * 8 / 1e9
    row("route_throughput_jnp_xla", us,
        f"{pps/1e6:.2f} Mpps = {gbps:.1f} Gbps at 9KB (paper: 98 Gbps line rate)")

    out = ops.route_packets(headers, tables, use_pallas=True, interpret=True)
    jax.block_until_ready(out)
    us2 = timeit(lambda: jax.block_until_ready(
        ops.route_packets(headers, tables, use_pallas=True, interpret=True)),
        iters=3)
    row("route_throughput_pallas_interpret", us2,
        f"{N_PACKETS/(us2/1e6)/1e6:.3f} Mpps (functional model on CPU)")


if __name__ == "__main__":
    run()
