"""controld HA cost: what warm-standby replication and failover cost.

The HA tentpole's two promises have prices, and this bench pins both:

* **Replication tax** — a leader ships every WAL entry to its standby
  *synchronously* (the ack lands before the client reply, so any
  client-visible state is durable on the standby). The heartbeat
  message path is timed on an unreplicated journaled daemon and on an
  ``HACluster`` leader with one standby; the gated figure is the
  replicated rate, floored in baselines.json at 80% of
  ``bench_controld``'s committed in-proc floor (4000 msg/s -> 3200) —
  adding a standby must not drop the control plane below the paper's
  heartbeat-absorption requirement. The batched leg (``SendStateBatch``,
  the path simnet actually drives) shows the tax amortized to one
  shipment per window. Digest invariants are asserted inline: after the
  burst the standby's ``state_digest`` is byte-identical to the
  leader's, and replication lag is exactly 0 entries.
* **Failover time** — wall-clock from SIGKILL-ing the leader (in-proc
  ``kill``) to the first *successful* mutating call against the
  promoted successor, driven purely by a retrying ``ControldClient``
  over a ``FailoverTransport`` (no external coordinator). Median over
  several kill/promote/revive rounds, ceiling-gated in baselines.json.

CI gates the replicated rate and the failover ceiling; trend.py tracks
every metric against committed floors.
"""
from __future__ import annotations

import time as _t

from benchmarks.common import emit_json, row, timeit
from repro.controld import (ControlDaemon, ControldClient, FailoverTransport,
                            HACluster, InProcTransport, Journal, RetryPolicy)

N_MEMBERS = 8
HB_ROUNDS = 16       # heartbeats per timed call = N_MEMBERS * HB_ROUNDS
M_BATCH = 1024       # batched-window lane width (matches bench_controld)
FAILOVERS = 5        # kill/promote/revive rounds for the failover median
FAILOVER_TERM_S = 0.05

DAEMON_KW = dict(n_instances=1, lease_s=1e9, epoch_horizon=256,
                 max_members=64)


def _register(client):
    token = client.reserve(policy="pid")["token"]
    for m in range(N_MEMBERS):
        client.register(token, member_id=m, node_id=m, lane_bits=1)
    client.tick(current_event=0)
    return token


def _hb_burst(client, token):
    def fn():
        for _ in range(HB_ROUNDS):
            for m in range(N_MEMBERS):
                client.send_state(token, m, fill=0.25 + 0.05 * m)
    return fn


def run() -> dict:
    msgs = N_MEMBERS * HB_ROUNDS

    # -- unreplicated floor: one journaled daemon, in-proc ------------------
    daemon = ControlDaemon(journal=Journal(), **DAEMON_KW)
    client = ControldClient(InProcTransport(daemon))
    token = _register(client)
    us = timeit(_hb_burst(client, token), warmup=2, iters=20)
    unreplicated = msgs / us * 1e6
    row("ha_unreplicated_heartbeat", us / msgs,
        f"{unreplicated:,.0f} msg/s journaled, no standby")

    # -- replicated: leader + 1 warm standby, synchronous shipping ----------
    cluster = HACluster(n_nodes=2, term_s=1e9, daemon_kwargs=DAEMON_KW)
    rclient = ControldClient(cluster.client_endpoints()[0])
    rtoken = _register(rclient)
    us = timeit(_hb_burst(rclient, rtoken), warmup=2, iters=20)
    replicated = msgs / us * 1e6
    efficiency = replicated / unreplicated if unreplicated > 0 else 0.0
    row("ha_replicated_heartbeat", us / msgs,
        f"{replicated:,.0f} msg/s shipped to 1 standby "
        f"({efficiency * 100:.0f}% of unreplicated)")

    # synchronous-durability invariants: zero lag, byte-identical digest
    leader, (standby,) = cluster.leader(), cluster.standbys()
    assert leader.replicator.lag() == 0, "standby lags a synchronous leader"
    assert (leader.daemon.state_digest()
            == standby.daemon.state_digest()), "standby digest diverged"

    # -- batched heartbeats, replicated: one shipment per window ------------
    bkw = dict(DAEMON_KW, max_members=M_BATCH)
    bcluster = HACluster(n_nodes=2, term_s=1e9, daemon_kwargs=bkw)
    bclient = ControldClient(bcluster.client_endpoints()[0])
    btoken = bclient.reserve(policy="pid")["token"]
    ids = list(range(M_BATCH))
    for m in ids:
        bclient.register(btoken, member_id=m, node_id=m, lane_bits=1)
    fills = [0.25 + 0.05 * (m % 16) for m in ids]
    us = timeit(lambda: bclient.send_state_batch(btoken, ids, fills),
                warmup=2, iters=20)
    batched = M_BATCH / us * 1e6
    row("ha_batched_replicated", us / M_BATCH,
        f"{batched:,.0f} hb/s via one SendStateBatch of {M_BATCH}, "
        "shipped as one WAL entry per window")

    # -- failover: kill the leader, time the client-driven takeover ---------
    fo = HACluster(n_nodes=2, term_s=FAILOVER_TERM_S, daemon_kwargs=DAEMON_KW)
    retry = RetryPolicy(base_s=FAILOVER_TERM_S / 16.0,
                        cap_s=FAILOVER_TERM_S / 8.0,
                        max_elapsed_s=100.0 * FAILOVER_TERM_S, seed=0)
    fclient = ControldClient(
        FailoverTransport(fo.client_endpoints(), retry=retry))
    ftoken = _register(fclient)
    durations = []
    for i in range(FAILOVERS):
        dead = fo.kill_leader()
        t0 = _t.perf_counter()
        fclient.send_state(ftoken, i % N_MEMBERS, fill=0.5)
        durations.append(_t.perf_counter() - t0)
        fo.revive(dead)  # back as a fresh standby, caught up from backlog
    durations.sort()
    failover_ms = durations[len(durations) // 2] * 1e3
    row("ha_failover", failover_ms * 1e3,
        f"median {failover_ms:.1f}ms kill-to-first-accepted-mutation "
        f"(term {FAILOVER_TERM_S * 1e3:.0f}ms, worst "
        f"{durations[-1] * 1e3:.1f}ms over {FAILOVERS} takeovers)")
    # the session survived every takeover: the token minted before the
    # first kill is still honoured by the last successor
    assert fo.leader().daemon.handle is not None
    assert fo.leader().promotions >= 1

    emit_json("ha", metrics={
        "unreplicated_hb_per_s": unreplicated,
        "replicated_hb_per_s": replicated,
        "replication_efficiency": efficiency,
        "batched_replicated_hb_per_s": batched,
        "failover_ms": failover_ms,
        "failover_worst_ms": durations[-1] * 1e3,
    }, params={"n_members": N_MEMBERS, "hb_rounds": HB_ROUNDS,
               "m_batch": M_BATCH, "failovers": FAILOVERS,
               "failover_term_s": FAILOVER_TERM_S})
    return {
        "replicated_hb_per_s": replicated,
        "failover_ms": failover_ms,
    }


if __name__ == "__main__":
    run()
