"""Paper fig. 7c final epoch: fair distribution of sequential events to all
CNs, with CN-5 weighted 2x. Measures the realized per-member packet share
against the programmed calendar weights."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit_json, row, timeit
from repro.core import DataPlane, EpochManager, MemberSpec, encode_headers
from repro.core.calendar import calendar_counts


def run():
    weights = {i: (2.0 if i == 5 else 1.0) for i in range(10)}
    em = EpochManager(max_members=64)
    em.initialize({i: MemberSpec(node_id=i, lane_bits=2) for i in weights},
                  weights)
    dp = DataPlane.from_manager(em, backend="jnp")
    n = 200_000
    rng = np.random.default_rng(0)
    ev = rng.integers(0, 1 << 40, n).astype(np.uint64)
    ent = rng.integers(0, 1 << 16, n).astype(np.uint32)

    import jax
    import jax.numpy as jnp

    headers = jnp.asarray(encode_headers(ev, ent))
    fn = jax.jit(lambda h: dp.route(h).member)
    member = np.asarray(fn(headers))
    us = timeit(lambda: jax.block_until_ready(fn(headers)))

    counts = np.bincount(member, minlength=10).astype(np.float64)
    share = counts / counts.sum()
    want = np.asarray([weights[i] for i in range(10)])
    want = want / want.sum()
    max_rel_err = float(np.max(np.abs(share - want) / want))
    cn5_ratio = counts[5] / np.mean(np.delete(counts, 5))
    row("fairness_weighted_cn5", us,
        f"CN5/others={cn5_ratio:.3f} (want 2.0) max_rel_err={max_rel_err:.3f} "
        f"over {n} events")
    # calendar-level exactness (the programmed quotas)
    cal_counts = calendar_counts(em.state.calendars[0], 10)
    row("fairness_calendar_quota", 0.0,
        f"cn5_slots={cal_counts[5]} others_mean={np.delete(cal_counts, 5).mean():.1f}"
        f" all_filled={int(cal_counts.sum())==512}")
    emit_json("fairness", metrics={
        "cn5_ratio": float(cn5_ratio),
        "max_rel_err": max_rel_err,
        "cn5_slots": int(cal_counts[5]),
        "all_filled": bool(int(cal_counts.sum()) == 512),
    }, params={"n_events": n, "n_members": 10, "cn5_weight": 2.0})


if __name__ == "__main__":
    run()
