"""Elastic scaling + straggler mitigation + failure recovery — the paper's
fig-7c scenario driven by the control plane during a live training run.

    PYTHONPATH=src python examples/elastic_scaling.py

Timeline:
  steps  0-19 : 4 members, uniform weights
  step    20 : member 3 FAILS -> hit-lessly removed from the next epoch
  steps 21-39: member 2 is a 3x straggler -> PI controller sheds its slots
  step    40 : two fresh members join (scale-out)
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_smoke_config
from repro.core.calendar import calendar_counts
from repro.train import optimizer as OPT
from repro.train import train_step as TS
from repro.train.trainer import Trainer, TrainerConfig


def shares(trainer, n=8):
    em = trainer.manager
    cal = em.state.calendars[em.current_epoch]
    c = calendar_counts(cal, n)
    return {i: int(v) for i, v in enumerate(c) if v > 0}


def main():
    cfg = get_smoke_config("yi_6b")
    tcfg = TS.TrainConfig(adamw=OPT.AdamWConfig(lr=1e-3), remat=False,
                          lb_ingest=False, q_chunk=16, k_chunk=16)
    tr = Trainer(cfg, tcfg, TrainerConfig(n_members=4, ckpt_dir="/tmp/elastic_ckpt",
                                          ckpt_every=10, recalendar_every=5))
    tr.init_or_restore(jax.random.PRNGKey(0))

    print("epoch 0 calendar shares:", shares(tr))
    tr.run(20, batch=4, seq=16)

    print("\n-- member 3 fails --")
    tr.handle_failure([3])
    print("next-epoch shares:", shares(tr))

    # straggler: member 2 reports 3x step time
    orig = tr.hub.report_step
    tr.hub.report_step = lambda m, dt, **kw: orig(m, dt * (3.0 if m == 2 else 1.0), **kw)
    tr.run(20, batch=4, seq=16)
    print("\n-- after 20 steps with member 2 straggling (3x) --")
    print("shares:", shares(tr))

    print("\n-- scale out: members 6, 7 join --")
    tr.hub.report_step = orig
    tr.add_members([6, 7])
    print("next-epoch shares:", shares(tr))
    tr.run(10, batch=4, seq=16)

    losses = [h["loss"] for h in tr.history]
    print(f"\ntrained {len(losses)} steps through 4 epochs; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    print("audit tail:", tr.manager.audit[-6:])


if __name__ == "__main__":
    main()
