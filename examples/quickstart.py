"""Quickstart: the end-to-end driver — stream DAQ events through the EJ-FAT
load balancer into a small LM and train it for a few hundred steps.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]

What it exercises: DAQ fleet (5 sources, synchronized event numbers) ->
9KB segmentation -> WAN reorder -> LB calendar routing -> per-lane
reassembly -> token batches -> AdamW training with checkpointing.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EpochManager, MemberSpec
from repro.data.daq import DAQConfig
from repro.data.pipeline import StreamingPipeline, batches_from_bundles
from repro.data.transport import TransportConfig
from repro.models.config import ModelConfig
from repro.train import optimizer as OPT
from repro.train import train_step as TS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--backend", default="auto", choices=["auto", "jnp", "pallas"],
                    help="data-plane backend (core.dataplane.DataPlane)")
    args = ap.parse_args()

    # --- the LB front end: 4 compute members, entropy over 4 lanes ---
    em = EpochManager(max_members=16)
    em.initialize({i: MemberSpec(node_id=i, lane_bits=2) for i in range(4)},
                  {i: 1.0 for i in range(4)})
    pipe = StreamingPipeline(
        DAQConfig(n_daqs=5, seq_len=args.seq, mean_bundle_bytes=12_000, seed=0),
        TransportConfig(reorder_window=32, seed=0), em, backend=args.backend)

    # --- a ~10M-param LM (same block as the full configs) ---
    cfg = ModelConfig(name="quickstart-lm", family="dense", n_layers=4,
                      d_model=256, n_heads=8, n_kv_heads=4, d_ff=704,
                      vocab=256, dtype="float32")
    n_params, _ = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params")

    tcfg = TS.TrainConfig(adamw=OPT.AdamWConfig(lr=3e-4, warmup_steps=20,
                                                decay_steps=args.steps),
                          remat=False, lb_ingest=False, q_chunk=64, k_chunk=64)
    state = TS.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(TS.make_train_step(cfg, tcfg))

    losses, seen = [], 0
    while seen < args.steps:
        payloads = pipe.pump(6)
        for b in batches_from_bundles(payloads, args.seq, args.batch):
            t = jnp.asarray(b % cfg.vocab)
            state, metrics = step(state, {"tokens": t, "labels": t}, None)
            losses.append(float(metrics["loss"]))
            seen += 1
            if seen % 25 == 0:
                print(f"step {seen:4d}  loss {np.mean(losses[-25:]):.4f}  "
                      f"lb: routed={pipe.stats.n_routed} "
                      f"members={dict(sorted(pipe.stats.per_member.items()))}")
            if seen >= args.steps:
                break
    print(f"\nfinal loss {np.mean(losses[-10:]):.4f} (start {np.mean(losses[:10]):.4f})")
    emap = pipe.event_member_map()
    assert all(len(m) == 1 for m in emap.values())
    print(f"event atomicity: OK over {len(emap)} events; "
          f"dropped={pipe.stats.n_discarded}")


if __name__ == "__main__":
    main()
