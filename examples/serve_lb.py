"""Serving through the LB front door: batched requests are events; the
calendar picks the replica, the entropy field picks the decode lane (RSS).
Submissions accumulate and are routed lazily — one batched DataPlane device
call per engine tick, not one per request. Mid-run, a replica is drained
hit-lessly (weight -> 0 in the next epoch).

    PYTHONPATH=src python examples/serve_lb.py
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "jnp", "pallas"],
                    help="data-plane backend (core.dataplane.DataPlane)")
    args = ap.parse_args()
    cfg = get_smoke_config("yi_6b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, ServeConfig(n_replicas=3, lane_bits=1,
                                         max_len=96, backend=args.backend),
                        params)
    rng = np.random.default_rng(0)

    print("phase 1: 12 requests across 3 replicas")
    reqs = [eng.submit(rng.integers(0, cfg.vocab, int(rng.integers(4, 12))),
                       max_new_tokens=8) for _ in range(12)]
    eng.run_until_done()
    print("  routed per replica:", dict(sorted(eng.stats["routed"].items())),
          f"({eng.stats['route_calls']} batched route calls)")
    print("  completed:", eng.stats["completed"])

    print("\nphase 2: drain replica 1 (weight 0 in next epoch, hit-less)")
    eng.cp.weights[1] = 0.0
    eng.cp.schedule_epoch(eng.next_event, boundary=eng.next_event)
    before = dict(eng.stats["routed"])
    reqs2 = [eng.submit(rng.integers(0, cfg.vocab, 6), max_new_tokens=6)
             for _ in range(12)]
    eng.run_until_done()
    after = eng.stats["routed"]
    delta = {k: after.get(k, 0) - before.get(k, 0) for k in (0, 1, 2)}
    print("  new requests per replica:", delta)
    assert delta[1] == 0, "drained replica must receive no new work"
    assert all(r.done for r in reqs + reqs2)
    print("  drained OK; all", len(reqs) + len(reqs2), "requests completed")


if __name__ == "__main__":
    main()
