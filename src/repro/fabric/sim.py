"""FabricSim: a two-tier LB fabric on virtual time.

A fleet of DAQs sprays event bundles across a tier of K LB instances via
two-phase VLB (``fabric.spray``), an elephant detector (``fabric.elephant``)
strict-source-routes heavy streams onto reserved calendar lanes, and the
whole plant — DAQ uplinks, per-LB ingress trunks, the inter-LB fabric hop,
per-member downlinks, bounded CN queues — runs on the existing simnet
machinery (token-bucket ``LinkSet`` banks + Lindley ``FarmQueues``).

Lane partition (DESIGN.md §Fabric): every LB instance carries TWO calendars
(stacked as ``DataPlane.from_instances`` entries ``lb*2 + class``): the
*spray* calendar and the *reserved* calendar. With isolation ON the spray
calendar is programmed over the mice members and the reserved calendar over
the last ``reserved_fraction`` of the farm — elephants can't queue a byte on
a mouse's downlink or CN. With isolation OFF both calendars span the whole
farm (the control group the ``elephant_mice`` gate measures against).

Everything is window-atomic struct-of-arrays: one window's segments flow
emission -> uplink -> ingress trunk -> (optional) fabric hop -> owner
calendar -> downlink -> queue as array programs, and every segment is
accounted exactly once (the conservation identity in ``run()`` is a hard
violation, not a best-effort counter). Killing a tier member at a window
boundary is therefore hit-less by construction; the spray plane re-indexes
over the survivors deterministically.

``controld=True`` makes the fabric a first-class tenant of the control
daemon: one ``ReserveFabric`` reservation (2K sessions), members registered
per lane class, and ``kill_lb`` tears the dead LB's sessions down with
``DeregisterBatch`` + ``Free`` — K instances' teardown in 2 frames each.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Callable, Optional

import numpy as np

from repro.core.control_plane import LoadBalancerControlPlane
from repro.core.dataplane import DataPlaneCache
from repro.core.epoch import EpochManager
from repro.core.protocol import HEADER_BYTES
from repro.core.tables import MemberSpec
from repro.data.segmentation import SEG_HDR_BYTES, next_pow2
from repro.fabric.elephant import ElephantConfig, ElephantDetector
from repro.fabric.spray import spray_paths
from repro.simnet.clock import VirtualClock
from repro.simnet.links import LinkConfig, LinkSet
from repro.simnet.queues import FarmConfig, FarmQueues
from repro.telemetry.trace import (TraceBuffer, TraceConfig, bundle_key,
                                   trace_id)

IP_UDP_BYTES = 28
WIRE_OVERHEAD = HEADER_BYTES + SEG_HDR_BYTES + IP_UDP_BYTES


@dataclasses.dataclass
class FabricConfig:
    """One fabric run's shape. Scenario presets override fields of this."""

    steps: int = 40
    k_lbs: int = 4                 # LB tier size
    n_members: int = 16            # global CN farm (shared by the tier)
    n_daqs: int = 8
    triggers_per_step: int = 4
    trigger_period_s: float = 1e-3
    mean_bundle_bytes: int = 12_000
    daq_scale: Optional[np.ndarray] = None   # [D] per-DAQ size multiplier
    mtu_payload: int = 2048
    seed: int = 0

    # spray plane
    mode: str = "vlb"              # "vlb" | "direct" (per-DAQ static hash)
    isolate: bool = True           # partition the farm across lane classes
    reserved_fraction: float = 0.25
    detector: ElephantConfig = dataclasses.field(
        default_factory=ElephantConfig)

    # LB data plane
    backend: str = "auto"
    lb_latency_s: float = 4e-6

    # links: per-DAQ uplink, per-LB ingress trunk, per-LB fabric (inter-LB)
    # port, per-member downlink
    daq_uplink: LinkConfig = dataclasses.field(
        default_factory=lambda: LinkConfig(rate_Bps=400e6, jitter_s=1e-5))
    lb_ingress: LinkConfig = dataclasses.field(
        default_factory=lambda: LinkConfig(rate_Bps=250e6,
                                           prop_delay_s=2e-4, jitter_s=1e-5))
    lb_fabric: LinkConfig = dataclasses.field(
        default_factory=lambda: LinkConfig(rate_Bps=250e6,
                                           prop_delay_s=5e-5, jitter_s=1e-5))
    member_link: LinkConfig = dataclasses.field(
        default_factory=lambda: LinkConfig(rate_Bps=50e6,
                                           prop_delay_s=5e-5, jitter_s=1e-5))

    # farm service model (per-member ~50 MB/s default)
    service_per_packet_s: float = 1e-5
    service_per_byte_s: float = 2e-8
    queue_capacity_s: float = 0.05
    queue_engine: str = "np"

    # control plane: local calendars (default) or a ReserveFabric tenant
    controld: bool = False
    controld_policy: str = "proportional"
    tick_every: int = 5
    lease_s: Optional[float] = None

    # tracing: per-bundle stage spans (telemetry.trace). Per-LB spans carry
    # the stacked-calendar instance id (lb*2 + class) as ``aux``, so the
    # two VLB hops and the elephant/mice lane split are visible per span;
    # two-hop paths show a distinct "fabric" stage in the span tree.
    trace: bool = False
    trace_sample: float = 1.0
    trace_tail_k: int = 64

    def window_period_s(self) -> float:
        return self.triggers_per_step * self.trigger_period_s


@dataclasses.dataclass
class FabricScenario:
    """A named fabric preset: config overrides + live hooks."""

    name: str
    description: str
    overrides: dict = dataclasses.field(default_factory=dict)
    daq_scale: Optional[Callable[[int], np.ndarray]] = None
    on_step: Optional[Callable[["FabricSim", int], None]] = None

    def build_config(self, **extra) -> FabricConfig:
        cfg = FabricConfig(**{**self.overrides, **extra})
        if self.daq_scale is not None:
            cfg.daq_scale = self.daq_scale(cfg.n_daqs)
        return cfg


@dataclasses.dataclass
class FabricReport:
    """What a fabric run measured (per-class latency is the headline)."""

    scenario: str
    steps: int
    mode: str
    isolate: bool
    k_lbs: int
    sim_time_s: float
    wall_s: float
    # segment conservation (sums exactly to segments_sent; audited in run())
    segments_sent: int
    segments_served: int
    lost_uplink: int
    lost_ingress: int
    lost_fabric: int
    discarded_invalid: int
    lost_downlink: int
    dropped_queue: int
    # bundles: lost = at least one segment lost anywhere
    bundles_sent: int
    bundles_completed: int
    bundles_lost: int
    # latency, fabric-wide and per class
    latency_p50_s: float
    latency_p99_s: float
    latency_max_s: float
    mice_completed: int
    mice_p50_s: float
    mice_p99_s: float
    elephant_completed: int
    elephant_p50_s: float
    elephant_p99_s: float
    # tier balance: bytes traversing each LB (phase 1 + phase 2 arrivals)
    lb_load_bytes: list
    max_lb_load_frac: float
    # detector
    elephants_detected: int
    detector_transitions: int
    lbs_killed: list
    violations: list

    @property
    def packets_per_sec(self) -> float:
        return self.segments_sent / self.wall_s if self.wall_s > 0 else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["packets_per_sec"] = round(self.packets_per_sec, 1)
        for k, v in list(d.items()):
            if isinstance(v, float):
                d[k] = round(v, 9)
        return d


def _pct(lat: np.ndarray, q: float) -> float:
    return float(np.percentile(lat, q)) if len(lat) else 0.0


class FabricSim:
    """Drives one fabric scenario end to end on virtual time."""

    def __init__(self, cfg: FabricConfig,
                 scenario: Optional[FabricScenario] = None,
                 metrics=None):
        if cfg.k_lbs < 1:
            raise ValueError("need at least one LB in the tier")
        if not (0.0 < cfg.reserved_fraction < 1.0):
            raise ValueError("reserved_fraction must be in (0, 1)")
        if cfg.n_members < 2:
            raise ValueError("the lane partition needs >= 2 members")
        self.cfg = cfg
        self.scenario = scenario
        self.clock = VirtualClock()
        self.rng = np.random.default_rng(cfg.seed)
        self.trace: Optional[TraceBuffer] = None
        self._trace_pid0 = 0
        if cfg.trace:
            self.trace = TraceBuffer(TraceConfig(
                head_rate=cfg.trace_sample, tail_k=cfg.trace_tail_k,
                seed=cfg.seed))

        m = cfg.n_members
        r = min(max(1, int(round(cfg.reserved_fraction * m))), m - 1)
        self.reserved_members = list(range(m - r, m))
        self.spray_members = list(range(m - r))
        # isolation OFF: both calendars span the whole farm — elephants and
        # mice share every downlink and queue (the control group)
        self._lane_sets = ((self.spray_members, self.reserved_members)
                           if cfg.isolate
                           else (list(range(m)), list(range(m))))

        self.live: list[int] = list(range(cfg.k_lbs))
        self.killed: list[int] = []
        self.daemon = None
        self.client = None
        self.fabric_id = ""
        self.tokens: list[tuple[str, str]] = []
        if cfg.controld:
            self._start_controld()
        else:
            self.managers = []
            for _lb in range(cfg.k_lbs):
                for members in self._lane_sets:
                    em = EpochManager(max_members=max(64, 4 * m))
                    cp = LoadBalancerControlPlane(em)
                    cp.policy.epoch_horizon = max(
                        16, 8 * cfg.triggers_per_step)
                    cp.start({mm: MemberSpec(node_id=mm, lane_bits=1)
                              for mm in members})
                    self.managers.append(em)
        self._dp_cache = DataPlaneCache(self.managers, backend=cfg.backend)

        # -- plant ------------------------------------------------------------
        self.daq_scale = (np.ones(cfg.n_daqs)
                          if cfg.daq_scale is None
                          else np.asarray(cfg.daq_scale, np.float64))
        if self.daq_scale.shape != (cfg.n_daqs,):
            raise ValueError("daq_scale must be one multiplier per DAQ")
        self.daq_uplinks = LinkSet([
            dataclasses.replace(cfg.daq_uplink, seed=cfg.seed + 11)
            for _ in range(cfg.n_daqs)])
        self.lb_ingress = LinkSet([
            dataclasses.replace(cfg.lb_ingress, seed=cfg.seed + 23)
            for _ in range(cfg.k_lbs)])
        self.lb_fabric = LinkSet([
            dataclasses.replace(cfg.lb_fabric, seed=cfg.seed + 37)
            for _ in range(cfg.k_lbs)])
        self.member_links = LinkSet([
            dataclasses.replace(cfg.member_link, seed=cfg.seed + 53)
            for _ in range(m)])
        self.farm = FarmQueues(
            FarmConfig.uniform(m, per_packet_s=cfg.service_per_packet_s,
                               per_byte_s=cfg.service_per_byte_s,
                               capacity_s=cfg.queue_capacity_s),
            backend=cfg.queue_engine)
        self.detector = ElephantDetector(cfg.n_daqs, cfg.detector)

        # -- accounting -------------------------------------------------------
        self.event_base = 1
        self.segments_sent = 0
        self.segments_served = 0
        self.lost_uplink = 0
        self.lost_ingress = 0
        self.lost_fabric = 0
        self.discarded = 0
        self.lost_downlink = 0
        self.dropped_queue = 0
        self.bundles_sent = 0
        self.bundles_completed = 0
        self.bundles_lost = 0
        self.lat_mice: list[float] = []
        self.lat_elephant: list[float] = []
        self.lb_load_bytes = np.zeros(cfg.k_lbs, np.float64)
        self.total_wire_bytes = 0.0
        self.event_members: dict[tuple[int, int], set[int]] = defaultdict(set)

        # -- fabric gauges on the PR-7 metrics registry -----------------------
        self._g_load = None
        self._g_elephants = None
        if metrics is not None:
            g = metrics.gauge("fabric_lb_load",
                              "Bytes traversing each LB instance.",
                              labelnames=("lb",))
            self._g_load = [g.labels(lb=str(j)) for j in range(cfg.k_lbs)]
            self._g_elephants = metrics.gauge(
                "fabric_elephants",
                "DAQ streams currently classified as elephants.")

    # -- controld: the fabric as a first-class tenant -------------------------
    def _start_controld(self) -> None:
        from repro.controld import (ControlDaemon, ControldClient,
                                    InProcTransport, Journal)
        cfg = self.cfg
        lease = (cfg.lease_s if cfg.lease_s is not None
                 else 10.0 * cfg.steps * cfg.window_period_s())
        self.daemon = ControlDaemon(
            n_instances=2 * cfg.k_lbs, clock=self.clock.now, lease_s=lease,
            epoch_horizon=max(16, 8 * cfg.triggers_per_step),
            max_members=max(64, 4 * cfg.n_members), journal=Journal(),
            trace=self.trace)
        self.client = ControldClient(InProcTransport(self.daemon))
        fab = self.client.reserve_fabric(
            k=cfg.k_lbs, policy=cfg.controld_policy,
            reserved_fraction=cfg.reserved_fraction)
        self.fabric_id = fab["fabric"]
        for sess, members in zip(
                fab["sessions"],
                [self._lane_sets] * cfg.k_lbs):
            spray_set, reserved_set = members
            for token, ids in ((sess["spray"], spray_set),
                               (sess["reserved"], reserved_set)):
                reg = self.client.register_batch(token, ids, lane_bits=1)
                assert not reg["rejected"], reg["rejected"]
            self.tokens.append((sess["spray"], sess["reserved"]))
        self.client.tick(current_event=0)   # starts every session
        # ReserveFabric pops instances in (lb, class) order, so session
        # managers stack exactly as instance_id = lb*2 + class
        self.managers = [self.daemon.sessions[t].manager
                         for pair in self.tokens for t in pair]

    def kill_lb(self, lb: int) -> None:
        """Fail one tier member at a window boundary (hit-less: windows are
        atomic, and the spray plane re-indexes over the survivors). In
        controld mode the dead LB's members drain via one DeregisterBatch
        frame per lane class and both sessions are freed."""
        if lb not in self.live:
            return
        if len(self.live) == 1:
            raise ValueError("cannot kill the last live LB")
        self.live.remove(lb)
        self.killed.append(lb)
        if self.client is not None:
            spray_set, reserved_set = self._lane_sets
            for token, ids in ((self.tokens[lb][0], spray_set),
                               (self.tokens[lb][1], reserved_set)):
                self.client.deregister_batch(token, ids)
                self.client.free(token)

    # -- one window -----------------------------------------------------------
    def step(self, step_idx: int) -> None:
        cfg = self.cfg
        if self.scenario is not None and self.scenario.on_step is not None:
            self.scenario.on_step(self, step_idx)
        t_triggers, d = cfg.triggers_per_step, cfg.n_daqs
        t0 = self.clock.now()
        window_s = cfg.window_period_s()

        # classes come from the detector state as of the PREVIOUS window —
        # classification is causal, never clairvoyant
        elephant_daq = self.detector.elephant

        # -- emission: one bundle per (trigger, DAQ) --------------------------
        ev = (self.event_base + np.arange(t_triggers)).astype(np.uint64)
        self.event_base += t_triggers
        ev_b = np.repeat(ev, d)
        daq_b = np.tile(np.arange(d, dtype=np.int64), t_triggers)
        size_b = np.maximum(
            (cfg.mean_bundle_bytes * self.daq_scale[daq_b]
             * self.rng.gamma(4.0, 0.25, size=len(ev_b))).astype(np.int64),
            64)
        t_emit_b = t0 + np.repeat(np.arange(t_triggers), d) * cfg.trigger_period_s
        klass_b = elephant_daq[daq_b].astype(np.int64)
        inter_b, owner_b, entropy_b = spray_paths(
            ev_b, daq_b, self.live, mode=cfg.mode, seed=cfg.seed)
        tb = self.trace
        if tb is not None:
            key_b = bundle_key(ev_b, daq_b)
            tb.record_window("emit_wait", key_b,
                             np.full(len(ev_b), t0), t_emit_b,
                             aux=klass_b)

        # -- segmentation (struct-of-arrays, one repeat) ----------------------
        nseg_b = np.maximum(
            -(-size_b // cfg.mtu_payload), 1).astype(np.int64)
        bidx = np.repeat(np.arange(len(ev_b)), nseg_b)
        n = len(bidx)
        seg_in_b = np.arange(n) - np.repeat(np.cumsum(nseg_b) - nseg_b,
                                            nseg_b)
        is_last = seg_in_b == nseg_b[bidx] - 1
        payload = np.where(
            is_last, size_b[bidx] - (nseg_b[bidx] - 1) * cfg.mtu_payload,
            cfg.mtu_payload)
        wire = payload.astype(np.float64) + WIRE_OVERHEAD
        self.segments_sent += n
        self.bundles_sent += len(ev_b)
        self.total_wire_bytes += float(wire.sum())
        if tb is not None:
            key_s = key_b[bidx]
            pid_s = np.uint64(self._trace_pid0) + np.arange(n, dtype=np.uint64)
            self._trace_pid0 += n

        # -- DAQ uplink -------------------------------------------------------
        rows = np.arange(n)
        t_arr, keep = self.daq_uplinks.transit(
            daq_b[bidx], t_emit_b[bidx], wire)
        self.lost_uplink += int((~keep).sum())
        rows, t_now = rows[keep], t_arr[keep]
        if tb is not None:
            tb.record_window("uplink", key_s[rows], t_emit_b[bidx[rows]],
                             t_now, pid=pid_s[rows], aux=daq_b[bidx[rows]])

        # -- phase 1: ingress trunk of the intermediate LB --------------------
        inter_s = inter_b[bidx]
        owner_s = owner_b[bidx]
        t_arr, keep = self.lb_ingress.transit(
            inter_s[rows], t_now, wire[rows])
        self.lost_ingress += int((~keep).sum())
        t_in = t_now
        rows, t_now = rows[keep], t_arr[keep] + cfg.lb_latency_s
        self.lb_load_bytes += np.bincount(
            inter_s[rows], weights=wire[rows], minlength=cfg.k_lbs)
        if tb is not None:
            # per-LB + per-class span: aux is the stacked instance id
            tb.record_window("lb", key_s[rows], t_in[keep], t_now,
                             pid=pid_s[rows],
                             aux=inter_s[rows] * 2 + klass_b[bidx[rows]])

        # -- phase 2: inter-LB fabric hop for two-hop rows --------------------
        two_hop = inter_s[rows] != owner_s[rows]
        sub = rows[two_hop]
        if len(sub):
            t_fab, keep_fab = self.lb_fabric.transit(
                inter_s[sub], t_now[two_hop], wire[sub])
            self.lost_fabric += int((~keep_fab).sum())
            landed = sub[keep_fab]
            self.lb_load_bytes += np.bincount(
                owner_s[landed], weights=wire[landed],
                minlength=cfg.k_lbs)
            keep_all = np.ones(len(rows), bool)
            keep_all[two_hop] = keep_fab
            t_merged = t_now.copy()
            t_merged[two_hop] = t_fab + cfg.lb_latency_s
            if tb is not None and len(landed):
                # two-hop rows get a distinct "fabric" span, so VLB paths
                # show up as a deeper span tree than direct one-hop rows
                tb.record_window(
                    "fabric", key_s[landed], t_now[two_hop][keep_fab],
                    t_fab[keep_fab] + cfg.lb_latency_s, pid=pid_s[landed],
                    aux=owner_s[landed] * 2 + klass_b[bidx[landed]])
            rows, t_now = rows[keep_all], t_merged[keep_all]

        # -- the owner's calendar: the production routing engine --------------
        if len(rows):
            iid = (owner_s[rows] * 2 + klass_b[bidx[rows]]).astype(np.int32)
            member, valid = self._route(ev_b[bidx[rows]],
                                        entropy_b[bidx[rows]], iid)
            self.discarded += int((~valid).sum())
            # event-affinity audit on unique (instance, event, member)
            # triples — O(#bundles) host work, never O(#segments)
            rows_v = np.flatnonzero(valid)
            triples = np.unique(np.stack(
                [iid[rows_v].astype(np.uint64),
                 ev_b[bidx[rows[rows_v]]],
                 member[rows_v].astype(np.uint64)], axis=1), axis=0)
            for i, e, mm in triples.tolist():
                self.event_members[(int(i), int(e))].add(int(mm))
            rows, t_now, member = (rows[valid], t_now[valid],
                                   member[rows_v].astype(np.int64))

        # -- downlink + bounded CN queue --------------------------------------
        if len(rows):
            t_arr, keep = self.member_links.transit(member, t_now, wire[rows])
            self.lost_downlink += int((~keep).sum())
            t_in = t_now
            rows, t_now, member = rows[keep], t_arr[keep], member[keep]
            if tb is not None:
                tb.record_window("downlink", key_s[rows], t_in[keep], t_now,
                                 pid=pid_s[rows], aux=member)
        if len(rows):
            served = self.farm.serve(member, t_now, wire[rows])
            acc = ~served.dropped
            self.dropped_queue += int(served.dropped.sum())
            if tb is not None and acc.any():
                svc = self.farm.service_time(member[acc], wire[rows][acc])
                dep_a = served.depart[acc]
                tb.record_window("farm_wait", key_s[rows[acc]], t_now[acc],
                                 dep_a - svc, pid=pid_s[rows[acc]],
                                 aux=member[acc])
                tb.record_window("service", key_s[rows[acc]], dep_a - svc,
                                 dep_a, pid=pid_s[rows[acc]],
                                 aux=member[acc])
            rows, dep = rows[acc], served.depart[acc]
        else:
            dep = np.empty((0,), np.float64)
        self.segments_served += len(rows)

        # -- bundle completion: all segments served ---------------------------
        nb = len(ev_b)
        got = np.bincount(bidx[rows], minlength=nb)
        done = got == nseg_b
        if done.any():
            t_done = np.full(nb, -np.inf)
            np.maximum.at(t_done, bidx[rows], dep)
            lat = t_done[done] - t_emit_b[done]
            kd = klass_b[done]
            self.lat_mice.extend(lat[kd == 0].tolist())
            self.lat_elephant.extend(lat[kd == 1].tolist())
            if tb is not None:
                rmin = np.full(nb, np.inf)
                np.minimum.at(rmin, bidx[rows], dep)
                tb.record_window("reassembly", key_b[done], rmin[done],
                                 t_done[done], aux=klass_b[done])
                tb.complete_window(key_b[done], t_emit_b[done], t_done[done])
        self.bundles_completed += int(done.sum())
        self.bundles_lost += int(nb - done.sum())

        # -- detector + gauges at the window boundary -------------------------
        emitted = np.bincount(daq_b[bidx], weights=wire, minlength=d)
        mask = self.detector.update(emitted, window_s)
        if self._g_load is not None:
            for j, g in enumerate(self._g_load):
                g.set(float(self.lb_load_bytes[j]))
            self._g_elephants.set(float(mask.sum()))

        self.clock.advance_to(t0 + window_s)
        if tb is not None:
            tb.end_window()
        if (self.client is not None and cfg.tick_every
                and (step_idx + 1) % cfg.tick_every == 0):
            if tb is not None:
                self.client.trace = trace_id((1 << 62) | step_idx)
            self.client.tick(current_event=int(self.event_base))

    def _route(self, ev, entropy, iid) -> tuple[np.ndarray, np.ndarray]:
        """Route one window through the stacked calendars, padded to a
        power of two so window-size jitter doesn't grow the jit cache
        (padding rows route harmlessly and are sliced away)."""
        n = len(ev)
        size = next_pow2(n)
        ev_p = np.zeros(size, np.uint64)
        en_p = np.zeros(size, np.uint32)
        iid_p = np.zeros(size, np.int32)
        ev_p[:n], en_p[:n], iid_p[:n] = ev, entropy, iid
        r = self._dp_cache.get().route_events(ev_p, en_p, instance_id=iid_p)
        return (np.asarray(r.member)[:n],
                np.asarray(r.valid)[:n].astype(bool))

    # -- whole run ------------------------------------------------------------
    def run(self) -> FabricReport:
        t_wall = time.perf_counter()
        for i in range(self.cfg.steps):
            self.step(i)
        wall = time.perf_counter() - t_wall

        violations = []
        split = sum(1 for ms in self.event_members.values() if len(ms) > 1)
        if split:
            violations.append(
                f"{split} (instance, event) pairs split across members")
        accounted = (self.segments_served + self.lost_uplink
                     + self.lost_ingress + self.lost_fabric + self.discarded
                     + self.lost_downlink + self.dropped_queue)
        if accounted != self.segments_sent:
            violations.append(
                f"segment conservation broken: {self.segments_sent} sent, "
                f"{accounted} accounted")
        if self.bundles_completed + self.bundles_lost != self.bundles_sent:
            violations.append("bundle conservation broken")
        for lb in self.killed:
            if self.lb_load_bytes[lb] > 0 and lb in self.live:
                violations.append(f"killed LB {lb} still live")

        lat_all = np.asarray(self.lat_mice + self.lat_elephant)
        lat_m = np.asarray(self.lat_mice)
        lat_e = np.asarray(self.lat_elephant)
        total = max(self.total_wire_bytes, 1.0)
        return FabricReport(
            scenario=self.scenario.name if self.scenario else "custom",
            steps=self.cfg.steps,
            mode=self.cfg.mode,
            isolate=self.cfg.isolate,
            k_lbs=self.cfg.k_lbs,
            sim_time_s=self.clock.now(),
            wall_s=wall,
            segments_sent=self.segments_sent,
            segments_served=self.segments_served,
            lost_uplink=self.lost_uplink,
            lost_ingress=self.lost_ingress,
            lost_fabric=self.lost_fabric,
            discarded_invalid=self.discarded,
            lost_downlink=self.lost_downlink,
            dropped_queue=self.dropped_queue,
            bundles_sent=self.bundles_sent,
            bundles_completed=self.bundles_completed,
            bundles_lost=self.bundles_lost,
            latency_p50_s=_pct(lat_all, 50),
            latency_p99_s=_pct(lat_all, 99),
            latency_max_s=float(lat_all.max()) if len(lat_all) else 0.0,
            mice_completed=len(lat_m),
            mice_p50_s=_pct(lat_m, 50),
            mice_p99_s=_pct(lat_m, 99),
            elephant_completed=len(lat_e),
            elephant_p50_s=_pct(lat_e, 50),
            elephant_p99_s=_pct(lat_e, 99),
            lb_load_bytes=[round(float(b), 1) for b in self.lb_load_bytes],
            max_lb_load_frac=float(self.lb_load_bytes.max()) / total,
            elephants_detected=int(self.detector.ever_elephant.sum()),
            detector_transitions=self.detector.transitions,
            lbs_killed=list(self.killed),
            violations=violations,
        )
