"""Elephant-flow detection: per-stream EWMA byte rate with hysteresis.

RDNA Balance (PAPERS.md) isolates heavy flows by *strict source routing*
them onto paths mice never share. The detector here is its control half:
each DAQ stream's byte rate is tracked as an exponentially weighted moving
average, and a stream is promoted to *elephant* when the EWMA crosses
``hi_Bps`` — then stays one until it falls below ``lo_Bps``. The two
thresholds are the hysteresis band: a stream hovering between them keeps
its current class, so the classifier cannot flap packet classes (and with
them, calendar lanes) at the boundary. Promotion/demotion happens at
window boundaries only — mid-window every bundle of a stream shares one
class, which is what keeps the lane assignment per-bundle-atomic.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ElephantConfig:
    """Hysteresis thresholds + smoothing for the per-stream rate EWMA."""

    hi_Bps: float = 30e6      # promote to elephant above this EWMA rate
    lo_Bps: float = 15e6      # demote below this (hysteresis band between)
    alpha: float = 0.3        # EWMA weight of the newest window

    def __post_init__(self) -> None:
        if not (self.hi_Bps > self.lo_Bps > 0.0):
            raise ValueError(
                f"need hi_Bps > lo_Bps > 0, got hi={self.hi_Bps!r} "
                f"lo={self.lo_Bps!r}")
        if not (0.0 < self.alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha!r}")


class ElephantDetector:
    """Stateful per-stream classifier; one vectorized update per window."""

    def __init__(self, n_streams: int, cfg: ElephantConfig | None = None):
        self.cfg = cfg or ElephantConfig()
        self.n_streams = int(n_streams)
        self.ewma_Bps = np.zeros(self.n_streams, np.float64)
        self.elephant = np.zeros(self.n_streams, bool)
        self.ever_elephant = np.zeros(self.n_streams, bool)
        self.transitions = 0      # total class flips (flap telemetry)
        self.n_windows = 0

    def update(self, window_bytes: np.ndarray, window_s: float) -> np.ndarray:
        """Fold one window's per-stream byte counts into the EWMA and
        return the updated elephant mask (a copy; safe to keep)."""
        rate = np.asarray(window_bytes, np.float64) / max(window_s, 1e-12)
        if rate.shape != (self.n_streams,):
            raise ValueError(
                f"expected [{self.n_streams}] byte counts, got {rate.shape}")
        a = self.cfg.alpha
        self.ewma_Bps = a * rate + (1.0 - a) * self.ewma_Bps
        promote = ~self.elephant & (self.ewma_Bps > self.cfg.hi_Bps)
        demote = self.elephant & (self.ewma_Bps < self.cfg.lo_Bps)
        self.transitions += int(promote.sum()) + int(demote.sum())
        self.elephant = (self.elephant | promote) & ~demote
        self.ever_elephant |= self.elephant
        self.n_windows += 1
        return self.elephant.copy()
