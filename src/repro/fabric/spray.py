"""VLB spray plane: two-phase oblivious path selection across an LB tier.

Valiant load balancing (SNIPPETS §3, RotorNet lineage) routes every bundle
through a *random intermediate* LB before the hop to its owner: phase 1
spreads any traffic matrix — however skewed per-DAQ — uniformly over the
tier, and phase 2 restores event affinity. The guarantee is traffic-
*oblivious*: no LB carries more than ~2/K of the aggregate regardless of
which DAQs are hot, where direct per-DAQ hashing concentrates a hot DAQ's
entire stream on one tier member.

Both choices are pure hashes (splitmix64 finalizer over the event number),
computed **per bundle**, never per segment:

* the *owner* is a function of the event number alone, so every segment of
  an event — from any DAQ, in any window — lands at the same owning LB and
  one calendar decides its member (fabric-wide event affinity);
* the *intermediate* mixes in the DAQ id, so one event's bundles from
  different DAQs take decorrelated phase-1 paths, but all segments of one
  bundle share a path and arrive in FIFO order for reassembly.

Hashing over the **live** tier (rank-indexed, not id-modulo) is what makes
``lb_node_failure`` re-spray hit-less: kill a tier member and the same
hash keys re-index over the survivors — deterministically, so a re-run
reproduces the exact re-spray (the digest-identical audit in
tests/test_fabric.py).
"""
from __future__ import annotations

import numpy as np

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
_DAQ_SALT = np.uint64(0xD6E8FEB86659FD93)


def mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64 (wrapping arithmetic)."""
    z = np.asarray(x, np.uint64)
    with np.errstate(over="ignore"):
        z = z + _GOLDEN
        z = (z ^ (z >> np.uint64(30))) * _MIX1
        z = (z ^ (z >> np.uint64(27))) * _MIX2
        return z ^ (z >> np.uint64(31))


def spray_keys(event_numbers: np.ndarray, daq_ids: np.ndarray,
               seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Per-bundle ``(bundle_key, owner_key)`` uint64 hash pair.

    ``owner_key`` depends on the event number only (fabric-wide event
    affinity); ``bundle_key`` mixes in the DAQ id so phase-1 spray is
    decorrelated across a single event's bundles.
    """
    ev = np.asarray(event_numbers, np.uint64)
    dq = np.asarray(daq_ids, np.uint64)
    s = np.uint64(int(seed) & 0xFFFFFFFFFFFFFFFF)
    with np.errstate(over="ignore"):
        owner_key = mix64(ev ^ (s * _GOLDEN))
        bundle_key = mix64(ev ^ ((dq + np.uint64(1)) * _DAQ_SALT) ^ s)
    return bundle_key, owner_key


def spray_paths(event_numbers: np.ndarray, daq_ids: np.ndarray,
                live_lbs, *, mode: str = "vlb",
                seed: int = 0) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Choose ``(intermediate_lb, owner_lb, entropy)`` for each bundle.

    ``live_lbs`` is the ordered list of surviving tier members; hashes
    index its *ranks*, so the mapping is deterministic for a given live
    set. ``mode='vlb'`` is the two-phase spray; ``mode='direct'`` is the
    strawman it is gated against — static per-DAQ assignment (one hop,
    intermediate == owner), the "hash the source" scheme that concentrates
    a hot DAQ on one LB. ``entropy`` (u16, from the bundle key) rides in
    the LB header so all of a bundle's segments pick the same lane.
    """
    live = np.asarray(live_lbs, np.int64)
    n_live = len(live)
    if n_live == 0:
        raise ValueError("no live LB instances to spray across")
    bundle_key, owner_key = spray_keys(event_numbers, daq_ids, seed)
    entropy = (bundle_key & np.uint64(0xFFFF)).astype(np.uint32)
    if mode == "direct":
        lb = live[(np.asarray(daq_ids, np.int64) % n_live)]
        return lb, lb, entropy
    if mode != "vlb":
        raise ValueError(f"unknown spray mode {mode!r}")
    n = np.uint64(n_live)
    inter = live[(bundle_key % n).astype(np.int64)]
    owner = live[(owner_key % n).astype(np.int64)]
    return inter, owner, entropy
