"""repro.fabric — two-tier LB fabric (DESIGN.md §Fabric).

A fleet of DAQs sprays event bundles across a tier of K LB instances via
two-phase Valiant load balancing (random intermediate, then direct to the
owning instance; per-bundle spray keys keep a bundle's segments on one
path), while an elephant-flow detector strict-source-routes heavy streams
onto reserved calendar lanes so mice never share a queue with them.
"""
from repro.fabric.elephant import ElephantConfig, ElephantDetector
from repro.fabric.scenarios import FABRIC_SCENARIOS, get_fabric_scenario
from repro.fabric.sim import (FabricConfig, FabricReport, FabricScenario,
                              FabricSim)
from repro.fabric.spray import mix64, spray_keys, spray_paths

__all__ = [
    "ElephantConfig", "ElephantDetector",
    "FABRIC_SCENARIOS", "get_fabric_scenario",
    "FabricConfig", "FabricReport", "FabricScenario", "FabricSim",
    "mix64", "spray_keys", "spray_paths",
]
