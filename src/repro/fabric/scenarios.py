"""Named fabric scenarios — each one encodes a gate from ISSUE.md.

* ``vlb_spray``: one white-hot DAQ (16x the rest). Direct per-DAQ hashing
  concentrates ~3/4 of the aggregate on one LB; the VLB gate is that the
  two-phase spray's max-LB load share stays at or below direct's.
* ``elephant_mice``: one elephant stream among mice. Run twice (isolation
  on/off); the gate is mice p99 strictly better with isolation ON.
* ``lb_node_failure``: lossless links, kill a tier member mid-run. Gate:
  zero lost bundles and a clean invariant audit (windows are atomic, the
  spray plane re-indexes over survivors).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.fabric.elephant import ElephantConfig
from repro.fabric.sim import FabricScenario
from repro.simnet.links import LinkConfig


def _hot_daq(scale: float):
    def make(n_daqs: int) -> np.ndarray:
        s = np.ones(n_daqs)
        s[0] = scale
        return s
    return make


def _kill_midrun(sim, step: int) -> None:
    if step == sim.cfg.steps // 2 and len(sim.live) > 1:
        sim.kill_lb(sim.live[0])


FABRIC_SCENARIOS: dict[str, FabricScenario] = {
    "vlb_spray": FabricScenario(
        name="vlb_spray",
        description="Skewed DAQ load; VLB spray must beat direct hashing "
                    "on max-LB load share.",
        overrides=dict(
            steps=40, k_lbs=4, n_members=16, n_daqs=8,
            triggers_per_step=4, trigger_period_s=1e-3,
            mean_bundle_bytes=12_000, seed=7,
        ),
        daq_scale=_hot_daq(16.0),
    ),
    "elephant_mice": FabricScenario(
        name="elephant_mice",
        description="One elephant stream among mice; reserved-lane "
                    "isolation must cut mice p99.",
        overrides=dict(
            steps=50, k_lbs=2, n_members=8, n_daqs=6,
            triggers_per_step=4, trigger_period_s=1e-3,
            mean_bundle_bytes=12_000, seed=11,
            reserved_fraction=0.25,
            detector=ElephantConfig(hi_Bps=30e6, lo_Bps=15e6, alpha=0.3),
        ),
        daq_scale=_hot_daq(6.0),
    ),
    "lb_node_failure": FabricScenario(
        name="lb_node_failure",
        description="Kill one LB tier member mid-run on lossless links; "
                    "re-spray must be hit-less (zero lost bundles).",
        overrides=dict(
            steps=30, k_lbs=4, n_members=16, n_daqs=8,
            triggers_per_step=4, trigger_period_s=1e-3,
            mean_bundle_bytes=8_000, seed=3,
            daq_uplink=LinkConfig(rate_Bps=400e6, jitter_s=1e-5),
            lb_ingress=LinkConfig(rate_Bps=400e6, prop_delay_s=2e-4,
                                  jitter_s=1e-5),
            lb_fabric=LinkConfig(rate_Bps=400e6, prop_delay_s=5e-5,
                                 jitter_s=1e-5),
            member_link=LinkConfig(rate_Bps=100e6, prop_delay_s=5e-5,
                                   jitter_s=1e-5),
            queue_capacity_s=10.0,
        ),
        on_step=_kill_midrun,
    ),
}


def get_fabric_scenario(name: str) -> FabricScenario:
    try:
        return dataclasses.replace(FABRIC_SCENARIOS[name])
    except KeyError:
        raise KeyError(
            f"unknown fabric scenario {name!r}; "
            f"have {sorted(FABRIC_SCENARIOS)}") from None
