"""hubert-xlarge [audio]: 48L d_model=1280 16H (MHA kv=16) d_ff=5120
vocab=504 — encoder-only; frame-embedding frontend is a STUB
(input_specs provides precomputed frame embeddings).
[arXiv:2106.07447; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab=504, causal=False, act="gelu",
)


def smoke_config():
    return ModelConfig(
        name="hubert-smoke", family="audio",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=32, causal=False, act="gelu", dtype="float32",
    )
