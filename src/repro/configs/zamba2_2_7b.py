"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (MHA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 blocks + shared attention block applied
after every 6 mamba blocks (one shared param set). [arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, attn_every=6, ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    head_dim=80,
)


def smoke_config():
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, attn_every=2, ssm_state=8, ssm_expand=2, ssm_head_dim=16,
        head_dim=16, dtype="float32",
    )
