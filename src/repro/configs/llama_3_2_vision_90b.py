"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attn image layers every 10th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, rope_theta=5e5,
    cross_attn_every=10, n_vision_tokens=1601,
)


def smoke_config():
    return ModelConfig(
        name="llama-vision-smoke", family="vlm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=256, cross_attn_every=2, n_vision_tokens=16, dtype="float32",
    )
