"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 —
Finch, data-dependent decay. [arXiv:2404.05892; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=14336,
    vocab=65536, ssm_head_dim=64,
)


def smoke_config():
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=0, n_kv_heads=0, d_ff=128,
        vocab=256, ssm_head_dim=16, dtype="float32",
    )
