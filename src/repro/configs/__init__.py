"""Assigned architecture registry: --arch <id> selects one of these.

Each module defines CONFIG (exact assigned config) and smoke_config()
(reduced same-family config for CPU tests). Sources per the assignment
table; see DESIGN.md §4 for applicability notes.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "llama_3_2_vision_90b",
    "arctic_480b",
    "mixtral_8x22b",
    "granite_20b",
    "stablelm_3b",
    "chatglm3_6b",
    "yi_6b",
    "hubert_xlarge",
    "zamba2_2_7b",
    "rwkv6_7b",
]

def _normalize(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{_normalize(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{_normalize(arch)}")
    return mod.smoke_config()
