"""Pluggable reweighting policies for the control plane.

``LoadBalancerControlPlane.update_weights`` historically hard-coded one PI
update; that logic now lives here as ``ProportionalPolicy`` (bit-identical
semantics, extracted verbatim) and the layer is pluggable per controld
reservation: a tenant picks its controller at ``Reserve`` time.

``PIDFillPolicy`` is the EJFAT-style per-member PID fill controller (the
real control plane runs PID loops on CN fill level): proportional + integral
+ derivative on the fill error, with

* **output clamping** — the per-update control action ``u`` is clamped to
  ``±output_limit`` so one noisy sample can never slam a member's share;
* **anti-windup by back-calculation** — when the output clamps, the integral
  is rewound to the value that exactly saturates it (plus a hard
  ``±integral_limit`` clip), so sustained saturation cannot wind the
  integral up and the controller recovers without lag;
* **calendar normalization** — weights are only meaningful relatively
  (calendar share = w / sum w), so both policies renormalize live members to
  mean 1 before clamping into ``[min_weight, max_weight]`` — the same
  finalize step, which is why a zero-error PID reproduces the proportional
  policy's fixed point exactly (property-tested in tests/test_controld.py).

Policies duck-type telemetry (``.fill`` / ``.healthy`` attributes, i.e.
``MemberTelemetry``) and expose ``state()``/``load_state()`` so the controld
journal can replay a daemon to byte-identical controller state.

**Array-native path** (the perf hot path): ``update_lanes`` runs the same
controller over ``[M]`` lanes at once — weights, fill, health, integral and
derivative state all as arrays — in one fused pass instead of M scalar
dict updates. Two engines:

* ``engine="np"`` — vectorized float64 numpy, **bit-identical** to the
  scalar dict path (same elementwise IEEE ops, same pairwise-summation
  mean over live members in the same lane order). This is what the daemon
  runs per Tick, so journal replay stays byte-identical.
* ``engine="jnp"`` — one fused, jitted jax kernel: a 10k-member farm's
  whole policy update is a single device call (float32 on device, so
  property-equal to the oracle within float tolerance, not bitwise).
  ``FUSED_KERNEL_CALLS`` counts device dispatches so benchmarks can prove
  the single-call claim.

The scalar ``update`` stays as the reference oracle; the lanes path is
property-tested element-wise against it (tests/test_controld.py), including
missing/stale members, drains, and saturation/anti-windup edges.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: number of fused jnp kernel dispatches (device calls) since import —
#: benchmarks diff this around an update to prove "one device call per tick"
FUSED_KERNEL_CALLS = 0

_PROP_JIT = None
_PID_JIT = None


def _finalize_lanes(xp, new, min_w, max_w):
    """Jit-safe calendar normalization (no boolean compression): live mean
    via masked sum / count, then the same clamp as ``_finalize``."""
    live = new > 0
    cnt = xp.sum(live)
    mean = xp.where(cnt > 0,
                    xp.sum(xp.where(live, new, 0.0)) / xp.maximum(cnt, 1),
                    1.0)
    scaled = xp.clip(new / xp.maximum(mean, 1e-9), min_w, max_w)
    return xp.where(live, scaled, new)


def _finalize_np(new, min_w, max_w):
    """Exact-parity finalize: ``np.mean`` over the live lanes in lane order
    is the same pairwise summation the scalar ``_finalize`` performs over
    its python list, so the np engine matches the oracle bitwise."""
    live = new > 0
    mean = float(np.mean(new[live])) if live.any() else 1.0
    scaled = np.clip(new / max(mean, 1e-9), min_w, max_w)
    return np.where(live, scaled, new)


def _prop_np(weights, fill, healthy, present, integral, p):
    err = p.target_fill - fill
    integ = np.clip(integral + p.ki * err, -1.0, 1.0)
    upd = healthy & present
    new = np.where(upd, weights * np.maximum(1.0 + p.kp * err + integ, 0.1),
                   np.where(present, 0.0, weights))
    return (_finalize_np(new, p.min_weight, p.max_weight),
            np.where(upd, integ, integral))


def _pid_np(weights, fill, healthy, present, integral, prev_err, has_prev, p):
    err = p.target_fill - fill
    d_err = np.where(has_prev, err - prev_err, 0.0)
    integ = np.clip(integral + p.ki * err,
                    -p.integral_limit, p.integral_limit)
    u_raw = p.kp * err + integ + p.kd * d_err
    u = np.clip(u_raw, -p.output_limit, p.output_limit)
    integ = np.where(u != u_raw,
                     np.clip(u - p.kp * err - p.kd * d_err,
                             -p.integral_limit, p.integral_limit), integ)
    upd = healthy & present
    new = np.where(upd, weights * np.maximum(1.0 + u, 0.1),
                   np.where(present, 0.0, weights))
    return (_finalize_np(new, p.min_weight, p.max_weight),
            np.where(upd, integ, integral),
            np.where(upd, err, prev_err),
            has_prev | upd)


def _fused_kernels():
    """Build (once) the jitted [M]-lane kernels. Gains travel as a traced
    array argument, so one trace serves every PolicyConfig and every lane
    count M gets exactly one compile."""
    global _PROP_JIT, _PID_JIT
    if _PROP_JIT is None:
        import jax
        import jax.numpy as jnp

        def prop(weights, fill, healthy, present, integral, gains):
            target, kp, ki, min_w, max_w = (gains[0], gains[1], gains[2],
                                            gains[3], gains[4])
            err = target - fill
            integ = jnp.clip(integral + ki * err, -1.0, 1.0)
            upd = healthy & present
            new = jnp.where(
                upd, weights * jnp.maximum(1.0 + kp * err + integ, 0.1),
                jnp.where(present, 0.0, weights))
            return (_finalize_lanes(jnp, new, min_w, max_w),
                    jnp.where(upd, integ, integral))

        def pid(weights, fill, healthy, present, integral, prev_err,
                has_prev, gains):
            (target, kp, ki, kd, min_w, max_w, int_lim, out_lim) = (
                gains[0], gains[1], gains[2], gains[3], gains[4], gains[5],
                gains[6], gains[7])
            err = target - fill
            d_err = jnp.where(has_prev, err - prev_err, 0.0)
            integ = jnp.clip(integral + ki * err, -int_lim, int_lim)
            u_raw = kp * err + integ + kd * d_err
            u = jnp.clip(u_raw, -out_lim, out_lim)
            integ = jnp.where(u != u_raw,
                              jnp.clip(u - kp * err - kd * d_err,
                                       -int_lim, int_lim), integ)
            upd = healthy & present
            new = jnp.where(upd, weights * jnp.maximum(1.0 + u, 0.1),
                            jnp.where(present, 0.0, weights))
            return (_finalize_lanes(jnp, new, min_w, max_w),
                    jnp.where(upd, integ, integral),
                    jnp.where(upd, err, prev_err),
                    has_prev | upd)

        _PROP_JIT = jax.jit(prop)
        _PID_JIT = jax.jit(pid)
    return _PROP_JIT, _PID_JIT


@dataclasses.dataclass
class PolicyConfig:
    """Shared controller shape. ``kd``/limits only bind for the PID."""

    target_fill: float = 0.5   # setpoint for receive-queue occupancy
    kp: float = 0.5            # proportional gain on (target - fill)
    ki: float = 0.1            # integral gain
    kd: float = 0.0            # derivative gain (PID only)
    min_weight: float = 0.05   # floor so a member stays reachable
    max_weight: float = 8.0
    integral_limit: float = 1.0   # hard clip on the integral term
    output_limit: float = 2.0     # clamp on the per-update action (PID only)


class WeightPolicy:
    """Interface: ``update`` maps (weights, telemetry) -> new weights and
    carries per-member controller state across calls."""

    name = "base"

    def __init__(self, cfg: PolicyConfig | None = None):
        self.cfg = cfg or PolicyConfig()

    # -- lifecycle ----------------------------------------------------------
    def reset(self, member_ids) -> None:
        for mid in member_ids:
            self.add_member(mid)

    def add_member(self, member_id: int) -> None:  # pragma: no cover
        pass

    def forget_member(self, member_id: int) -> None:  # pragma: no cover
        pass

    # -- journal support ----------------------------------------------------
    def state(self) -> dict:
        return {}

    def load_state(self, st: dict) -> None:
        pass

    # -- the update ---------------------------------------------------------
    def update(self, weights: dict[int, float], telemetry: dict) -> dict:
        raise NotImplementedError

    # -- the array-native update --------------------------------------------
    def update_lanes(self, member_ids, weights, fill, healthy,
                     present=None, engine: str = "np") -> np.ndarray:
        """One fused policy update over ``[M]`` lanes.

        ``member_ids[i]`` names lane ``i``; ``present[i]=False`` means no
        telemetry arrived for that member this window (scalar-path
        ``t is None``: weight and controller state are left untouched),
        while ``present & ~healthy`` is an explicit drain (weight -> 0).
        Per-member controller state is gathered from / scattered back to the
        same dicts the scalar path (and the journal ``state()``) uses, so
        the two paths are interchangeable mid-stream. Returns the new
        weight array; ``engine="jnp"`` runs the whole update as one jitted
        device call."""
        raise NotImplementedError

    @staticmethod
    def _coerce_lanes(member_ids, weights, fill, healthy, present):
        ids = np.asarray(member_ids, np.int64)
        w = np.asarray(weights, np.float64)
        fill = np.asarray(fill, np.float64)
        healthy = np.asarray(healthy, bool)
        present = (np.ones(len(ids), bool) if present is None
                   else np.asarray(present, bool))
        if not (ids.shape == w.shape == fill.shape == healthy.shape
                == present.shape) or ids.ndim != 1:
            raise ValueError("lane arrays must be 1-D and the same length")
        return ids, w, fill, healthy, present

    def _gains(self, kind: str) -> np.ndarray:
        p = self.cfg
        if kind == "prop":
            vals = (p.target_fill, p.kp, p.ki, p.min_weight, p.max_weight)
        else:
            vals = (p.target_fill, p.kp, p.ki, p.kd, p.min_weight,
                    p.max_weight, p.integral_limit, p.output_limit)
        return np.asarray(vals, np.float32)

    def _gather(self, store: dict, ids: np.ndarray,
                default: float = 0.0) -> np.ndarray:
        return np.fromiter((store.get(int(m), default) for m in ids),
                           np.float64, count=len(ids))

    @staticmethod
    def _scatter(store: dict, ids: np.ndarray, values: np.ndarray,
                 mask: np.ndarray) -> None:
        if mask.any():
            store.update(zip(ids[mask].tolist(),
                             np.asarray(values, np.float64)[mask].tolist()))

    def _finalize(self, new: dict[int, float]) -> dict[int, float]:
        """Calendar normalization: renormalize live members to mean 1 so
        healthy members don't all saturate the ceiling and erase the
        straggler signal, then clamp into [min_weight, max_weight].
        Weight 0 (a deliberate drain) is preserved."""
        p = self.cfg
        live = [v for v in new.values() if v > 0]
        mean = float(np.mean(live)) if live else 1.0
        for mid in new:
            if new[mid] > 0:
                new[mid] = float(np.clip(new[mid] / max(mean, 1e-9),
                                         p.min_weight, p.max_weight))
        return new


class ProportionalPolicy(WeightPolicy):
    """The legacy PI update, extracted verbatim from
    ``LoadBalancerControlPlane.update_weights``: slow/full members shed
    slots, fast/empty members gain."""

    name = "proportional"

    def __init__(self, cfg: PolicyConfig | None = None):
        super().__init__(cfg)
        self._integral: dict[int, float] = {}

    def add_member(self, member_id: int) -> None:
        self._integral[member_id] = 0.0

    def forget_member(self, member_id: int) -> None:
        self._integral.pop(member_id, None)

    def state(self) -> dict:
        return {"integral": {str(k): v for k, v in self._integral.items()}}

    def load_state(self, st: dict) -> None:
        self._integral = {int(k): float(v)
                          for k, v in st.get("integral", {}).items()}

    def update(self, weights: dict[int, float], telemetry: dict) -> dict:
        p = self.cfg
        new = {}
        for mid, w in weights.items():
            t = telemetry.get(mid)
            if t is None or not t.healthy:
                new[mid] = 0.0 if (t is not None and not t.healthy) else w
                continue
            err = p.target_fill - t.fill  # positive => under-filled => more
            self._integral[mid] = float(
                np.clip(self._integral.get(mid, 0.0) + p.ki * err, -1.0, 1.0)
            )
            factor = 1.0 + p.kp * err + self._integral[mid]
            # Organic decay never reaches zero — weight 0 is reserved for a
            # deliberate drain (mark_failed / explicit weights).
            new[mid] = w * max(factor, 0.1)
        return self._finalize(new)

    def update_lanes(self, member_ids, weights, fill, healthy,
                     present=None, engine: str = "np") -> np.ndarray:
        ids, w, fill, healthy, present = self._coerce_lanes(
            member_ids, weights, fill, healthy, present)
        integral = self._gather(self._integral, ids)
        if engine == "jnp":
            global FUSED_KERNEL_CALLS
            prop_jit, _ = _fused_kernels()
            new, new_integral = prop_jit(
                w.astype(np.float32), fill.astype(np.float32), healthy,
                present, integral.astype(np.float32), self._gains("prop"))
            FUSED_KERNEL_CALLS += 1
            new = np.asarray(new, np.float64)
            new_integral = np.asarray(new_integral, np.float64)
        else:
            new, new_integral = _prop_np(w, fill, healthy, present,
                                         integral, self.cfg)
        self._scatter(self._integral, ids, new_integral, healthy & present)
        return new


class PIDFillPolicy(WeightPolicy):
    """EJFAT-style per-member PID on queue fill, with output clamping and
    back-calculation anti-windup (module docstring)."""

    name = "pid"

    def __init__(self, cfg: PolicyConfig | None = None):
        super().__init__(cfg)
        self._integral: dict[int, float] = {}
        self._prev_err: dict[int, float] = {}

    def add_member(self, member_id: int) -> None:
        self._integral[member_id] = 0.0
        self._prev_err.pop(member_id, None)

    def forget_member(self, member_id: int) -> None:
        self._integral.pop(member_id, None)
        self._prev_err.pop(member_id, None)

    def state(self) -> dict:
        return {"integral": {str(k): v for k, v in self._integral.items()},
                "prev_err": {str(k): v for k, v in self._prev_err.items()}}

    def load_state(self, st: dict) -> None:
        self._integral = {int(k): float(v)
                          for k, v in st.get("integral", {}).items()}
        self._prev_err = {int(k): float(v)
                          for k, v in st.get("prev_err", {}).items()}

    def update(self, weights: dict[int, float], telemetry: dict) -> dict:
        p = self.cfg
        new = {}
        for mid, w in weights.items():
            t = telemetry.get(mid)
            if t is None or not t.healthy:
                new[mid] = 0.0 if (t is not None and not t.healthy) else w
                # a silent/unhealthy member's controller state is stale, not
                # evidence — freeze it (no integration on missing samples)
                continue
            err = p.target_fill - t.fill
            # derivative on the error; first sample after (re)registration
            # contributes zero (no previous error to difference against)
            d_err = err - self._prev_err.get(mid, err)
            self._prev_err[mid] = err
            integral = float(np.clip(
                self._integral.get(mid, 0.0) + p.ki * err,
                -p.integral_limit, p.integral_limit))
            u_raw = p.kp * err + integral + p.kd * d_err
            u = float(np.clip(u_raw, -p.output_limit, p.output_limit))
            if u != u_raw:
                # back-calculation: rewind the integral to the value that
                # exactly saturates the output — windup never accumulates
                integral = float(np.clip(u - p.kp * err - p.kd * d_err,
                                         -p.integral_limit, p.integral_limit))
            self._integral[mid] = integral
            new[mid] = w * max(1.0 + u, 0.1)
        return self._finalize(new)

    def update_lanes(self, member_ids, weights, fill, healthy,
                     present=None, engine: str = "np") -> np.ndarray:
        ids, w, fill, healthy, present = self._coerce_lanes(
            member_ids, weights, fill, healthy, present)
        integral = self._gather(self._integral, ids)
        # lanes with no previous error sample difference against themselves
        # (d_err = 0), exactly like the scalar ``prev_err.get(mid, err)``
        has_prev = np.fromiter((int(m) in self._prev_err for m in ids),
                               bool, count=len(ids))
        prev_err = self._gather(self._prev_err, ids)
        if engine == "jnp":
            global FUSED_KERNEL_CALLS
            _, pid_jit = _fused_kernels()
            new, new_integral, new_prev, _ = pid_jit(
                w.astype(np.float32), fill.astype(np.float32), healthy,
                present, integral.astype(np.float32),
                prev_err.astype(np.float32), has_prev, self._gains("pid"))
            FUSED_KERNEL_CALLS += 1
            new = np.asarray(new, np.float64)
            new_integral = np.asarray(new_integral, np.float64)
            new_prev = np.asarray(new_prev, np.float64)
        else:
            new, new_integral, new_prev, _ = _pid_np(
                w, fill, healthy, present, integral, prev_err, has_prev,
                self.cfg)
        upd = healthy & present
        self._scatter(self._integral, ids, new_integral, upd)
        self._scatter(self._prev_err, ids, new_prev, upd)
        return new


POLICIES: dict[str, type[WeightPolicy]] = {
    ProportionalPolicy.name: ProportionalPolicy,
    PIDFillPolicy.name: PIDFillPolicy,
}


def make_policy(name: str, params: dict | None = None) -> WeightPolicy:
    """Build a policy by wire name with optional ``PolicyConfig`` overrides
    (unknown override keys are a protocol error, not a silent ignore)."""
    cls = POLICIES.get(name)
    if cls is None:
        raise ValueError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    cfg = PolicyConfig()
    for k, v in (params or {}).items():
        if not hasattr(cfg, k):
            raise ValueError(f"unknown policy param {k!r}")
        try:
            setattr(cfg, k, float(v))
        except (TypeError, ValueError):
            # must stay ValueError: the daemon maps it to a protocol
            # rejection that replays identically from the journal — a
            # TypeError here would crash handle() AND poison recovery
            raise ValueError(
                f"policy param {k}={v!r} is not a number") from None
    return cls(cfg)
