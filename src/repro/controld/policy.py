"""Pluggable reweighting policies for the control plane.

``LoadBalancerControlPlane.update_weights`` historically hard-coded one PI
update; that logic now lives here as ``ProportionalPolicy`` (bit-identical
semantics, extracted verbatim) and the layer is pluggable per controld
reservation: a tenant picks its controller at ``Reserve`` time.

``PIDFillPolicy`` is the EJFAT-style per-member PID fill controller (the
real control plane runs PID loops on CN fill level): proportional + integral
+ derivative on the fill error, with

* **output clamping** — the per-update control action ``u`` is clamped to
  ``±output_limit`` so one noisy sample can never slam a member's share;
* **anti-windup by back-calculation** — when the output clamps, the integral
  is rewound to the value that exactly saturates it (plus a hard
  ``±integral_limit`` clip), so sustained saturation cannot wind the
  integral up and the controller recovers without lag;
* **calendar normalization** — weights are only meaningful relatively
  (calendar share = w / sum w), so both policies renormalize live members to
  mean 1 before clamping into ``[min_weight, max_weight]`` — the same
  finalize step, which is why a zero-error PID reproduces the proportional
  policy's fixed point exactly (property-tested in tests/test_controld.py).

Policies duck-type telemetry (``.fill`` / ``.healthy`` attributes, i.e.
``MemberTelemetry``) and expose ``state()``/``load_state()`` so the controld
journal can replay a daemon to byte-identical controller state.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PolicyConfig:
    """Shared controller shape. ``kd``/limits only bind for the PID."""

    target_fill: float = 0.5   # setpoint for receive-queue occupancy
    kp: float = 0.5            # proportional gain on (target - fill)
    ki: float = 0.1            # integral gain
    kd: float = 0.0            # derivative gain (PID only)
    min_weight: float = 0.05   # floor so a member stays reachable
    max_weight: float = 8.0
    integral_limit: float = 1.0   # hard clip on the integral term
    output_limit: float = 2.0     # clamp on the per-update action (PID only)


class WeightPolicy:
    """Interface: ``update`` maps (weights, telemetry) -> new weights and
    carries per-member controller state across calls."""

    name = "base"

    def __init__(self, cfg: PolicyConfig | None = None):
        self.cfg = cfg or PolicyConfig()

    # -- lifecycle ----------------------------------------------------------
    def reset(self, member_ids) -> None:
        for mid in member_ids:
            self.add_member(mid)

    def add_member(self, member_id: int) -> None:  # pragma: no cover
        pass

    def forget_member(self, member_id: int) -> None:  # pragma: no cover
        pass

    # -- journal support ----------------------------------------------------
    def state(self) -> dict:
        return {}

    def load_state(self, st: dict) -> None:
        pass

    # -- the update ---------------------------------------------------------
    def update(self, weights: dict[int, float], telemetry: dict) -> dict:
        raise NotImplementedError

    def _finalize(self, new: dict[int, float]) -> dict[int, float]:
        """Calendar normalization: renormalize live members to mean 1 so
        healthy members don't all saturate the ceiling and erase the
        straggler signal, then clamp into [min_weight, max_weight].
        Weight 0 (a deliberate drain) is preserved."""
        p = self.cfg
        live = [v for v in new.values() if v > 0]
        mean = float(np.mean(live)) if live else 1.0
        for mid in new:
            if new[mid] > 0:
                new[mid] = float(np.clip(new[mid] / max(mean, 1e-9),
                                         p.min_weight, p.max_weight))
        return new


class ProportionalPolicy(WeightPolicy):
    """The legacy PI update, extracted verbatim from
    ``LoadBalancerControlPlane.update_weights``: slow/full members shed
    slots, fast/empty members gain."""

    name = "proportional"

    def __init__(self, cfg: PolicyConfig | None = None):
        super().__init__(cfg)
        self._integral: dict[int, float] = {}

    def add_member(self, member_id: int) -> None:
        self._integral[member_id] = 0.0

    def forget_member(self, member_id: int) -> None:
        self._integral.pop(member_id, None)

    def state(self) -> dict:
        return {"integral": {str(k): v for k, v in self._integral.items()}}

    def load_state(self, st: dict) -> None:
        self._integral = {int(k): float(v)
                          for k, v in st.get("integral", {}).items()}

    def update(self, weights: dict[int, float], telemetry: dict) -> dict:
        p = self.cfg
        new = {}
        for mid, w in weights.items():
            t = telemetry.get(mid)
            if t is None or not t.healthy:
                new[mid] = 0.0 if (t is not None and not t.healthy) else w
                continue
            err = p.target_fill - t.fill  # positive => under-filled => more
            self._integral[mid] = float(
                np.clip(self._integral.get(mid, 0.0) + p.ki * err, -1.0, 1.0)
            )
            factor = 1.0 + p.kp * err + self._integral[mid]
            # Organic decay never reaches zero — weight 0 is reserved for a
            # deliberate drain (mark_failed / explicit weights).
            new[mid] = w * max(factor, 0.1)
        return self._finalize(new)


class PIDFillPolicy(WeightPolicy):
    """EJFAT-style per-member PID on queue fill, with output clamping and
    back-calculation anti-windup (module docstring)."""

    name = "pid"

    def __init__(self, cfg: PolicyConfig | None = None):
        super().__init__(cfg)
        self._integral: dict[int, float] = {}
        self._prev_err: dict[int, float] = {}

    def add_member(self, member_id: int) -> None:
        self._integral[member_id] = 0.0
        self._prev_err.pop(member_id, None)

    def forget_member(self, member_id: int) -> None:
        self._integral.pop(member_id, None)
        self._prev_err.pop(member_id, None)

    def state(self) -> dict:
        return {"integral": {str(k): v for k, v in self._integral.items()},
                "prev_err": {str(k): v for k, v in self._prev_err.items()}}

    def load_state(self, st: dict) -> None:
        self._integral = {int(k): float(v)
                          for k, v in st.get("integral", {}).items()}
        self._prev_err = {int(k): float(v)
                          for k, v in st.get("prev_err", {}).items()}

    def update(self, weights: dict[int, float], telemetry: dict) -> dict:
        p = self.cfg
        new = {}
        for mid, w in weights.items():
            t = telemetry.get(mid)
            if t is None or not t.healthy:
                new[mid] = 0.0 if (t is not None and not t.healthy) else w
                # a silent/unhealthy member's controller state is stale, not
                # evidence — freeze it (no integration on missing samples)
                continue
            err = p.target_fill - t.fill
            # derivative on the error; first sample after (re)registration
            # contributes zero (no previous error to difference against)
            d_err = err - self._prev_err.get(mid, err)
            self._prev_err[mid] = err
            integral = float(np.clip(
                self._integral.get(mid, 0.0) + p.ki * err,
                -p.integral_limit, p.integral_limit))
            u_raw = p.kp * err + integral + p.kd * d_err
            u = float(np.clip(u_raw, -p.output_limit, p.output_limit))
            if u != u_raw:
                # back-calculation: rewind the integral to the value that
                # exactly saturates the output — windup never accumulates
                integral = float(np.clip(u - p.kp * err - p.kd * d_err,
                                         -p.integral_limit, p.integral_limit))
            self._integral[mid] = integral
            new[mid] = w * max(1.0 + u, 0.1)
        return self._finalize(new)


POLICIES: dict[str, type[WeightPolicy]] = {
    ProportionalPolicy.name: ProportionalPolicy,
    PIDFillPolicy.name: PIDFillPolicy,
}


def make_policy(name: str, params: dict | None = None) -> WeightPolicy:
    """Build a policy by wire name with optional ``PolicyConfig`` overrides
    (unknown override keys are a protocol error, not a silent ignore)."""
    cls = POLICIES.get(name)
    if cls is None:
        raise ValueError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    cfg = PolicyConfig()
    for k, v in (params or {}).items():
        if not hasattr(cfg, k):
            raise ValueError(f"unknown policy param {k!r}")
        try:
            setattr(cfg, k, float(v))
        except (TypeError, ValueError):
            # must stay ValueError: the daemon maps it to a protocol
            # rejection that replays identically from the journal — a
            # TypeError here would crash handle() AND poison recovery
            raise ValueError(
                f"policy param {k}={v!r} is not a number") from None
    return cls(cfg)
