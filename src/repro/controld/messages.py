"""Typed control-plane message schema (the controld wire protocol).

The paper's control plane is a long-running *service* on the FPGA host:
compute nodes register with it, stream telemetry to it, and hold leases that
expire when they go silent (§I-B.4/5, the CN daemon feedback loop). This
module is the protocol surface of that service — one frozen dataclass per
message, a kind registry, and a canonical JSON wire form shared by both
transports (in-process and length-prefixed socket), so the two are
property-equal by construction: the in-proc path round-trips every message
and reply through the same encoder the socket uses.

Messages:

* ``Reserve`` / ``Free``       — multi-tenant reservation of one virtual LB
  instance (the paper's 4 instances per device, §I-C); ``Reserve`` returns a
  token that scopes every member call to that instance.
* ``ReserveFabric``           — atomically reserve a *tier* of LB instances
  as one fabric: ``k`` LBs, each with a spray session and a reserved-lane
  session (the per-instance lane partition elephant flows are isolated
  onto — DESIGN.md §Fabric). One frame, one journal entry; all-or-nothing.
* ``Register`` / ``Deregister`` — member (CN) lifecycle inside a reservation.
* ``RegisterBatch``            — one bring-up wave of registrations in a
  single frame (parallel arrays), one journal entry; per-member validation
  failures are rejected individually in the reply.
* ``DeregisterBatch``          — the mirror teardown wave: one frame, one
  journal entry, per-member rejections in the reply. Fabric teardown of K
  instances' members is K*2 frames, not thousands of messages.
* ``SendState``               — the heartbeat: carries the MemberTelemetry
  fields (fill / rate / healthy) and renews the member's lease.
* ``SendStateBatch``          — one *window* of heartbeats for many members
  in a single frame: parallel arrays of member ids / fills / rates / health.
  The daemon ingests it as one array scatter into the reservation's
  telemetry lanes (per-member lease semantics identical to M ``SendState``
  messages at the same instant), amortizing the per-message JSON round trip
  that dominates the heartbeat path at farm scale.
* ``Tick``                    — advances the daemon: expires leases, runs the
  policy feedback, garbage-collects drained epochs. Explicit (not a timer)
  so virtual-time drivers and journal replay are deterministic.
* ``Status``                  — admin query, read-only (never journaled).

Every request carries an optional ``trace`` field (a 16-hex trace id from
``telemetry.trace``): both transports pass it through unchanged, and the
daemon — when given a ``TraceBuffer`` — records one ``controld.<kind>`` span
per traced message, linking control-plane work into the same per-window
span trees the data plane emits. ``trace=""`` (the default) records nothing,
and journal replay never records spans (digests are unchanged either way).
"""
from __future__ import annotations

import dataclasses
import json
import struct

_LEN = struct.Struct(">I")
MAX_FRAME_BYTES = 1 << 20  # a control message is small; 1 MiB is corruption


class MessageError(ValueError):
    """Malformed frame / unknown kind / bad field set."""


@dataclasses.dataclass(frozen=True)
class Reserve:
    """Reserve one virtual LB instance. ``policy`` selects the reweighting
    controller for this reservation (``proportional`` | ``pid``);
    ``policy_params`` overrides its gains. ``instance_hint`` pins a specific
    instance when free (-1 = daemon's choice)."""

    KIND = "reserve"
    policy: str = "proportional"
    policy_params: dict = dataclasses.field(default_factory=dict)
    instance_hint: int = -1
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class Free:
    """Release a reservation: drains the session and returns the instance."""

    KIND = "free"
    token: str = ""
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class ReserveFabric:
    """Reserve ``2*k`` virtual LB instances as one two-tier fabric: for each
    of the ``k`` tier members, a *spray* session (the VLB lanes mice traffic
    is obliviously sprayed across) and a *reserved* session (the calendar
    lanes detected elephant flows are strict-source-routed onto).
    All-or-nothing: if fewer than ``2*k`` instances are free the whole
    reservation is rejected. ``reserved_fraction`` records the fabric's
    lane-partition contract (what share of the farm the reserved calendars
    are programmed over) — surfaced in ``Status`` so operators and the
    simulator agree on the partition."""

    KIND = "reserve_fabric"
    k: int = 2
    policy: str = "proportional"
    policy_params: dict = dataclasses.field(default_factory=dict)
    reserved_fraction: float = 0.25
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class Register:
    """Add a member (CN) to a reservation. Grants a lease that heartbeats
    renew; re-registering after a lapsed lease is the recovery path."""

    KIND = "register"
    token: str = ""
    member_id: int = 0
    node_id: int = 0
    base_lane: int = 0
    lane_bits: int = 0
    weight: float = 1.0
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class RegisterBatch:
    """One session bring-up (or rejoin wave) of many members in a single
    frame: parallel arrays of member ids / node ids / lanes / weights. The
    daemon handles it as one journal entry with per-member semantics exactly
    ``Register`` at a shared instant — members that fail validation (bad id,
    bad weight, bad lane spec) are *individually* rejected in the reply's
    ``rejected`` map while the rest are admitted; duplicates of a member id
    resolve last-spec-wins. At 10k members this turns ~0.5 s of per-member
    round trips into one frame."""

    KIND = "register_batch"
    token: str = ""
    member_ids: tuple = ()
    node_ids: tuple = ()
    base_lanes: tuple = ()
    lane_bits: tuple = ()
    weights: tuple = ()
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class Deregister:
    """Graceful exit: the member drains hit-lessly from the next epoch."""

    KIND = "deregister"
    token: str = ""
    member_id: int = 0
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class DeregisterBatch:
    """One teardown wave of many members in a single frame — the mirror of
    ``RegisterBatch``: one journal entry, per-member semantics exactly
    ``Deregister`` at a shared instant. Members that are not registered are
    *individually* rejected in the reply's ``rejected`` map while the rest
    drain hit-lessly; duplicates of a member id resolve to one deregister
    plus a rejection for the rest."""

    KIND = "deregister_batch"
    token: str = ""
    member_ids: tuple = ()
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class SendState:
    """Heartbeat: one telemetry sample (MemberTelemetry fields) + lease
    renewal. A heartbeat for a lapsed lease is *rejected* — the member must
    re-register (the protocol form of ``TelemetryHub.stale_after``)."""

    KIND = "send_state"
    token: str = ""
    member_id: int = 0
    fill: float = 0.0
    rate: float = 1.0
    healthy: bool = True
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class SendStateBatch:
    """One window of heartbeats for many members: parallel arrays, one
    frame, one journal entry, one telemetry scatter. Per-member semantics
    are exactly ``SendState`` at a shared instant — members whose lease
    lapsed (or who hold none) are *individually* rejected in the reply's
    ``rejected`` map while the rest are accepted; duplicates of a member id
    resolve last-sample-wins."""

    KIND = "send_state_batch"
    token: str = ""
    member_ids: tuple = ()
    fills: tuple = ()
    rates: tuple = ()
    healthy: tuple = ()
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class Tick:
    """One daemon step at ``current_event``: expire leases (-> hit-less
    drain), start pending sessions, run policy feedback per session, GC
    drained epochs at ``gc_event`` (-1 = ``current_event``)."""

    KIND = "tick"
    current_event: int = 0
    gc_event: int = -1
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class Status:
    """Read-only admin query. With a token: that session; without: all."""

    KIND = "status"
    token: str = ""
    trace: str = ""
    req: str = ""


# -- HA / replication control messages (DESIGN.md §Controld-HA) ---------------
@dataclasses.dataclass(frozen=True)
class ReplicateEntries:
    """Leader -> standby WAL shipment: a contiguous batch of journal
    entries (``[{"seq", "kind", "payload"}, ...]``) the standby must
    append to its own journal and apply through the replay path. An
    *empty* batch is a probe: the reply's ``ReplicaAck`` tells the
    leader where the standby's journal ends (bootstrap / catch-up).
    ``generation`` is the leader's lease generation — a standby rejects
    shipments from a stale generation (fencing a partitioned
    ex-leader)."""

    KIND = "replicate_entries"
    leader: str = ""
    generation: int = 0
    entries: tuple = ()
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class ReplicaAck:
    """Standby -> leader acknowledgement, carried in the
    ``ReplicateEntries`` reply's ``data`` (wire form round-tripped via
    ``to_wire``/``from_wire``): ``ack_seq`` is the last journal seq the
    standby holds; ``need_from`` (>= 0) asks the leader to re-ship from
    that seq when the batch was non-contiguous with the standby's
    journal."""

    KIND = "replica_ack"
    node: str = ""
    ack_seq: int = -1
    need_from: int = -1
    generation: int = 0
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class LeaseClaim:
    """Leadership announcement / fencing: a node that claimed the lease
    (``generation`` from the arbiter) tells a peer. A leader receiving a
    claim with a *newer* generation steps down to standby immediately —
    a partitioned ex-leader must stop accepting mutations the moment it
    hears from its successor, even before its next arbiter read."""

    KIND = "lease_claim"
    node: str = ""
    generation: int = 0
    expires: float = 0.0
    trace: str = ""
    req: str = ""


@dataclasses.dataclass(frozen=True)
class Reply:
    """Every request gets one. ``data`` is kind-specific; protocol errors
    (bad token, lapsed lease, no free instance) come back ``ok=False`` with
    ``error`` set — they are *replies*, not transport failures."""

    ok: bool
    data: dict = dataclasses.field(default_factory=dict)
    error: str = ""


MESSAGE_TYPES = {
    cls.KIND: cls
    for cls in (Reserve, Free, ReserveFabric, Register, RegisterBatch,
                Deregister, DeregisterBatch, SendState, SendStateBatch,
                Tick, Status, ReplicateEntries, ReplicaAck, LeaseClaim)
}
#: HA control-plane kinds: handled by the HA layer (``controld.ha``),
#: never journaled as session state — replication carries journal
#: entries, it must not *generate* them
HA_KINDS = frozenset(
    {ReplicateEntries.KIND, ReplicaAck.KIND, LeaseClaim.KIND})
#: kinds that mutate daemon state and therefore must be journaled
MUTATING_KINDS = frozenset(
    k for k in MESSAGE_TYPES if k != Status.KIND and k not in HA_KINDS)


# -- canonical dict form ------------------------------------------------------
def to_wire(msg) -> dict:
    # shallow field dict, NOT dataclasses.asdict: messages hold no nested
    # dataclasses, and asdict deep-copies every element of a batch message's
    # arrays (it dominated the SendStateBatch hot path by ~10x)
    d = {f.name: getattr(msg, f.name) for f in dataclasses.fields(msg)}
    d["kind"] = msg.KIND
    return d


def from_wire(d: dict):
    d = dict(d)
    kind = d.pop("kind", None)
    cls = MESSAGE_TYPES.get(kind)
    if cls is None:
        raise MessageError(f"unknown message kind {kind!r}")
    try:
        return cls(**d)
    except TypeError as e:
        raise MessageError(f"bad fields for {kind!r}: {e}") from None


def reply_to_wire(r: Reply) -> dict:
    return {"ok": r.ok, "data": r.data, "error": r.error}


def reply_from_wire(d: dict) -> Reply:
    try:
        return Reply(ok=bool(d["ok"]), data=d.get("data") or {},
                     error=d.get("error", ""))
    except (KeyError, TypeError) as e:
        raise MessageError(f"bad reply frame: {e}") from None


# -- length-prefixed framing (the socket wire form) ---------------------------
def _check_frame_size(n: int) -> None:
    if n > MAX_FRAME_BYTES:
        raise MessageError(f"frame too large ({n} bytes)")


def _decode_body(body: bytes) -> dict:
    try:
        return json.loads(body.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise MessageError(f"undecodable frame: {e}") from None


def pack_frame(obj: dict) -> bytes:
    body = json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()
    _check_frame_size(len(body))
    return _LEN.pack(len(body)) + body


def read_frame(recv_exactly) -> dict | None:
    """Read one frame via ``recv_exactly(n) -> bytes`` (returns b'' on EOF
    at a frame boundary -> None)."""
    head = recv_exactly(_LEN.size)
    if not head:
        return None
    if len(head) != _LEN.size:
        raise MessageError("truncated frame header")
    (n,) = _LEN.unpack(head)
    _check_frame_size(n)
    body = recv_exactly(n)
    if len(body) != n:
        raise MessageError("truncated frame body")
    return _decode_body(body)


def parse_frames(buf: bytearray) -> list[dict]:
    """Consume every *complete* frame at the head of ``buf`` (in place) and
    return the decoded bodies — the non-blocking form of ``read_frame`` the
    selector transport uses: whatever half-frame remains stays in ``buf``
    for the next read. Raises ``MessageError`` on an oversized or
    undecodable frame (the connection is corrupt, not just slow)."""
    out = []
    while len(buf) >= _LEN.size:
        (n,) = _LEN.unpack(bytes(buf[:_LEN.size]))
        _check_frame_size(n)
        if len(buf) < _LEN.size + n:
            break
        body = bytes(buf[_LEN.size:_LEN.size + n])
        del buf[:_LEN.size + n]
        out.append(_decode_body(body))
    return out
