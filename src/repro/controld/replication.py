"""WAL shipping for controld HA (leader side) and the standby apply path.

The leader's journal is the single source of truth (every mutating
message is WAL-appended before it executes — ``daemon.py``), so
replication is exactly "ship the WAL": each handled message's fresh
entries go to every attached standby as one ``ReplicateEntries`` frame
over the ordinary controld transport, and the standby *applies them
through the same journal-replay path a recovering daemon uses* —
``append_entry`` mirrors the entry byte-for-byte into the standby's own
journal, then the message runs under ``_replaying`` with its recorded
clock instant. Determinism of replay (PR 4-5's digest property) is what
makes the standby's ``state_digest`` track the leader's exactly.

Protocol (DESIGN.md §Controld-HA):

* shipment  — ``ReplicateEntries(leader, generation, entries)`` where
  ``entries`` is a seq-contiguous batch; empty = probe.
* ack       — the reply data is a wire-form ``ReplicaAck``:
  ``ack_seq`` (standby's journal head) and ``need_from`` >= 0 when the
  batch did not attach to the standby's journal (the leader then ships
  backlog from that seq — ``Journal.read_entries``).
* fencing   — a standby rejects shipments from a generation older than
  the newest it has seen, so a partitioned ex-leader cannot overwrite a
  promoted successor's journal; the rejection tells the ex-leader to
  step down.

Delivery policy: synchronous best-effort. The leader ships (and waits
for the ack) before answering the client, so any reply the client saw
is durable on every *live* standby — a SIGKILLed leader loses only
unacknowledged calls, which the client resends idempotently (request
ids). A standby that errors or disconnects is marked dead and skipped
(one stuck standby must not freeze the control plane); it catches up
via the probe/backlog dance when it re-attaches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.controld import messages as M
from repro.controld.journal import Entry, Journal

#: keep shipment frames far under messages.MAX_FRAME_BYTES (1 MiB)
BATCH_ENTRIES = 256

#: marker a standby uses to reject a stale-generation shipment — the
#: ex-leader seeing it must step down immediately
STALE_GENERATION = "STALE_GENERATION"


def entry_to_wire(e: Entry) -> dict:
    return {"seq": e.seq, "kind": e.kind, "payload": e.payload}


def entry_from_wire(d: dict) -> Entry:
    return Entry(seq=int(d["seq"]), kind=str(d["kind"]),
                 payload=dict(d["payload"]))


def apply_entries(daemon, entries) -> int:
    """Standby-side application: mirror each shipped entry into the
    local journal (exact seq — ``append_entry``), then execute it through
    the daemon's replay path with its recorded instant. This IS the
    recovery path run incrementally, so the standby's ``state_digest``
    tracks the leader byte-for-byte; the request-id dedup cache rebuilds
    too, which is what makes a client resend land correctly on the
    successor after failover."""
    j = daemon.journal
    n = 0
    for e in entries:
        if j is not None:
            j.append_entry(e)
        payload = dict(e.payload)
        recorded_now = payload.pop("now")
        msg = M.from_wire({"kind": e.kind, **payload})
        daemon._replaying = True
        try:
            daemon.handle(msg, now=recorded_now)
        finally:
            daemon._replaying = False
        n += 1
    return n


@dataclasses.dataclass
class ReplicaPeer:
    """Leader-side view of one standby."""

    name: str
    transport: object
    acked_seq: int = -1
    alive: bool = True
    errors: int = 0


class Replicator:
    """Leader-side WAL shipper over a set of standby transports.

    ``ship`` sends fresh entries to every live peer and processes acks
    (including ``need_from`` backlog requests). Returns True if any peer
    fenced us with ``STALE_GENERATION`` — the caller (``HANode``) must
    step down. ``lag`` = journal head minus the slowest live peer's ack
    (the replication-lag gauge)."""

    def __init__(self, node_id: str, journal: Optional[Journal],
                 faults=None):
        self.node_id = node_id
        self.journal = journal
        self.faults = faults
        self.peers: dict[str, ReplicaPeer] = {}

    def attach(self, name: str, transport, generation: int) -> ReplicaPeer:
        """Register a standby and bring it to the journal head: probe for
        its ack seq, then ship whatever backlog it is missing."""
        peer = self.peers[name] = ReplicaPeer(name=name, transport=transport)
        self._ship_peer(peer, [], generation)  # probe; triggers catch-up
        return peer

    def detach(self, name: str) -> None:
        self.peers.pop(name, None)

    def lag(self) -> int:
        if self.journal is None:
            return 0
        live = [p.acked_seq for p in self.peers.values() if p.alive]
        if not live:
            return 0
        return max(0, self.journal.seq - min(live))

    def ship(self, entries, generation: int) -> bool:
        """One shipment round to every live peer; True => we were fenced
        (a peer holds a newer generation) and must step down."""
        if self.faults is not None:
            self.faults.crashpoint("replication.ship")
        wire = [entry_to_wire(e) for e in entries]
        fenced = False
        for peer in self.peers.values():
            if peer.alive:
                fenced |= self._ship_peer(peer, wire, generation)
        return fenced

    def _call(self, peer: ReplicaPeer, wire_entries,
              generation: int) -> Optional[M.ReplicaAck]:
        """One ReplicateEntries round trip; None => peer marked dead or
        (if fenced) the ack is replaced by raising via return code."""
        from repro.controld.transport import TransportError
        msg = M.ReplicateEntries(leader=self.node_id,
                                 generation=int(generation),
                                 entries=tuple(wire_entries))
        try:
            reply = peer.transport.call(msg)
        except TransportError:
            peer.alive = False
            peer.errors += 1
            return None
        if not reply.ok:
            peer.errors += 1
            if STALE_GENERATION in reply.error:
                return M.ReplicaAck(node=peer.name, ack_seq=-2)
            peer.alive = False
            return None
        ack = M.from_wire(reply.data)
        if not isinstance(ack, M.ReplicaAck):
            peer.alive = False
            peer.errors += 1
            return None
        return ack

    def _ship_peer(self, peer: ReplicaPeer, wire_entries,
                   generation: int) -> bool:
        """Ship one batch to one peer, then stream backlog until the peer
        acks the journal *head* — a freshly (re)attached standby is
        brought fully current before this returns, which is what makes
        the synchronous-durability invariant hold for every live peer.
        Returns True when fenced."""
        ack = self._call(peer, wire_entries, generation)
        for _ in range(4096):  # rounds are strictly monotone; bound them
            if ack is None:
                return False
            if ack.ack_seq == -2:  # STALE_GENERATION sentinel
                return True
            peer.acked_seq = max(peer.acked_seq, ack.ack_seq)
            if self.journal is None:
                return False
            if ack.need_from < 0 and peer.acked_seq >= self.journal.seq:
                return False  # converged to head
            start = (ack.need_from if ack.need_from >= 0
                     else peer.acked_seq + 1)
            backlog = self.journal.read_entries(start)
            if not backlog:
                return False
            sent_through = backlog[min(len(backlog), BATCH_ENTRIES) - 1].seq
            chunk = [entry_to_wire(e)
                     for e in backlog[:BATCH_ENTRIES]]
            prev_ack = peer.acked_seq
            ack = self._call(peer, chunk, generation)
            if (ack is not None and ack.ack_seq >= 0
                    and ack.ack_seq <= prev_ack
                    and sent_through > prev_ack):
                # no forward progress — stop rather than loop
                peer.alive = False
                return False
        peer.alive = False  # backlog never converged
        return False
