"""controld transports: in-process and length-prefixed socket.

Both fronts speak the exact same wire form (``controld.messages``): the
in-process transport round-trips every request and reply through the JSON
frame encoder before delivery, so anything that works in-proc works over the
socket byte-for-byte (property-tested in tests/test_controld.py). In-proc is
what simnet and the serving engine embed (deterministic, virtual-clock
friendly); the socket server is what ``scripts/run_controld.py`` exposes for
real CN daemons.

The socket server is a **selector loop**, not thread-per-connection: one
event-loop thread services every connection, parsing as many frames as each
read delivers and answering them in arrival order, so clients can
*pipeline* — write a burst of frames, then read the replies
(``SocketClient.call_many``) — and a heartbeat window travels as one
``SendStateBatch`` frame instead of M round trips. The daemon stays
single-writer by construction (one thread touches it), which is what the
journal's total order requires; no lock needed.
"""
from __future__ import annotations

import dataclasses
import selectors
import socket
import threading
from typing import Optional

import numpy as np

from repro.controld import messages as M
from repro.controld.daemon import ControlDaemon
from repro.telemetry.registry import SIZE_BUCKETS, MetricsRegistry


class TransportError(RuntimeError):
    """The transport failed (connection, framing) — distinct from a protocol
    rejection, which arrives as ``Reply(ok=False)``."""


class InProcTransport:
    """Direct call into a daemon in the same process — through the wire
    encoding, so semantics are identical to the socket path."""

    def __init__(self, daemon: ControlDaemon):
        self.daemon = daemon

    def call(self, msg) -> M.Reply:
        wire = M.read_frame(_BufReader(M.pack_frame(M.to_wire(msg))).read)
        reply = self.daemon.handle(M.from_wire(wire))
        back = M.read_frame(
            _BufReader(M.pack_frame(M.reply_to_wire(reply))).read)
        return M.reply_from_wire(back)

    def call_many(self, msgs) -> list[M.Reply]:
        """API parity with the socket client's pipelined burst."""
        return [self.call(m) for m in msgs]

    def close(self) -> None:
        pass


class _BufReader:
    def __init__(self, data: bytes):
        self._data = data
        self._pos = 0

    def read(self, n: int) -> bytes:
        out = self._data[self._pos:self._pos + n]
        self._pos += len(out)
        return out


def _recv_exactly(sock: socket.socket, n: int) -> bytes:
    chunks = []
    remaining = n
    while remaining:
        chunk = sock.recv(remaining)
        if not chunk:
            break
        chunks.append(chunk)
        remaining -= len(chunk)
    return b"".join(chunks)


class _Conn:
    """Per-connection buffers for the selector loop."""

    __slots__ = ("sock", "rbuf", "wbuf")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.rbuf = bytearray()
        self.wbuf = bytearray()


class _ServerMetrics:
    """Socket-front instrumentation: frames, pipeline depth, connection
    churn, bytes. Resolved once; the selector loop pays plain float adds."""

    def __init__(self, registry: MetricsRegistry):
        self.frames = registry.counter(
            "controld_socket_frames_total", "Request frames handled.")
        self.pipeline_depth = registry.histogram(
            "controld_socket_pipeline_depth",
            "Complete frames parsed per socket read (client pipelining).",
            buckets=SIZE_BUCKETS)
        self.conns_opened = registry.counter(
            "controld_socket_connections_opened_total",
            "Connections accepted.")
        self.conns_closed = registry.counter(
            "controld_socket_connections_closed_total",
            "Connections torn down (EOF, error, corrupt framing, stop).")
        self.bytes_read = registry.counter(
            "controld_socket_read_bytes_total", "Bytes received.")
        self.bytes_written = registry.counter(
            "controld_socket_written_bytes_total", "Bytes sent.")


class SocketServer:
    """Selector-loop length-prefixed-JSON server over a ``ControlDaemon``.

    One event-loop thread services every connection: each readable socket
    is drained into a per-connection buffer, every complete frame is
    handled immediately (``messages.parse_frames``), and replies are queued
    to a write buffer flushed as the socket drains. Clients may pipeline
    arbitrarily many frames before reading a reply — replies come back in
    request order. A single thread touching the daemon keeps it
    single-writer (the journal is a total order) without a lock."""

    def __init__(self, daemon: ControlDaemon, host: str = "127.0.0.1",
                 port: int = 0,
                 metrics: Optional[MetricsRegistry] = None):
        self.daemon = daemon
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self.host, self.port = self._sock.getsockname()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._sel: Optional[selectors.BaseSelector] = None
        self._mx = None if metrics is None else _ServerMetrics(metrics)

    def start(self) -> tuple[str, int]:
        self._sock.listen(128)
        self._sock.setblocking(False)
        self._sel = selectors.DefaultSelector()
        self._sel.register(self._sock, selectors.EVENT_READ, None)
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self.host, self.port

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                events = self._sel.select(timeout=0.2)
            except OSError:
                break
            for key, mask in events:
                if key.data is None:
                    self._accept()
                else:
                    try:
                        self._service(key.data, mask)
                    except Exception:
                        # an unexpected handler exception must cost ONE
                        # connection (the old thread-per-connection blast
                        # radius), never the whole event loop — a dead loop
                        # thread would silently hang every client
                        self._close(key.data)
        for key in list(self._sel.get_map().values()):
            if key.data is not None:
                self._close(key.data)
        self._sel.close()

    def _accept(self) -> None:
        try:
            conn, _ = self._sock.accept()
        except OSError:
            return
        conn.setblocking(False)
        self._sel.register(conn, selectors.EVENT_READ, _Conn(conn))
        if self._mx is not None:
            self._mx.conns_opened.inc()

    def _close(self, c: _Conn) -> None:
        try:
            self._sel.unregister(c.sock)
        except (KeyError, ValueError):
            was_registered = False
        else:
            was_registered = True
        try:
            c.sock.close()
        except OSError:
            pass
        if self._mx is not None and was_registered:
            # guard on the unregister so a double _close counts once
            self._mx.conns_closed.inc()

    def _service(self, c: _Conn, mask: int) -> None:
        if mask & selectors.EVENT_READ:
            try:
                data = c.sock.recv(1 << 16)
            except BlockingIOError:
                data = None
            except OSError:
                self._close(c)
                return
            if data == b"":
                self._close(c)  # clean EOF
                return
            if data:
                if self._mx is not None:
                    self._mx.bytes_read.inc(len(data))
                c.rbuf += data
                if not self._handle_frames(c):
                    return
        self._flush(c)

    def _handle_frames(self, c: _Conn) -> bool:
        """Answer every complete pipelined frame in ``c.rbuf`` in order.
        Returns False if the connection was torn down (corrupt framing)."""
        try:
            wires = M.parse_frames(c.rbuf)
        except M.MessageError:
            self._close(c)  # framing corruption: the stream is unusable
            return False
        if self._mx is not None and wires:
            self._mx.frames.inc(len(wires))
            self._mx.pipeline_depth.observe(len(wires))
        for wire in wires:
            try:
                msg = M.from_wire(wire)
            except M.MessageError as e:
                reply = M.Reply(False, error=str(e))
            else:
                reply = self.daemon.handle(msg)
            c.wbuf += M.pack_frame(M.reply_to_wire(reply))
        return True

    def _flush(self, c: _Conn) -> None:
        if c.wbuf:
            try:
                n = c.sock.send(c.wbuf)
                del c.wbuf[:n]
                if self._mx is not None:
                    self._mx.bytes_written.inc(n)
            except BlockingIOError:
                pass
            except OSError:
                self._close(c)
                return
        want = selectors.EVENT_READ | (selectors.EVENT_WRITE
                                       if c.wbuf else 0)
        try:
            self._sel.modify(c.sock, want, c)
        except (KeyError, ValueError):
            pass

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        try:
            self._sock.close()
        except OSError:
            pass


class SocketClient:
    """Blocking request/reply client over one connection."""

    def __init__(self, host: str, port: int, timeout_s: float = 10.0):
        self._sock = socket.create_connection((host, port),
                                              timeout=timeout_s)

    def call(self, msg) -> M.Reply:
        try:
            self._sock.sendall(M.pack_frame(M.to_wire(msg)))
            wire = M.read_frame(lambda n: _recv_exactly(self._sock, n))
        except (OSError, M.MessageError) as e:
            raise TransportError(f"socket call failed: {e}") from e
        if wire is None:
            raise TransportError("server closed the connection")
        return M.reply_from_wire(wire)

    def call_many(self, msgs) -> list[M.Reply]:
        """Pipelined burst: write every frame, then read the replies in
        request order — one wire round trip for the whole batch instead of
        one per message (the selector server answers frames as they land)."""
        msgs = list(msgs)
        try:
            self._sock.sendall(
                b"".join(M.pack_frame(M.to_wire(m)) for m in msgs))
            replies = []
            for _ in msgs:
                wire = M.read_frame(lambda n: _recv_exactly(self._sock, n))
                if wire is None:
                    raise TransportError("server closed the connection")
                replies.append(M.reply_from_wire(wire))
        except (OSError, M.MessageError) as e:
            raise TransportError(f"socket call failed: {e}") from e
        return replies

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


class ControldError(RuntimeError):
    """A protocol rejection surfaced by the high-level client."""


class ControldClient:
    """Convenience API over any transport: builds typed messages, raises
    ``ControldError`` on ``ok=False`` replies, returns ``reply.data``.

    Setting ``client.trace`` to a trace id (``telemetry.trace.trace_id``)
    stamps every subsequent outgoing message with it — the daemon links its
    handling spans to that id. Clear it (``""``) to stop propagating."""

    def __init__(self, transport):
        self.transport = transport
        self.trace = ""

    def _stamp(self, msg):
        if self.trace and not getattr(msg, "trace", ""):
            return dataclasses.replace(msg, trace=self.trace)
        return msg

    def _call(self, msg) -> dict:
        reply = self.transport.call(self._stamp(msg))
        if not reply.ok:
            raise ControldError(reply.error)
        return reply.data

    def reserve(self, policy: str = "proportional",
                policy_params: dict | None = None,
                instance_hint: int = -1) -> dict:
        return self._call(M.Reserve(policy=policy,
                                    policy_params=policy_params or {},
                                    instance_hint=instance_hint))

    def reserve_fabric(self, k: int = 2, policy: str = "proportional",
                       policy_params: dict | None = None,
                       reserved_fraction: float = 0.25) -> dict:
        """Atomically reserve a two-tier fabric: ``k`` LBs, each a (spray,
        reserved) session pair. Returns the daemon's ``{"fabric", "k",
        "reserved_fraction", "lease_s", "sessions": [{"lb", "spray",
        "reserved"}, ...]}``."""
        return self._call(M.ReserveFabric(
            k=k, policy=policy, policy_params=policy_params or {},
            reserved_fraction=reserved_fraction))

    def free(self, token: str) -> dict:
        return self._call(M.Free(token=token))

    def register(self, token: str, member_id: int, node_id: int | None = None,
                 base_lane: int = 0, lane_bits: int = 0,
                 weight: float = 1.0) -> dict:
        return self._call(M.Register(
            token=token, member_id=member_id,
            node_id=member_id if node_id is None else node_id,
            base_lane=base_lane, lane_bits=lane_bits, weight=weight))

    def register_batch(self, token: str, member_ids, node_ids=None,
                       base_lanes=None, lane_bits=0, weights=None) -> dict:
        """One bring-up wave in one frame. ``node_ids`` defaults to the
        member ids; ``lane_bits`` may be a scalar (applied to every member)
        or a parallel array. Returns the daemon's ``{"n_accepted",
        "member_ids", "lease_expires", "rejected"}`` — per-member
        validation failures live in ``rejected``, they do not raise: the
        rest of the wave is admitted."""
        # np integers -> python ints for JSON; anything non-integral passes
        # through untouched so the daemon rejects it per-member (a client-
        # side int() would silently truncate onto the wrong lane)
        def as_id(m):
            return (int(m) if isinstance(m, (int, np.integer))
                    and not isinstance(m, bool) else m)

        ids = [as_id(m) for m in member_ids]
        n = len(ids)
        if np.isscalar(lane_bits):
            lane_bits = [lane_bits] * n
        return self._call(M.RegisterBatch(
            token=token, member_ids=ids,
            node_ids=(list(ids) if node_ids is None
                      else [as_id(m) for m in node_ids]),
            base_lanes=([0] * n if base_lanes is None else list(base_lanes)),
            lane_bits=[as_id(b) for b in lane_bits],
            weights=([1.0] * n if weights is None
                     else [float(w) for w in weights])))

    def deregister(self, token: str, member_id: int) -> dict:
        return self._call(M.Deregister(token=token, member_id=member_id))

    def deregister_batch(self, token: str, member_ids) -> dict:
        """One teardown wave in one frame — the mirror of
        ``register_batch``. Returns the daemon's ``{"n_accepted",
        "member_ids", "rejected"}`` — unregistered members live in
        ``rejected``, they do not raise: the rest of the wave drains."""
        # np integers -> python ints for JSON; anything non-integral passes
        # through untouched so the daemon rejects it per-member
        ids = [int(m) if isinstance(m, (int, np.integer))
               and not isinstance(m, bool) else m for m in member_ids]
        return self._call(M.DeregisterBatch(token=token, member_ids=ids))

    def send_state(self, token: str, member_id: int, fill: float,
                   rate: float = 1.0, healthy: bool = True) -> dict:
        return self._call(M.SendState(token=token, member_id=member_id,
                                      fill=fill, rate=rate, healthy=healthy))

    def send_state_batch(self, token: str, member_ids, fills,
                         rates=None, healthy=None) -> dict:
        """One window of heartbeats in one frame. Returns the daemon's
        ``{"n_accepted", "lease_expires", "rejected"}`` — per-member
        rejections (lapsed/no lease) live in ``rejected``, they do not
        raise: the rest of the window is accepted."""
        # np integers -> python ints for JSON; anything non-integral passes
        # through untouched so the daemon rejects it per-member (a client-
        # side int() would silently truncate onto the wrong lane)
        ids = [int(m) if isinstance(m, (int, np.integer))
               and not isinstance(m, bool) else m for m in member_ids]
        return self._call(M.SendStateBatch(
            token=token, member_ids=ids,
            fills=[float(f) for f in fills],
            rates=([1.0] * len(ids) if rates is None
                   else [float(r) for r in rates]),
            healthy=([True] * len(ids) if healthy is None
                     else [bool(h) for h in healthy])))

    def heartbeat_window(self, token: str, samples: dict,
                         lane_bits: int = 0) -> dict:
        """One batched heartbeat window from a telemetry snapshot
        ``{member_id: MemberTelemetry-like}`` (``.fill``/``.rate``/
        ``.healthy``). Members whose lease lapsed come back rejected; for a
        caller that owns its members (serve engine, trainer) the right move
        is always re-register (node_id = member_id) and resend their
        samples — done here so every embedder shares one protocol dance.
        Returns the first batch's reply."""
        def send(ids):
            return self.send_state_batch(
                token, ids, [samples[m].fill for m in ids],
                [samples[m].rate for m in ids],
                [samples[m].healthy for m in ids])

        ids = sorted(samples)
        if not ids:
            return {"n_accepted": 0, "lease_expires": 0.0, "rejected": {}}
        reply = send(ids)
        retry = sorted(int(m) for m in reply["rejected"])
        if retry:
            self.register_batch(token, retry, lane_bits=lane_bits)
            send(retry)
        return reply

    def call_many(self, msgs) -> list[M.Reply]:
        """Raw pipelined burst of typed messages (replies, not data)."""
        return self.transport.call_many([self._stamp(m) for m in msgs])

    def tick(self, current_event: int, gc_event: int = -1) -> dict:
        return self._call(M.Tick(current_event=current_event,
                                 gc_event=gc_event))

    def status(self, token: str = "") -> dict:
        return self._call(M.Status(token=token))

    def close(self) -> None:
        self.transport.close()
