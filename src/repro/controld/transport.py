"""controld transports: in-process and length-prefixed socket.

Both fronts speak the exact same wire form (``controld.messages``): the
in-process transport round-trips every request and reply through the JSON
frame encoder before delivery, so anything that works in-proc works over the
socket byte-for-byte (property-tested in tests/test_controld.py). In-proc is
what simnet and the serving engine embed (deterministic, virtual-clock
friendly); the socket server is what ``scripts/run_controld.py`` exposes for
real CN daemons.

The socket server is a **selector loop**, not thread-per-connection: one
event-loop thread services every connection, parsing as many frames as each
read delivers and answering them in arrival order, so clients can
*pipeline* — write a burst of frames, then read the replies
(``SocketClient.call_many``) — and a heartbeat window travels as one
``SendStateBatch`` frame instead of M round trips. The daemon stays
single-writer by construction (one thread touches it), which is what the
journal's total order requires; no lock needed.
"""
from __future__ import annotations

import dataclasses
import random
import selectors
import socket
import threading
import time
import uuid
from typing import Optional

import numpy as np

from repro.controld import messages as M
from repro.controld.daemon import ControlDaemon
from repro.telemetry.registry import SIZE_BUCKETS, MetricsRegistry


class TransportError(RuntimeError):
    """The transport failed (connection, framing) — distinct from a protocol
    rejection, which arrives as ``Reply(ok=False)``."""


#: marker prefix standbys use to reject client mutations — the failover
#: transport treats it as "try another endpoint", not a protocol error
NOT_LEADER = "NOT_LEADER"


class RetryPolicy:
    """Capped exponential backoff with deterministic (seeded) jitter.

    ``delays()`` yields the sleep before each retry round: ``base_s``
    doubling (``multiplier``) up to ``cap_s``, each scaled by a jitter
    factor uniform in ``[1-jitter, 1+jitter]`` drawn from a seeded RNG —
    reruns with the same seed retry on the identical schedule (the
    chaos-scenario determinism gate). ``max_elapsed_s``/``max_attempts``
    bound the loop (0 = unbounded on that axis)."""

    def __init__(self, base_s: float = 0.05, cap_s: float = 1.0,
                 multiplier: float = 2.0, jitter: float = 0.5,
                 max_elapsed_s: float = 30.0, max_attempts: int = 0,
                 seed: int = 0):
        self.base_s = float(base_s)
        self.cap_s = float(cap_s)
        self.multiplier = float(multiplier)
        self.jitter = max(0.0, min(float(jitter), 1.0))
        self.max_elapsed_s = float(max_elapsed_s)
        self.max_attempts = int(max_attempts)
        self.seed = int(seed)

    def delays(self):
        rng = random.Random(self.seed)
        delay = self.base_s
        n = 0
        while self.max_attempts <= 0 or n < self.max_attempts:
            scale = 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
            yield min(delay, self.cap_s) * scale
            delay = min(delay * self.multiplier, self.cap_s)
            n += 1


class InProcTransport:
    """Direct call into a daemon in the same process — through the wire
    encoding, so semantics are identical to the socket path."""

    def __init__(self, daemon: ControlDaemon):
        self.daemon = daemon

    def call(self, msg) -> M.Reply:
        wire = M.read_frame(_BufReader(M.pack_frame(M.to_wire(msg))).read)
        reply = self.daemon.handle(M.from_wire(wire))
        back = M.read_frame(
            _BufReader(M.pack_frame(M.reply_to_wire(reply))).read)
        return M.reply_from_wire(back)

    def call_many(self, msgs) -> list[M.Reply]:
        """API parity with the socket client's pipelined burst."""
        return [self.call(m) for m in msgs]

    def close(self) -> None:
        pass


class _BufReader:
    def __init__(self, data: bytes):
        self._data = data
        self._pos = 0

    def read(self, n: int) -> bytes:
        out = self._data[self._pos:self._pos + n]
        self._pos += len(out)
        return out


def _recv_exactly(sock: socket.socket, n: int) -> bytes:
    chunks = []
    remaining = n
    while remaining:
        chunk = sock.recv(remaining)
        if not chunk:
            break
        chunks.append(chunk)
        remaining -= len(chunk)
    return b"".join(chunks)


class _Conn:
    """Per-connection buffers for the selector loop."""

    __slots__ = ("sock", "rbuf", "wbuf")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.rbuf = bytearray()
        self.wbuf = bytearray()


class _ServerMetrics:
    """Socket-front instrumentation: frames, pipeline depth, connection
    churn, bytes. Resolved once; the selector loop pays plain float adds."""

    def __init__(self, registry: MetricsRegistry):
        self.frames = registry.counter(
            "controld_socket_frames_total", "Request frames handled.")
        self.pipeline_depth = registry.histogram(
            "controld_socket_pipeline_depth",
            "Complete frames parsed per socket read (client pipelining).",
            buckets=SIZE_BUCKETS)
        self.conns_opened = registry.counter(
            "controld_socket_connections_opened_total",
            "Connections accepted.")
        self.conns_closed = registry.counter(
            "controld_socket_connections_closed_total",
            "Connections torn down (EOF, error, corrupt framing, stop).")
        self.bytes_read = registry.counter(
            "controld_socket_read_bytes_total", "Bytes received.")
        self.bytes_written = registry.counter(
            "controld_socket_written_bytes_total", "Bytes sent.")


class SocketServer:
    """Selector-loop length-prefixed-JSON server over a ``ControlDaemon``.

    One event-loop thread services every connection: each readable socket
    is drained into a per-connection buffer, every complete frame is
    handled immediately (``messages.parse_frames``), and replies are queued
    to a write buffer flushed as the socket drains. Clients may pipeline
    arbitrarily many frames before reading a reply — replies come back in
    request order. A single thread touching the daemon keeps it
    single-writer (the journal is a total order) without a lock."""

    def __init__(self, daemon: ControlDaemon, host: str = "127.0.0.1",
                 port: int = 0,
                 metrics: Optional[MetricsRegistry] = None):
        self.daemon = daemon
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self.host, self.port = self._sock.getsockname()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._sel: Optional[selectors.BaseSelector] = None
        self._mx = None if metrics is None else _ServerMetrics(metrics)

    def start(self) -> tuple[str, int]:
        self._sock.listen(128)
        self._sock.setblocking(False)
        self._sel = selectors.DefaultSelector()
        self._sel.register(self._sock, selectors.EVENT_READ, None)
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self.host, self.port

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                events = self._sel.select(timeout=0.2)
            except OSError:
                break
            for key, mask in events:
                if key.data is None:
                    self._accept()
                else:
                    try:
                        self._service(key.data, mask)
                    except Exception:
                        # an unexpected handler exception must cost ONE
                        # connection (the old thread-per-connection blast
                        # radius), never the whole event loop — a dead loop
                        # thread would silently hang every client
                        self._close(key.data)
        for key in list(self._sel.get_map().values()):
            if key.data is not None:
                self._close(key.data)
        self._sel.close()

    def _accept(self) -> None:
        try:
            conn, _ = self._sock.accept()
        except OSError:
            return
        conn.setblocking(False)
        self._sel.register(conn, selectors.EVENT_READ, _Conn(conn))
        if self._mx is not None:
            self._mx.conns_opened.inc()

    def _close(self, c: _Conn) -> None:
        try:
            self._sel.unregister(c.sock)
        except (KeyError, ValueError):
            was_registered = False
        else:
            was_registered = True
        try:
            c.sock.close()
        except OSError:
            pass
        if self._mx is not None and was_registered:
            # guard on the unregister so a double _close counts once
            self._mx.conns_closed.inc()

    def _service(self, c: _Conn, mask: int) -> None:
        if mask & selectors.EVENT_READ:
            try:
                data = c.sock.recv(1 << 16)
            except BlockingIOError:
                data = None
            except OSError:
                self._close(c)
                return
            if data == b"":
                self._close(c)  # clean EOF
                return
            if data:
                if self._mx is not None:
                    self._mx.bytes_read.inc(len(data))
                c.rbuf += data
                if not self._handle_frames(c):
                    return
        self._flush(c)

    def _handle_frames(self, c: _Conn) -> bool:
        """Answer every complete pipelined frame in ``c.rbuf`` in order.
        Returns False if the connection was torn down (corrupt framing)."""
        try:
            wires = M.parse_frames(c.rbuf)
        except M.MessageError:
            self._close(c)  # framing corruption: the stream is unusable
            return False
        if self._mx is not None and wires:
            self._mx.frames.inc(len(wires))
            self._mx.pipeline_depth.observe(len(wires))
        for wire in wires:
            try:
                msg = M.from_wire(wire)
            except M.MessageError as e:
                reply = M.Reply(False, error=str(e))
            else:
                reply = self.daemon.handle(msg)
            c.wbuf += M.pack_frame(M.reply_to_wire(reply))
        return True

    def _flush(self, c: _Conn) -> None:
        if c.wbuf:
            try:
                n = c.sock.send(c.wbuf)
                del c.wbuf[:n]
                if self._mx is not None:
                    self._mx.bytes_written.inc(n)
            except BlockingIOError:
                pass
            except OSError:
                self._close(c)
                return
        want = selectors.EVENT_READ | (selectors.EVENT_WRITE
                                       if c.wbuf else 0)
        try:
            self._sel.modify(c.sock, want, c)
        except (KeyError, ValueError):
            pass

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        try:
            self._sock.close()
        except OSError:
            pass


def _reconnect_counter(metrics: Optional[MetricsRegistry]):
    if metrics is None:
        return None
    return metrics.counter(
        "controld_client_reconnects",
        "Client reconnect attempts after a lost connection/endpoint.")


class SocketClient:
    """Blocking request/reply client over one connection.

    With a ``RetryPolicy`` the client *reconnects* on connection loss —
    capped exponential backoff + jitter — and resends the request on the
    fresh connection instead of surfacing a raw socket error to every
    caller. Resends are safe iff requests are idempotent: stamp request
    ids (``ControldClient`` does) so the daemon dedups a resend whose
    original reply was lost. Reconnect attempts are counted on the
    ``controld_client_reconnects`` counter when ``metrics`` is given."""

    def __init__(self, host: str, port: int, timeout_s: float = 10.0,
                 retry: Optional[RetryPolicy] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 sleep=time.sleep):
        self.host, self.port = host, port
        self.timeout_s = timeout_s
        self.retry = retry
        self.sleep = sleep
        self.reconnects = 0
        self._mx_reconnects = _reconnect_counter(metrics)
        self._sock = socket.create_connection((host, port),
                                              timeout=timeout_s)

    def _reconnect(self) -> None:
        self.reconnects += 1
        if self._mx_reconnects is not None:
            self._mx_reconnects.inc()
        try:
            self._sock.close()
        except OSError:
            pass
        self._sock = socket.create_connection((self.host, self.port),
                                              timeout=self.timeout_s)

    def _with_retry(self, attempt):
        try:
            return attempt()
        except TransportError as e:
            if self.retry is None:
                raise
            last = e
        t0 = time.monotonic()
        for delay in self.retry.delays():
            if (self.retry.max_elapsed_s > 0
                    and time.monotonic() - t0 > self.retry.max_elapsed_s):
                break
            self.sleep(delay)
            try:
                self._reconnect()
                return attempt()
            except (TransportError, OSError) as e:
                last = e
                continue
        raise TransportError(
            f"socket retries to {self.host}:{self.port} exhausted: {last}")

    def call(self, msg) -> M.Reply:
        return self._with_retry(lambda: self._call_once(msg))

    def _call_once(self, msg) -> M.Reply:
        try:
            self._sock.sendall(M.pack_frame(M.to_wire(msg)))
            wire = M.read_frame(lambda n: _recv_exactly(self._sock, n))
        except (OSError, M.MessageError) as e:
            raise TransportError(f"socket call failed: {e}") from e
        if wire is None:
            raise TransportError("server closed the connection")
        return M.reply_from_wire(wire)

    def call_many(self, msgs) -> list[M.Reply]:
        """Pipelined burst: write every frame, then read the replies in
        request order — one wire round trip for the whole batch instead of
        one per message (the selector server answers frames as they land).
        With a ``RetryPolicy`` a dropped connection resends the *whole*
        burst on a fresh one (idempotent via request ids)."""
        msgs = list(msgs)
        return self._with_retry(lambda: self._call_many_once(msgs))

    def _call_many_once(self, msgs) -> list[M.Reply]:
        try:
            self._sock.sendall(
                b"".join(M.pack_frame(M.to_wire(m)) for m in msgs))
            replies = []
            for _ in msgs:
                wire = M.read_frame(lambda n: _recv_exactly(self._sock, n))
                if wire is None:
                    raise TransportError("server closed the connection")
                replies.append(M.reply_from_wire(wire))
        except (OSError, M.MessageError) as e:
            raise TransportError(f"socket call failed: {e}") from e
        return replies

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


class FailoverTransport:
    """Client-side failover across an ordered set of HA endpoints.

    ``endpoints`` are live transports or zero-arg factories (factories
    are re-invoked to reconnect after a failure — a live transport is
    reused as-is, the in-proc case). Each attempt round tries every
    endpoint once starting from the last known-good one; a
    ``TransportError`` (dead node) or a ``NOT_LEADER`` rejection (warm
    standby not yet promoted) moves to the next. Between rounds the
    transport backs off per ``retry`` (capped exponential + seeded
    jitter) using ``sleep`` — pass a virtual clock's ``advance`` for
    simulated time — and invokes ``on_retry`` (the simnet hook that
    steps the HA cluster so a standby can claim the lapsed lease).

    Correctness contract: messages MUST carry request ids
    (``ControldClient`` stamps them) — a resend whose original reply was
    lost mid-failover is deduped by the (new) leader, never
    double-applied."""

    def __init__(self, endpoints, retry: Optional[RetryPolicy] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 sleep=time.sleep, clock=time.monotonic, on_retry=None):
        if not endpoints:
            raise ValueError("FailoverTransport needs >= 1 endpoint")
        self.endpoints = list(endpoints)
        self.retry = retry if retry is not None else RetryPolicy()
        self.sleep = sleep
        self.clock = clock
        self.on_retry = on_retry
        self.reconnects = 0
        self.failovers = 0  # times the answering endpoint changed
        self._mx_reconnects = _reconnect_counter(metrics)
        self._live = [ep if not callable(ep) else None
                      for ep in self.endpoints]
        self._primary = 0

    def _get(self, i: int):
        t = self._live[i]
        if t is None:
            try:
                self._live[i] = t = self.endpoints[i]()
            except OSError as e:
                # a factory's connect refusal is an endpoint failure, not
                # a caller error — the round moves to the next endpoint
                raise TransportError(
                    f"endpoint {i} connect failed: {e}") from e
        return t

    def _drop(self, i: int) -> None:
        t = self._live[i]
        if t is not None and callable(self.endpoints[i]):
            try:
                t.close()
            except Exception:
                pass
            self._live[i] = None
        self.reconnects += 1
        if self._mx_reconnects is not None:
            self._mx_reconnects.inc()

    @staticmethod
    def _not_leader(reply: M.Reply) -> bool:
        return (not reply.ok) and reply.error.startswith(NOT_LEADER)

    def _attempt_round(self, fn):
        """One pass over the endpoints: (result, error). ``result`` is
        None when every endpoint was dead or not-leader."""
        n = len(self.endpoints)
        last = None
        for k in range(n):
            i = (self._primary + k) % n
            try:
                out = fn(self._get(i))
            except TransportError as e:
                last = e
                self._drop(i)
                continue
            first = out[0] if isinstance(out, list) else out
            if isinstance(first, M.Reply) and self._not_leader(first):
                last = TransportError(f"endpoint {i}: {first.error}")
                continue
            if i != self._primary:
                self.failovers += 1
                self._primary = i
            return out, None
        return None, last

    def _call_with_failover(self, fn):
        out, err = self._attempt_round(fn)
        if err is None:
            return out
        t0 = self.clock()
        for delay in self.retry.delays():
            if (self.retry.max_elapsed_s > 0
                    and self.clock() - t0 > self.retry.max_elapsed_s):
                break
            self.sleep(delay)
            if self.on_retry is not None:
                self.on_retry()
            out, err = self._attempt_round(fn)
            if err is None:
                return out
        raise TransportError(f"no live leader among "
                             f"{len(self.endpoints)} endpoints: {err}")

    def call(self, msg) -> M.Reply:
        return self._call_with_failover(lambda t: t.call(msg))

    def call_many(self, msgs) -> list[M.Reply]:
        msgs = list(msgs)
        return self._call_with_failover(lambda t: t.call_many(msgs))

    def close(self) -> None:
        for t in self._live:
            if t is not None:
                try:
                    t.close()
                except Exception:
                    pass


class ControldError(RuntimeError):
    """A protocol rejection surfaced by the high-level client."""


class ControldClient:
    """Convenience API over any transport: builds typed messages, raises
    ``ControldError`` on ``ok=False`` replies, returns ``reply.data``.

    Setting ``client.trace`` to a trace id (``telemetry.trace.trace_id``)
    stamps every subsequent outgoing message with it — the daemon links its
    handling spans to that id. Clear it (``""``) to stop propagating.

    Every *mutating* message is also stamped with a client-unique request
    id (``req``) — the idempotency key the daemon dedups on, which is what
    makes transport-level resends (reconnect, failover) exactly-once: the
    id is minted per logical call, so however many times the transport
    retries the same message object, the daemon applies it at most once
    and replays the same reply. ``client_id`` defaults to a random tag;
    pass a fixed one for deterministic journals (simnet does)."""

    def __init__(self, transport, client_id: Optional[str] = None):
        self.transport = transport
        self.trace = ""
        self.client_id = (uuid.uuid4().hex[:8] if client_id is None
                          else str(client_id))
        self._req_n = 0

    def _stamp(self, msg):
        patch = {}
        if self.trace and not getattr(msg, "trace", ""):
            patch["trace"] = self.trace
        if (self.client_id and msg.KIND in M.MUTATING_KINDS
                and not getattr(msg, "req", "")):
            patch["req"] = f"{self.client_id}:{self._req_n}"
            self._req_n += 1
        return dataclasses.replace(msg, **patch) if patch else msg

    def _call(self, msg) -> dict:
        reply = self.transport.call(self._stamp(msg))
        if not reply.ok:
            raise ControldError(reply.error)
        return reply.data

    def reserve(self, policy: str = "proportional",
                policy_params: dict | None = None,
                instance_hint: int = -1) -> dict:
        return self._call(M.Reserve(policy=policy,
                                    policy_params=policy_params or {},
                                    instance_hint=instance_hint))

    def reserve_fabric(self, k: int = 2, policy: str = "proportional",
                       policy_params: dict | None = None,
                       reserved_fraction: float = 0.25) -> dict:
        """Atomically reserve a two-tier fabric: ``k`` LBs, each a (spray,
        reserved) session pair. Returns the daemon's ``{"fabric", "k",
        "reserved_fraction", "lease_s", "sessions": [{"lb", "spray",
        "reserved"}, ...]}``."""
        return self._call(M.ReserveFabric(
            k=k, policy=policy, policy_params=policy_params or {},
            reserved_fraction=reserved_fraction))

    def free(self, token: str) -> dict:
        return self._call(M.Free(token=token))

    def register(self, token: str, member_id: int, node_id: int | None = None,
                 base_lane: int = 0, lane_bits: int = 0,
                 weight: float = 1.0) -> dict:
        return self._call(M.Register(
            token=token, member_id=member_id,
            node_id=member_id if node_id is None else node_id,
            base_lane=base_lane, lane_bits=lane_bits, weight=weight))

    def register_batch(self, token: str, member_ids, node_ids=None,
                       base_lanes=None, lane_bits=0, weights=None) -> dict:
        """One bring-up wave in one frame. ``node_ids`` defaults to the
        member ids; ``lane_bits`` may be a scalar (applied to every member)
        or a parallel array. Returns the daemon's ``{"n_accepted",
        "member_ids", "lease_expires", "rejected"}`` — per-member
        validation failures live in ``rejected``, they do not raise: the
        rest of the wave is admitted."""
        # np integers -> python ints for JSON; anything non-integral passes
        # through untouched so the daemon rejects it per-member (a client-
        # side int() would silently truncate onto the wrong lane)
        def as_id(m):
            return (int(m) if isinstance(m, (int, np.integer))
                    and not isinstance(m, bool) else m)

        ids = [as_id(m) for m in member_ids]
        n = len(ids)
        if np.isscalar(lane_bits):
            lane_bits = [lane_bits] * n
        return self._call(M.RegisterBatch(
            token=token, member_ids=ids,
            node_ids=(list(ids) if node_ids is None
                      else [as_id(m) for m in node_ids]),
            base_lanes=([0] * n if base_lanes is None else list(base_lanes)),
            lane_bits=[as_id(b) for b in lane_bits],
            weights=([1.0] * n if weights is None
                     else [float(w) for w in weights])))

    def deregister(self, token: str, member_id: int) -> dict:
        return self._call(M.Deregister(token=token, member_id=member_id))

    def deregister_batch(self, token: str, member_ids) -> dict:
        """One teardown wave in one frame — the mirror of
        ``register_batch``. Returns the daemon's ``{"n_accepted",
        "member_ids", "rejected"}`` — unregistered members live in
        ``rejected``, they do not raise: the rest of the wave drains."""
        # np integers -> python ints for JSON; anything non-integral passes
        # through untouched so the daemon rejects it per-member
        ids = [int(m) if isinstance(m, (int, np.integer))
               and not isinstance(m, bool) else m for m in member_ids]
        return self._call(M.DeregisterBatch(token=token, member_ids=ids))

    def send_state(self, token: str, member_id: int, fill: float,
                   rate: float = 1.0, healthy: bool = True) -> dict:
        return self._call(M.SendState(token=token, member_id=member_id,
                                      fill=fill, rate=rate, healthy=healthy))

    def send_state_batch(self, token: str, member_ids, fills,
                         rates=None, healthy=None) -> dict:
        """One window of heartbeats in one frame. Returns the daemon's
        ``{"n_accepted", "lease_expires", "rejected"}`` — per-member
        rejections (lapsed/no lease) live in ``rejected``, they do not
        raise: the rest of the window is accepted."""
        # np integers -> python ints for JSON; anything non-integral passes
        # through untouched so the daemon rejects it per-member (a client-
        # side int() would silently truncate onto the wrong lane)
        ids = [int(m) if isinstance(m, (int, np.integer))
               and not isinstance(m, bool) else m for m in member_ids]
        return self._call(M.SendStateBatch(
            token=token, member_ids=ids,
            fills=[float(f) for f in fills],
            rates=([1.0] * len(ids) if rates is None
                   else [float(r) for r in rates]),
            healthy=([True] * len(ids) if healthy is None
                     else [bool(h) for h in healthy])))

    def heartbeat_window(self, token: str, samples: dict,
                         lane_bits: int = 0) -> dict:
        """One batched heartbeat window from a telemetry snapshot
        ``{member_id: MemberTelemetry-like}`` (``.fill``/``.rate``/
        ``.healthy``). Members whose lease lapsed come back rejected; for a
        caller that owns its members (serve engine, trainer) the right move
        is always re-register (node_id = member_id) and resend their
        samples — done here so every embedder shares one protocol dance.
        Returns the first batch's reply."""
        def send(ids):
            return self.send_state_batch(
                token, ids, [samples[m].fill for m in ids],
                [samples[m].rate for m in ids],
                [samples[m].healthy for m in ids])

        ids = sorted(samples)
        if not ids:
            return {"n_accepted": 0, "lease_expires": 0.0, "rejected": {}}
        reply = send(ids)
        retry = sorted(int(m) for m in reply["rejected"])
        if retry:
            self.register_batch(token, retry, lane_bits=lane_bits)
            send(retry)
        return reply

    def call_many(self, msgs) -> list[M.Reply]:
        """Raw pipelined burst of typed messages (replies, not data)."""
        return self.transport.call_many([self._stamp(m) for m in msgs])

    def tick(self, current_event: int, gc_event: int = -1) -> dict:
        return self._call(M.Tick(current_event=current_event,
                                 gc_event=gc_event))

    def status(self, token: str = "") -> dict:
        return self._call(M.Status(token=token))

    def close(self) -> None:
        self.transport.close()
