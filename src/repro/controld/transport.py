"""controld transports: in-process and length-prefixed socket.

Both fronts speak the exact same wire form (``controld.messages``): the
in-process transport round-trips every request and reply through the JSON
frame encoder before delivery, so anything that works in-proc works over the
socket byte-for-byte (property-tested in tests/test_controld.py). In-proc is
what simnet and the serving engine embed (deterministic, virtual-clock
friendly); the socket server is what ``scripts/run_controld.py`` exposes for
real CN daemons.
"""
from __future__ import annotations

import socket
import threading
from typing import Optional

from repro.controld import messages as M
from repro.controld.daemon import ControlDaemon


class TransportError(RuntimeError):
    """The transport failed (connection, framing) — distinct from a protocol
    rejection, which arrives as ``Reply(ok=False)``."""


class InProcTransport:
    """Direct call into a daemon in the same process — through the wire
    encoding, so semantics are identical to the socket path."""

    def __init__(self, daemon: ControlDaemon):
        self.daemon = daemon

    def call(self, msg) -> M.Reply:
        wire = M.read_frame(_BufReader(M.pack_frame(M.to_wire(msg))).read)
        reply = self.daemon.handle(M.from_wire(wire))
        back = M.read_frame(
            _BufReader(M.pack_frame(M.reply_to_wire(reply))).read)
        return M.reply_from_wire(back)

    def close(self) -> None:
        pass


class _BufReader:
    def __init__(self, data: bytes):
        self._data = data
        self._pos = 0

    def read(self, n: int) -> bytes:
        out = self._data[self._pos:self._pos + n]
        self._pos += len(out)
        return out


def _recv_exactly(sock: socket.socket, n: int) -> bytes:
    chunks = []
    remaining = n
    while remaining:
        chunk = sock.recv(remaining)
        if not chunk:
            break
        chunks.append(chunk)
        remaining -= len(chunk)
    return b"".join(chunks)


class SocketServer:
    """Threaded length-prefixed-JSON server over a ``ControlDaemon``.

    One thread per connection; a lock serializes ``daemon.handle`` (the
    daemon is deliberately single-writer — the journal is a total order)."""

    def __init__(self, daemon: ControlDaemon, host: str = "127.0.0.1",
                 port: int = 0):
        self.daemon = daemon
        self._lock = threading.Lock()
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self.host, self.port = self._sock.getsockname()
        self._stop = threading.Event()
        self._accept_thread: Optional[threading.Thread] = None
        self._conn_threads: list[threading.Thread] = []

    def start(self) -> tuple[str, int]:
        self._sock.listen(16)
        self._sock.settimeout(0.2)
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()
        return self.host, self.port

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            t.start()
            # prune finished connections so a long-running daemon's thread
            # list stays bounded by *live* connections, not total served
            self._conn_threads = [c for c in self._conn_threads
                                  if c.is_alive()]
            self._conn_threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        with conn:
            while not self._stop.is_set():
                try:
                    wire = M.read_frame(lambda n: _recv_exactly(conn, n))
                except (M.MessageError, OSError):
                    break
                if wire is None:
                    break  # clean EOF
                try:
                    msg = M.from_wire(wire)
                except M.MessageError as e:
                    reply = M.Reply(False, error=str(e))
                else:
                    with self._lock:
                        reply = self.daemon.handle(msg)
                try:
                    conn.sendall(M.pack_frame(M.reply_to_wire(reply)))
                except OSError:
                    break

    def stop(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=2.0)
        for t in self._conn_threads:
            t.join(timeout=2.0)


class SocketClient:
    """Blocking request/reply client over one connection."""

    def __init__(self, host: str, port: int, timeout_s: float = 10.0):
        self._sock = socket.create_connection((host, port),
                                              timeout=timeout_s)

    def call(self, msg) -> M.Reply:
        try:
            self._sock.sendall(M.pack_frame(M.to_wire(msg)))
            wire = M.read_frame(lambda n: _recv_exactly(self._sock, n))
        except (OSError, M.MessageError) as e:
            raise TransportError(f"socket call failed: {e}") from e
        if wire is None:
            raise TransportError("server closed the connection")
        return M.reply_from_wire(wire)

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


class ControldError(RuntimeError):
    """A protocol rejection surfaced by the high-level client."""


class ControldClient:
    """Convenience API over any transport: builds typed messages, raises
    ``ControldError`` on ``ok=False`` replies, returns ``reply.data``."""

    def __init__(self, transport):
        self.transport = transport

    def _call(self, msg) -> dict:
        reply = self.transport.call(msg)
        if not reply.ok:
            raise ControldError(reply.error)
        return reply.data

    def reserve(self, policy: str = "proportional",
                policy_params: dict | None = None,
                instance_hint: int = -1) -> dict:
        return self._call(M.Reserve(policy=policy,
                                    policy_params=policy_params or {},
                                    instance_hint=instance_hint))

    def free(self, token: str) -> dict:
        return self._call(M.Free(token=token))

    def register(self, token: str, member_id: int, node_id: int | None = None,
                 base_lane: int = 0, lane_bits: int = 0,
                 weight: float = 1.0) -> dict:
        return self._call(M.Register(
            token=token, member_id=member_id,
            node_id=member_id if node_id is None else node_id,
            base_lane=base_lane, lane_bits=lane_bits, weight=weight))

    def deregister(self, token: str, member_id: int) -> dict:
        return self._call(M.Deregister(token=token, member_id=member_id))

    def send_state(self, token: str, member_id: int, fill: float,
                   rate: float = 1.0, healthy: bool = True) -> dict:
        return self._call(M.SendState(token=token, member_id=member_id,
                                      fill=fill, rate=rate, healthy=healthy))

    def tick(self, current_event: int, gc_event: int = -1) -> dict:
        return self._call(M.Tick(current_event=current_event,
                                 gc_event=gc_event))

    def status(self, token: str = "") -> dict:
        return self._call(M.Status(token=token))

    def close(self) -> None:
        self.transport.close()
