"""controld — the session-oriented control-plane daemon.

The paper's control plane is a long-running service on the FPGA host: CN
daemons *register with* it, stream telemetry to it, and it makes redirection
decisions continuously. ``ControlDaemon`` is that service for this repro
(DESIGN.md §Controld):

* **Reservations** (multi-tenancy, paper §I-C): the daemon owns N virtual LB
  instances; ``Reserve`` leases one to a tenant and returns a token that
  scopes every subsequent member call. Each reservation gets its own
  ``EpochManager`` + ``LoadBalancerControlPlane`` with the reweighting
  policy the tenant selected (``controld.policy``).
* **Leases**: a registered member holds a lease renewed by ``SendState``
  heartbeats. A lease expiring at a ``Tick`` triggers the *same* hit-less
  drain as ``mark_failed`` — removed from the next epoch, in-flight events
  keep routing to it until the boundary. This is ``TelemetryHub.stale_after``
  promoted from a passive snapshot flag to a protocol rule: a heartbeat for
  a lapsed lease is rejected and the member must re-register.
* **Ticks**: all time-driven behavior (lease expiry, session start, policy
  feedback, epoch GC) happens in explicit ``Tick`` messages, so virtual-time
  drivers (simnet) and journal replay are deterministic.
* **Journal**: every mutating message is appended to an event-sourced
  journal (``controld.journal``) with the clock instant it was handled at,
  *before* it executes. ``recover`` replays a journal through a fresh daemon
  and reproduces byte-identical calendar state (``state_digest``) — a
  restarted daemon resumes mid-epoch with identical calendars.

The daemon is transport-agnostic: ``handle`` takes a typed message and
returns a ``Reply``; ``controld.transport`` provides the in-process and
length-prefixed-socket fronts.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from bisect import insort
from typing import Callable, Optional

import numpy as np

from repro.controld import messages as M
from repro.controld.journal import Entry, Journal
from repro.controld.policy import make_policy
from repro.core.control_plane import (ControlPolicy, LoadBalancerControlPlane,
                                      TelemetryArray)
from repro.core.epoch import EpochManager
from repro.core.tables import MemberSpec, TableError
from repro.telemetry.registry import SIZE_BUCKETS, MetricsRegistry
from repro.telemetry.trace import parse_trace_id


class SessionError(ValueError):
    """Protocol-level rejection (bad token, lapsed lease, no free instance).
    Returned to the client as ``Reply(ok=False)``, never raised across the
    transport."""


class MemberLanes:
    """Array-native per-reservation member state: lease + telemetry lanes.

    One lane per member id in ``[0, max_members)``. Telemetry lanes default
    to ``MemberTelemetry()`` (fill 0, rate 1, healthy) so a registered
    member that has not heartbeat yet reads exactly what the dict path's
    ``telemetry.get(m, MemberTelemetry())`` produced; ``sampled`` tracks
    which lanes hold a real sample (for status/digest views). A whole
    heartbeat window lands as one fancy-index scatter."""

    def __init__(self, max_members: int):
        self.leased = np.zeros(max_members, bool)
        self.lease_expires = np.full(max_members, -np.inf, np.float64)
        self.fill = np.zeros(max_members, np.float64)
        self.rate = np.ones(max_members, np.float64)
        self.healthy = np.ones(max_members, bool)
        self.sampled = np.zeros(max_members, bool)

    def grant(self, member_id: int, expires: float) -> None:
        self.leased[member_id] = True
        self.lease_expires[member_id] = expires

    def revoke(self, member_ids) -> None:
        """Drop leases AND telemetry lanes (lease expiry / deregister)."""
        idx = np.asarray(member_ids, np.int64)
        self.leased[idx] = False
        self.lease_expires[idx] = -np.inf
        self.clear_samples(idx)

    def clear_samples(self, member_ids) -> None:
        idx = np.asarray(member_ids, np.int64)
        self.fill[idx] = 0.0
        self.rate[idx] = 1.0
        self.healthy[idx] = True
        self.sampled[idx] = False

    def scatter(self, member_ids, fills, rates, healthy,
                expires: float) -> None:
        """One window of accepted heartbeats in one pass (last-sample-wins
        for duplicate ids, numpy scatter semantics)."""
        idx = np.asarray(member_ids, np.int64)
        self.lease_expires[idx] = expires
        self.fill[idx] = fills
        self.rate[idx] = rates
        self.healthy[idx] = healthy
        self.sampled[idx] = True

    # -- views (status / digest / dict-path interop) --------------------------
    def lease_ids(self) -> list[int]:
        return [int(m) for m in np.flatnonzero(self.leased)]

    def lease_view(self) -> dict[int, float]:
        return {int(m): float(self.lease_expires[m])
                for m in np.flatnonzero(self.leased)}

    def telemetry_view(self) -> dict[int, dict]:
        return {int(m): {"fill": float(self.fill[m]),
                         "rate": float(self.rate[m]),
                         "healthy": bool(self.healthy[m])}
                for m in np.flatnonzero(self.sampled)}


@dataclasses.dataclass
class Session:
    """One reservation: a tenant's lease on one virtual LB instance."""

    token: str
    instance: int
    policy_name: str
    manager: EpochManager
    cp: LoadBalancerControlPlane
    lanes: MemberLanes
    pending: dict[int, tuple[MemberSpec, float]] = dataclasses.field(
        default_factory=dict)  # registered before the session started
    started: bool = False
    fabric: str = ""          # ReserveFabric grouping ("" = standalone)
    # per-reservation message-rate quota (token bucket; tokens < 0 = off)
    quota_tokens: float = -1.0
    quota_t: float = 0.0
    counters: dict[str, int] = dataclasses.field(
        default_factory=lambda: {"heartbeats": 0, "epoch_switches": 0,
                                 "leases_expired": 0, "registered": 0,
                                 "deregistered": 0, "quota_rejected": 0})


class _DaemonMetrics:
    """Pre-resolved registry children for the daemon's hot paths.

    Children are looked up ONCE here, at construction, so the per-message
    cost is a dict hit on ``msg.KIND`` plus plain float adds — this is what
    keeps ``bench_metrics`` under its 5% overhead gate. Occupancy is exported
    as callback gauges straight over ``MemberLanes`` arrays: nothing runs
    until a scrape asks.
    """

    def __init__(self, registry: MetricsRegistry, daemon: "ControlDaemon",
                 kinds) -> None:
        self.registry = registry
        msgs = registry.counter(
            "controld_messages_total", "Messages handled, by kind.",
            labelnames=("kind",))
        rejs = registry.counter(
            "controld_rejects_total",
            "Protocol rejections (Reply ok=False), by kind.",
            labelnames=("kind",))
        secs = registry.histogram(
            "controld_handle_seconds", "Message handling latency, by kind.",
            labelnames=("kind",))
        self.messages = {k: msgs.labels(kind=k) for k in kinds}
        self.rejects = {k: rejs.labels(kind=k) for k in kinds}
        self.handle_seconds = {k: secs.labels(kind=k) for k in kinds}
        self.heartbeats = registry.counter(
            "controld_heartbeats_total", "Accepted member heartbeats.")
        self.hb_batch = registry.histogram(
            "controld_heartbeat_batch_size",
            "Members per SendStateBatch window.", buckets=SIZE_BUCKETS)
        self.leases_reaped = registry.counter(
            "controld_leases_reaped_total", "Leases expired at a Tick.")
        self.quota_rejects = registry.counter(
            "controld_quota_rejects",
            "Messages rejected by a reservation's rate quota.")
        self.epoch_switches = registry.counter(
            "controld_epoch_switches_total",
            "Hit-less epoch switches scheduled by policy feedback.")
        registry.gauge(
            "controld_sessions_active", "Live reservations."
        ).set_function(lambda: len(daemon.sessions))
        registry.gauge(
            "controld_instances_free", "Unreserved virtual LB instances."
        ).set_function(lambda: len(daemon._free_instances))

    def watch_session(self, s: "Session") -> None:
        """Callback gauges over one reservation's MemberLanes arrays."""
        lanes = s.lanes
        self.registry.gauge(
            "controld_session_members", "Leased members, by reservation.",
            labelnames=("token",)
        ).labels(token=s.token).set_function(
            lambda: int(lanes.leased.sum()))
        self.registry.gauge(
            "controld_session_mean_fill",
            "Mean reported queue fill over sampled lanes, by reservation.",
            labelnames=("token",)
        ).labels(token=s.token).set_function(
            lambda: float(lanes.fill[lanes.sampled].mean())
            if lanes.sampled.any() else 0.0)

    def drop_session(self, token: str) -> None:
        for name in ("controld_session_members", "controld_session_mean_fill"):
            self.registry.gauge(name, labelnames=("token",)).remove(
                token=token)


class ControlDaemon:
    """Session manager over N virtual LB instances (module docstring)."""

    def __init__(self, n_instances: int = 4,
                 clock: Callable[[], float] = time.time,
                 lease_s: float = 10.0,
                 epoch_horizon: int = 1024,
                 max_members: int = 64,
                 journal: Optional[Journal] = None,
                 policy_engine: str = "np",
                 metrics: Optional[MetricsRegistry] = None,
                 quota_msgs_per_s: Optional[float] = None,
                 quota_burst: Optional[float] = None,
                 trace=None,
                 req_cache_size: int = 4096):
        self.n_instances = n_instances
        self.clock = clock
        self.lease_s = float(lease_s)
        self.epoch_horizon = int(epoch_horizon)
        self.max_members = int(max_members)
        self.journal = journal
        # per-reservation message-rate quota (None = unlimited): a token
        # bucket refilled at quota_msgs_per_s, capped at quota_burst. One
        # noisy tenant exhausts its own bucket, not the daemon — over-quota
        # member-lifecycle/heartbeat messages are protocol rejections.
        # Batch messages cost ONE token: batching is the sanctioned way to
        # say more under the same quota.
        self.quota_msgs_per_s = (None if quota_msgs_per_s is None
                                 else float(quota_msgs_per_s))
        self.quota_burst = (max(16.0, 2.0 * self.quota_msgs_per_s)
                            if quota_burst is None
                            and self.quota_msgs_per_s is not None
                            else None if quota_burst is None
                            else float(quota_burst))
        # engine for the fused per-Tick policy update ("np" = bit-identical
        # to the scalar path; "jnp" = one device call per update). Recover a
        # journal with the SAME engine it was written under — replay runs
        # the same arithmetic, so digests only match engine-to-engine.
        self.policy_engine = policy_engine
        self.sessions: dict[str, Session] = {}
        #: fabric groupings from ReserveFabric: id -> {"tokens", "k",
        #: "reserved_fraction"} — the lane-partition contract of record
        self.fabrics: dict[str, dict] = {}
        self._free_instances: list[int] = list(range(n_instances))
        self._token_counter = 0
        self._fabric_counter = 0
        self._replaying = False
        # request-id dedup (idempotent resend across reconnect/failover):
        # client-stamped ``req`` ids map to the reply the daemon already
        # gave, so a resend after a lost reply or a mid-call failover
        # never double-applies. The ``req`` rides in the journal payload,
        # so replay (and a warm standby applying shipped entries) rebuilds
        # this cache deterministically — a resend lands correctly on the
        # *successor* too. FIFO-evicted at ``req_cache_size`` (insertion
        # order is replay-deterministic).
        self.req_cache_size = int(req_cache_size)
        self._req_replies: dict[str, M.Reply] = {}
        self._handlers = {
            M.Reserve.KIND: self._reserve,
            M.Free.KIND: self._free,
            M.ReserveFabric.KIND: self._reserve_fabric,
            M.Register.KIND: self._register,
            M.RegisterBatch.KIND: self._register_batch,
            M.Deregister.KIND: self._deregister,
            M.DeregisterBatch.KIND: self._deregister_batch,
            M.SendState.KIND: self._send_state,
            M.SendStateBatch.KIND: self._send_state_batch,
            M.Tick.KIND: self._tick,
            M.Status.KIND: self._status,
        }
        # metrics=None keeps every hot path bit-identical to the
        # uninstrumented daemon (no branches taken, nothing allocated)
        self._mx = (None if metrics is None
                    else _DaemonMetrics(metrics, self, self._handlers))
        # trace: a telemetry.trace.TraceBuffer — per-message spans for
        # requests that carry a trace id (journal replay records nothing)
        self.trace = trace

    # -- the single entry point ----------------------------------------------
    def handle(self, msg, now: Optional[float] = None) -> M.Reply:
        """Dedup (client request ids), journal (mutating kinds, WAL-style:
        before execution, so replay sees the exact accepted sequence —
        rejected messages replay to the same rejection), execute, reply.
        Protocol errors become ``Reply(ok=False)``; anything else is a bug
        and propagates. A resent ``req`` the daemon has already answered
        returns the cached reply *before* the journal append — a resend is
        never a second WAL entry."""
        fn = self._handlers.get(msg.KIND)
        if fn is None:
            return M.Reply(False, error=f"unhandled message {msg.KIND!r}")
        if now is None:
            now = float(self.clock())
        req = getattr(msg, "req", "")
        if req:
            cached = self._req_replies.get(req)
            if cached is not None:
                return cached
        if (msg.KIND in M.MUTATING_KINDS and not self._replaying
                and self.journal is not None):
            payload = M.to_wire(msg)
            payload.pop("kind")
            payload["now"] = now
            self.journal.append(msg.KIND, payload)
        reply = self._execute(fn, msg, now)
        if req and msg.KIND in M.MUTATING_KINDS:
            self._req_replies[req] = reply
            if len(self._req_replies) > self.req_cache_size:
                del self._req_replies[next(iter(self._req_replies))]
        return reply

    def _execute(self, fn, msg, now: float) -> M.Reply:
        mx = None if self._replaying else self._mx
        tr = (self.trace if self.trace is not None and not self._replaying
              and getattr(msg, "trace", "") else None)
        if mx is None and tr is None:
            try:
                return M.Reply(True, data=fn(msg, now))
            except SessionError as e:
                return M.Reply(False, error=str(e))
        t0 = time.perf_counter()
        ok = True
        try:
            return M.Reply(True, data=fn(msg, now))
        except SessionError as e:
            ok = False
            if mx is not None:
                mx.rejects[msg.KIND].inc()
            return M.Reply(False, error=str(e))
        finally:
            dt = time.perf_counter() - t0
            if mx is not None:
                mx.messages[msg.KIND].inc()
                mx.handle_seconds[msg.KIND].observe(dt)
            if tr is not None:
                self._record_span(tr, msg, now, dt, ok)

    def _record_span(self, tr, msg, now: float, wall_s: float,
                     ok: bool) -> None:
        """One ``controld.<kind>`` span for a traced request: anchored at
        the virtual-clock instant it was handled, with the measured wall
        handling time as its duration (aux = 1 accepted / 0 rejected). A
        malformed trace id is ignored — tracing must never reject a
        message the untraced daemon would accept."""
        try:
            key = parse_trace_id(msg.trace)
        except (TypeError, ValueError):
            return
        tr.record_window("controld." + msg.KIND,
                         np.asarray([key], np.uint64),
                         np.asarray([now], np.float64),
                         np.asarray([now + wall_s], np.float64),
                         aux=np.asarray([1 if ok else 0], np.int64))

    def _session(self, token: str) -> Session:
        s = self.sessions.get(token)
        if s is None:
            raise SessionError(f"unknown or expired reservation {token!r}")
        return s

    def _member_index(self, member_id) -> Optional[int]:
        """Validated lane index, or None when ``member_id`` cannot address a
        lane. A non-integer id (a string or float is valid JSON!) must be a
        protocol rejection, never a TypeError/IndexError — the message is
        already in the WAL, and a handler crash would replay forever."""
        if isinstance(member_id, bool) or not isinstance(
                member_id, (int, np.integer)):
            return None
        mid = int(member_id)
        return mid if 0 <= mid < self.max_members else None

    # -- per-reservation message-rate quota -----------------------------------
    def _charge_quota(self, s: Session, now: float) -> None:
        """Token-bucket admission for one token-scoped message. Refill is
        computed from journaled ``now`` instants, so quota state (and every
        over-quota rejection) replays deterministically from the WAL."""
        if self.quota_msgs_per_s is None:
            return
        if s.quota_tokens < 0:  # session created before quotas were enabled
            s.quota_tokens, s.quota_t = self.quota_burst, now
        elapsed = max(now - s.quota_t, 0.0)
        s.quota_tokens = min(self.quota_burst,
                             s.quota_tokens + elapsed * self.quota_msgs_per_s)
        s.quota_t = now
        if s.quota_tokens < 1.0:
            s.counters["quota_rejected"] += 1
            if self._mx is not None and not self._replaying:
                self._mx.quota_rejects.inc()
            raise SessionError(
                f"reservation {s.token} over its message-rate quota "
                f"({self.quota_msgs_per_s:g} msg/s) — back off, or batch")
        s.quota_tokens -= 1.0

    # -- reservation lifecycle ------------------------------------------------
    def _new_session(self, inst: int, policy, now: float,
                     fabric: str = "") -> Session:
        """One reservation's state on an already-claimed instance."""
        token = f"r{self._token_counter:06d}"
        self._token_counter += 1
        manager = EpochManager(max_members=self.max_members)
        cp = LoadBalancerControlPlane(
            manager, ControlPolicy(epoch_horizon=self.epoch_horizon),
            reweighter=policy)
        cp.array_engine = self.policy_engine
        s = self.sessions[token] = Session(
            token=token, instance=inst, policy_name=policy.name,
            manager=manager, cp=cp, lanes=MemberLanes(self.max_members),
            fabric=fabric)
        if self.quota_msgs_per_s is not None:
            s.quota_tokens, s.quota_t = self.quota_burst, now
        if self._mx is not None:
            # runs during replay too: recovered sessions keep their gauges
            self._mx.watch_session(s)
        return s

    def _reserve(self, msg: M.Reserve, now: float) -> dict:
        if not self._free_instances:
            raise SessionError(
                f"all {self.n_instances} LB instances are reserved")
        if msg.instance_hint >= 0:
            if msg.instance_hint not in self._free_instances:
                raise SessionError(
                    f"instance {msg.instance_hint} is not free")
            inst = msg.instance_hint
            self._free_instances.remove(inst)
        else:
            inst = self._free_instances.pop(0)
        try:
            policy = make_policy(msg.policy, msg.policy_params)
        except ValueError as e:
            insort(self._free_instances, inst)
            raise SessionError(str(e)) from None
        s = self._new_session(inst, policy, now)
        return {"token": s.token, "instance": inst, "policy": policy.name,
                "lease_s": self.lease_s}

    def _reserve_fabric(self, msg: M.ReserveFabric, now: float) -> dict:
        """Atomically reserve a tier of ``k`` LBs, each as a (spray,
        reserved) session pair — the per-instance lane partition. All
        validation happens before any instance is claimed, so a rejection
        leaves the free pool untouched (and replays to the same rejection)."""
        if isinstance(msg.k, bool) or not isinstance(msg.k, int) or msg.k < 1:
            raise SessionError(f"fabric size k={msg.k!r} must be an int >= 1")
        try:
            frac = float(msg.reserved_fraction)
        except (TypeError, ValueError):
            raise SessionError(
                f"reserved_fraction {msg.reserved_fraction!r} is not a "
                "number") from None
        if not (0.0 < frac < 1.0):
            raise SessionError(
                f"reserved_fraction must be in (0, 1), got {frac!r}")
        if len(self._free_instances) < 2 * msg.k:
            raise SessionError(
                f"fabric needs {2 * msg.k} free instances "
                f"(k={msg.k} x spray+reserved), have "
                f"{len(self._free_instances)}")
        try:
            make_policy(msg.policy, msg.policy_params)  # validate only
        except ValueError as e:
            raise SessionError(str(e)) from None
        fabric_id = f"f{self._fabric_counter:06d}"
        self._fabric_counter += 1
        sessions, tokens = [], []
        for lb in range(msg.k):
            pair = {}
            for klass in ("spray", "reserved"):
                inst = self._free_instances.pop(0)
                # one fresh (stateful) policy per session
                policy = make_policy(msg.policy, msg.policy_params)
                s = self._new_session(inst, policy, now, fabric=fabric_id)
                pair[klass] = s.token
                tokens.append(s.token)
            sessions.append({"lb": lb, **pair})
        self.fabrics[fabric_id] = {"tokens": tokens, "k": msg.k,
                                   "reserved_fraction": frac}
        return {"fabric": fabric_id, "k": msg.k, "reserved_fraction": frac,
                "lease_s": self.lease_s, "sessions": sessions}

    def _free(self, msg: M.Free, now: float) -> dict:
        s = self._session(msg.token)
        del self.sessions[msg.token]
        insort(self._free_instances, s.instance)
        if s.fabric and s.fabric in self.fabrics:
            fab = self.fabrics[s.fabric]
            fab["tokens"] = [t for t in fab["tokens"] if t != msg.token]
            if not fab["tokens"]:
                del self.fabrics[s.fabric]
        if self._mx is not None:
            self._mx.drop_session(msg.token)
        return {"instance": s.instance, "counters": dict(s.counters)}

    # -- member lifecycle -----------------------------------------------------
    def _validate_member(self, member_id, node_id, base_lane, lane_bits,
                         weight) -> tuple[int, MemberSpec, float]:
        """One member's registration fields -> (lane, spec, weight), or a
        ``SessionError``. Every field a later (journaled!) step consumes is
        validated HERE, as a protocol rejection: a bad value that only blew
        up inside the starting Tick (e.g. weight=0 in cp.start) would crash
        *after* its WAL append and poison the journal for every future
        recover()."""
        mid = self._member_index(member_id)
        if mid is None:
            raise SessionError(
                f"member id {member_id!r} out of range "
                f"(max {self.max_members})")
        try:
            w = float(weight)
        except (TypeError, ValueError):
            raise SessionError(
                f"weight {weight!r} is not a number") from None
        if not (w > 0.0) or not np.isfinite(w):
            raise SessionError(
                f"weight must be positive and finite, got {weight!r}")
        try:
            spec = MemberSpec(node_id=node_id, base_lane=base_lane,
                              lane_bits=lane_bits)
        except (TableError, TypeError) as e:
            raise SessionError(str(e)) from None
        return mid, spec, w

    def _admit(self, s: Session, mid: int, spec: MemberSpec, weight: float,
               expires: float) -> None:
        s.lanes.grant(mid, expires)
        s.counters["registered"] += 1
        if s.started:
            # (re-)joining a live session: the next tick's feedback sees the
            # membership delta and schedules a hit-less epoch switch
            s.cp.add_members({mid: spec}, weight=weight)
            s.lanes.clear_samples([mid])
        else:
            s.pending[mid] = (spec, weight)

    def _register(self, msg: M.Register, now: float) -> dict:
        s = self._session(msg.token)
        self._charge_quota(s, now)
        mid, spec, weight = self._validate_member(
            msg.member_id, msg.node_id, msg.base_lane, msg.lane_bits,
            msg.weight)
        expires = now + self.lease_s
        self._admit(s, mid, spec, weight, expires)
        return {"member_id": msg.member_id, "lease_expires": expires}

    def _register_batch(self, msg: M.RegisterBatch, now: float) -> dict:
        """One bring-up wave in one journal entry. Per-member semantics are
        exactly N ``Register`` messages at this instant, except validation
        failures are per-member (in the reply's ``rejected`` map) instead of
        per-message; duplicates of an id resolve last-spec-wins."""
        s = self._session(msg.token)
        self._charge_quota(s, now)
        try:
            cols = [list(msg.member_ids), list(msg.node_ids),
                    list(msg.base_lanes), list(msg.lane_bits),
                    list(msg.weights)]
        except TypeError:
            raise SessionError(
                "batch fields must be parallel arrays") from None
        if len({len(c) for c in cols}) != 1:
            raise SessionError("batch arrays must be the same length")
        expires = now + self.lease_s
        accepted, rejected = [], {}
        for member_id, node_id, base_lane, lane_bits, weight in zip(*cols):
            try:
                mid, spec, w = self._validate_member(
                    member_id, node_id, base_lane, lane_bits, weight)
            except SessionError as e:
                rejected[str(member_id)] = str(e)
                continue
            self._admit(s, mid, spec, w, expires)
            accepted.append(mid)
        return {"n_accepted": len(accepted), "member_ids": accepted,
                "lease_expires": expires, "rejected": rejected}

    def _deregister(self, msg: M.Deregister, now: float) -> dict:
        s = self._session(msg.token)
        self._charge_quota(s, now)
        mid = self._member_index(msg.member_id)
        if mid is None or not s.lanes.leased[mid]:
            raise SessionError(f"member {msg.member_id} is not registered")
        s.lanes.revoke([mid])
        s.counters["deregistered"] += 1
        if s.started:
            # graceful exit == the failure drain: out of the next epoch,
            # in-flight events keep their member (epoch immutability)
            s.cp.mark_failed([msg.member_id])
        else:
            s.pending.pop(msg.member_id, None)
        return {"member_id": msg.member_id}

    def _deregister_batch(self, msg: M.DeregisterBatch, now: float) -> dict:
        """One teardown wave in one journal entry — the mirror of
        ``_register_batch``. Per-member semantics are exactly N
        ``Deregister`` messages at this instant (same revoke, same counters,
        same hit-less ``mark_failed`` drain), except unregistered members
        are per-member rejections in the reply; a duplicated id deregisters
        once and rejects the rest (it is no longer leased by then)."""
        s = self._session(msg.token)
        self._charge_quota(s, now)
        try:
            raw = list(msg.member_ids)
        except TypeError:
            raise SessionError("member_ids must be an array") from None
        accepted, rejected = [], {}
        for member_id in raw:
            mid = self._member_index(member_id)
            if mid is None or not s.lanes.leased[mid]:
                rejected[str(member_id)] = (
                    f"member {member_id!r} is not registered")
                continue
            s.lanes.revoke([mid])
            s.counters["deregistered"] += 1
            accepted.append(mid)
        if accepted:
            if s.started:
                # one call, but mark_failed drains per member — digest-
                # identical to N scalar Deregisters at this instant
                s.cp.mark_failed(accepted)
            else:
                for mid in accepted:
                    s.pending.pop(mid, None)
        return {"n_accepted": len(accepted), "member_ids": accepted,
                "rejected": rejected}

    def _send_state(self, msg: M.SendState, now: float) -> dict:
        s = self._session(msg.token)
        self._charge_quota(s, now)
        mid = self._member_index(msg.member_id)
        if mid is None or not s.lanes.leased[mid]:
            raise SessionError(
                f"member {msg.member_id} holds no lease (expired or never "
                "registered) — re-register to rejoin")
        expires = float(s.lanes.lease_expires[mid])
        if expires <= now:
            # the protocol rule, independent of tick cadence: a lapsed lease
            # cannot be renewed by a late heartbeat — the next Tick reaps it
            # (the one drain path); the member must re-register
            raise SessionError(
                f"member {msg.member_id}'s lease lapsed at {expires:.6f} "
                f"(now {now:.6f}) — re-register to rejoin")
        try:
            fill, rate = float(msg.fill), float(msg.rate)
        except (TypeError, ValueError):
            # protocol rejection, not a crash: the message is already in
            # the WAL and must replay to the same rejection
            raise SessionError("fill/rate must be numbers") from None
        new_expires = now + self.lease_s
        s.lanes.scatter([mid], [fill], [rate], [bool(msg.healthy)],
                        new_expires)
        s.counters["heartbeats"] += 1
        if self._mx is not None and not self._replaying:
            self._mx.heartbeats.inc()
        return {"member_id": mid, "lease_expires": new_expires}

    def _send_state_batch(self, msg: M.SendStateBatch, now: float) -> dict:
        """One heartbeat window for many members: a single array scatter
        into the reservation's lanes. Per-member semantics are exactly M
        ``SendState`` messages at this instant, except rejections are
        per-member (in the reply) instead of per-message."""
        s = self._session(msg.token)
        self._charge_quota(s, now)
        try:
            # every id through the same _member_index validation SendState
            # uses: a float/bool/string/huge-int id is a per-member
            # rejection, never an unsafe cast onto the wrong lane — and
            # never an exception after the WAL append (OverflowError from a
            # huge int would replay as a crash on every recover())
            raw = list(msg.member_ids)
            lanes = [self._member_index(m) for m in raw]
            fills = np.asarray(msg.fills, np.float64)
            rates = np.asarray(msg.rates, np.float64)
            healthy = np.asarray(msg.healthy, bool)
        except (TypeError, ValueError, OverflowError):
            raise SessionError(
                "batch fields must be parallel numeric arrays") from None
        if not (fills.ndim == rates.ndim == healthy.ndim == 1
                and len(lanes) == len(fills) == len(rates) == len(healthy)):
            raise SessionError(
                "batch arrays must be 1-D and the same length")
        ids = np.asarray([-1 if ln is None else ln for ln in lanes],
                         np.int64)
        in_range = ids >= 0
        ok = in_range.copy()
        rows = np.flatnonzero(in_range)
        sub = ids[rows]
        ok[rows] = s.lanes.leased[sub] & (s.lanes.lease_expires[sub] > now)
        new_expires = now + self.lease_s
        acc = np.flatnonzero(ok)
        if len(acc):
            s.lanes.scatter(ids[acc], fills[acc], rates[acc], healthy[acc],
                            new_expires)
        n_acc = int(ok.sum())
        s.counters["heartbeats"] += n_acc
        if self._mx is not None and not self._replaying:
            # once per WINDOW, not per member — the batch path must keep
            # its per-heartbeat cost in the array scatter
            self._mx.heartbeats.inc(n_acc)
            self._mx.hb_batch.observe(len(fills))
        rejected = {}
        for i in np.flatnonzero(~ok).tolist():
            if not in_range[i] or not s.lanes.leased[ids[i]]:
                rejected[str(raw[i])] = "no lease — re-register to rejoin"
            else:
                rejected[str(raw[i])] = "lease lapsed — re-register to rejoin"
        return {"n_accepted": n_acc, "lease_expires": float(new_expires),
                "rejected": rejected}

    # -- the daemon step ------------------------------------------------------
    def _tick(self, msg: M.Tick, now: float) -> dict:
        """Expire leases (-> hit-less drain), start pending sessions, run
        each session's policy feedback, GC drained epochs."""
        out = {}
        gc_event = msg.gc_event if msg.gc_event >= 0 else msg.current_event
        for token in sorted(self.sessions):
            s = self.sessions[token]
            lapsed = np.flatnonzero(s.lanes.leased
                                    & (s.lanes.lease_expires <= now))
            expired = [int(m) for m in lapsed]
            if expired:
                s.lanes.revoke(lapsed)
                s.counters["leases_expired"] += len(expired)
                if self._mx is not None and not self._replaying:
                    self._mx.leases_reaped.inc(len(expired))
                if s.started:
                    s.cp.mark_failed(expired)  # the lease-expiry drain path
                else:
                    for m in expired:
                        s.pending.pop(m, None)
            eid = None
            note = ""
            if not s.started and s.pending:
                members = {m: spec for m, (spec, _) in sorted(s.pending.items())}
                weights = {m: w for m, (_, w) in sorted(s.pending.items())}
                try:
                    eid = s.cp.start(members, weights)
                except (ValueError, RuntimeError) as e:
                    # defense in depth: _register validates every field, but
                    # a failed start must degrade to a note — this Tick is
                    # already in the WAL, and an exception here would replay
                    # as the same crash on every recover()
                    note = f"session start failed: {e}"
                else:
                    s.started = True
                    s.pending = {}
            elif s.started and s.cp.members:
                # exactly ONE fused policy update over [M] lanes: gather the
                # members' telemetry lanes (defaults match the dict path's
                # MemberTelemetry() for silent members) and hand the whole
                # window to feedback as arrays — no per-member dict churn
                ids = np.fromiter(s.cp.members.keys(), np.int64,
                                  len(s.cp.members))
                tele = TelemetryArray(
                    member_ids=ids, fill=s.lanes.fill[ids],
                    rate=s.lanes.rate[ids], healthy=s.lanes.healthy[ids])
                try:
                    eid = s.cp.feedback(tele, msg.current_event)
                except RuntimeError as e:
                    # every member drained — keep the last epoch live rather
                    # than tearing the session down (members may re-register)
                    note = str(e)
                    eid = None
                if eid is not None:
                    s.counters["epoch_switches"] += 1
                    if self._mx is not None and not self._replaying:
                        self._mx.epoch_switches.inc()
                s.cp.garbage_collect(gc_event)
            out[token] = {"epoch": eid, "expired": expired}
            if note:
                out[token]["note"] = note
        return {"sessions": out, "now": now}

    # -- read-only admin ------------------------------------------------------
    def _status(self, msg: M.Status, now: float) -> dict:
        tokens = [msg.token] if msg.token else sorted(self.sessions)
        sessions = {}
        for token in tokens:
            s = self._session(token)
            sessions[token] = {
                "instance": s.instance,
                "policy": s.policy_name,
                "started": s.started,
                "fabric": s.fabric,
                "current_epoch": s.manager.current_epoch,
                "members": {
                    str(m): {"lease_remaining": round(exp - now, 9),
                             "weight": s.cp.weights.get(m)}
                    for m, exp in sorted(s.lanes.lease_view().items())},
                "counters": dict(s.counters),
            }
        return {"sessions": sessions,
                "fabrics": {fid: dict(fab)
                            for fid, fab in sorted(self.fabrics.items())},
                "free_instances": list(self._free_instances),
                "journal_seq": self.journal.seq if self.journal else -1,
                # lets a remote admin audit replay/replication fidelity
                # over the wire (the HA failover smoke compares the
                # successor's digest to the dead leader's)
                "state_digest": self.state_digest()}

    # -- event-sourced recovery ----------------------------------------------
    def replay(self, entries: list[Entry]) -> int:
        """Feed a journal history through the handlers with each entry's
        recorded clock instant. Only valid on a virgin daemon."""
        if self.sessions or self._token_counter:
            raise ValueError("replay() requires a fresh daemon")
        self._replaying = True
        try:
            for e in entries:
                payload = dict(e.payload)
                recorded_now = payload.pop("now")
                msg = M.from_wire({"kind": e.kind, **payload})
                self.handle(msg, now=recorded_now)
        finally:
            self._replaying = False
        return len(entries)

    @classmethod
    def recover(cls, journal: Journal, **kwargs) -> "ControlDaemon":
        """Rebuild a daemon from a journal: replay its entries, then keep
        journaling seq-contiguously — and be recoverable again.

        The replayed ``journal`` becomes the live journal: it already holds
        the history and continues appending in place (to its file, for a
        ``Journal.load``-ed one), so recovering from an on-disk journal
        keeps persisting to it without duplicating entries. Pass
        ``live_journal`` to redirect post-recovery appends elsewhere: either
        an *empty* journal (the history is adopted into it — e.g. a fresh
        file after restoring from a snapshot directory) or a
        ``Journal.resume``-d one already positioned at the replayed seq
        (a compacted WAL whose prefix lives in the snapshot dir)."""
        live = kwargs.pop("live_journal", None)
        daemon = cls(journal=None, **kwargs)
        daemon.replay(journal.entries)
        if live is not None:
            if live.seq == -1:
                live.adopt(journal.entries)
            elif live.seq != journal.seq:
                raise ValueError(
                    f"live_journal at seq {live.seq} does not resume the "
                    f"replayed history at seq {journal.seq}")
            daemon.journal = live
        else:
            daemon.journal = journal
        # a file-backed journal's replayed entries are now redundant in RAM
        journal.release_replayed()
        return daemon

    # -- state digest ---------------------------------------------------------
    def state_digest(self) -> str:
        """SHA-256 over the daemon's complete programmable state — calendar
        bytes, LPM entries, member tables, epoch records, weights, leases,
        policy state, counters. Replay is correct iff digests match."""
        h = hashlib.sha256()

        def put(obj):
            h.update(json.dumps(obj, sort_keys=True, default=repr).encode())

        put({"token_counter": self._token_counter,
             "fabric_counter": self._fabric_counter,
             "fabrics": {fid: {"tokens": list(fab["tokens"]),
                               "k": fab["k"],
                               "reserved_fraction": fab["reserved_fraction"]}
                         for fid, fab in sorted(self.fabrics.items())},
             "free_instances": list(self._free_instances),
             "lease_s": self.lease_s})
        for token in sorted(self.sessions):
            s = self.sessions[token]
            leases = s.lanes.lease_view()
            put({"token": token, "instance": s.instance,
                 "policy": s.policy_name, "started": s.started,
                 "fabric": s.fabric,
                 "quota": [s.quota_tokens, s.quota_t],
                 "leases": {str(k): leases[k] for k in sorted(leases)},
                 "telemetry": {str(k): v for k, v in
                               sorted(s.lanes.telemetry_view().items())},
                 "pending": {str(k): (dataclasses.asdict(v[0]), v[1])
                             for k, v in sorted(s.pending.items())},
                 "counters": s.counters,
                 "weights": {str(k): v for k, v in sorted(s.cp.weights.items())},
                 "scheduled": {str(k): v for k, v in
                               sorted(s.cp._scheduled_weights.items())},
                 "policy_state": s.cp.reweighter.state()})
            em = s.manager
            put({"current_epoch": em.current_epoch,
                 "records": {str(eid): {
                     "start": r.start_event, "end": r.end_event,
                     "active": r.active,
                     "prefixes": sorted((p.value, p.length)
                                        for p in r.prefixes),
                     "members": {str(m): dataclasses.asdict(sp)
                                 for m, sp in sorted(r.members.items())}}
                     for eid, r in sorted(em.records.items())}})
            st = em.state
            put({"members": {str(m): dataclasses.asdict(sp)
                             for m, sp in sorted(st.members.items())},
                 "epoch_rows": {str(k): v
                                for k, v in sorted(st._epoch_rows.items())},
                 "free_rows": list(st._free_rows),
                 "lpm": sorted((p.value, p.length, repr(d))
                               for p, d in st.epoch_lpm.entries.items())})
            for eid in sorted(st.calendars):
                h.update(np.ascontiguousarray(
                    st.calendars[eid], dtype=np.int32).tobytes())
        return h.hexdigest()
