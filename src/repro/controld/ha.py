"""controld HA: warm-standby replication + lease-based leader failover.

One ``ControlDaemon`` is a single point of failure: its loss freezes
policy feedback, lease reaping and epoch switches for the whole farm.
This module removes it (DESIGN.md §Controld-HA):

* ``LeaseStore`` / ``FileLeaseStore`` — a tiny shared arbiter holding
  *the* leadership lease: ``(holder, expires, generation)``. Leadership
  is time-bounded — a leader that stops renewing (dead, partitioned)
  loses it one term after its last renewal, and any standby may then
  claim it. ``generation`` increments on every ownership change and
  fences stale leaders.
* ``HANode`` — one replica: a ``ControlDaemon`` plus a role. The
  *leader* serves clients, renews its lease, and ships every fresh WAL
  entry to its standbys before replying (``controld.replication``).
  A *standby* rejects client mutations with a ``NOT_LEADER`` reply
  (the failover transport's cue to try elsewhere), applies shipped
  entries through the journal-replay path so its ``state_digest``
  tracks the leader byte-for-byte, and — on any activity after the
  lease lapses — claims the lease and promotes: the takeover needs no
  external coordinator, a retrying client is enough to drive it.
* ``HACluster`` — the in-proc wiring (simnet, tests, benches): N nodes
  over one arbiter and in-proc transports, with ``kill_leader`` for
  chaos scenarios and ``client_endpoints()`` feeding a
  ``FailoverTransport``.

What counts as downtime: from the instant the leader dies until a
standby's promotion, *mutating* calls are retried by the client (capped
backoff) — the data plane keeps forwarding on the last programmed
epoch tables throughout, so bundles are not lost, decisions are merely
deferred. The scenario gate is that the deferral is bounded by roughly
one lease term and that the successor resumes digest-identical.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Callable, Optional

from repro.controld import messages as M
from repro.controld.daemon import ControlDaemon
from repro.controld.journal import Journal
from repro.controld.replication import (STALE_GENERATION, Replicator,
                                        apply_entries, entry_from_wire)
from repro.controld.transport import (NOT_LEADER, InProcTransport,
                                      TransportError)
from repro.telemetry.registry import MetricsRegistry


@dataclasses.dataclass(frozen=True)
class LeaseState:
    """The arbiter's record: who leads, until when, at which generation."""

    holder: str = ""
    expires: float = -float("inf")
    generation: int = 0


class LeaseStore:
    """In-proc lease arbiter (simnet / tests / single-process clusters).

    ``claim`` grants the lease when it is free, expired, or already held
    by the claimant (renewal); an ownership *change* bumps
    ``generation`` — the fencing token a new leader announces and a
    stale one is rejected by."""

    def __init__(self, term_s: float, clock: Callable[[], float] = time.time):
        self.term_s = float(term_s)
        self.clock = clock
        self._state = LeaseState()

    def read(self) -> LeaseState:
        return self._state

    def claim(self, node_id: str,
              now: Optional[float] = None) -> Optional[LeaseState]:
        now = float(self.clock()) if now is None else float(now)
        st = self.read()
        if st.holder == node_id:
            new = LeaseState(node_id, now + self.term_s, st.generation)
        elif not st.holder or st.expires <= now:
            new = LeaseState(node_id, now + self.term_s, st.generation + 1)
        else:
            return None
        self._write(new)
        return new

    def release(self, node_id: str) -> None:
        if self.read().holder == node_id:
            self._write(LeaseState(holder="", expires=-float("inf"),
                                   generation=self.read().generation))

    def _write(self, st: LeaseState) -> None:
        self._state = st


class FileLeaseStore(LeaseStore):
    """File-backed arbiter for multi-process deployments
    (``run_controld --lease-store``): the lease is one JSON file updated
    via tmp + atomic ``os.replace`` under a short ``O_EXCL`` lock file
    (stale locks from a killed claimant are broken after
    ``lock_timeout_s``)."""

    def __init__(self, path: str, term_s: float,
                 clock: Callable[[], float] = time.time,
                 lock_timeout_s: float = 2.0):
        super().__init__(term_s, clock)
        self.path = path
        self.lock_timeout_s = float(lock_timeout_s)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def read(self) -> LeaseState:
        try:
            with open(self.path, encoding="utf-8") as f:
                d = json.load(f)
            return LeaseState(holder=str(d["holder"]),
                              expires=float(d["expires"]),
                              generation=int(d["generation"]))
        except (OSError, json.JSONDecodeError, KeyError, ValueError):
            return LeaseState()

    def claim(self, node_id: str,
              now: Optional[float] = None) -> Optional[LeaseState]:
        with self._locked():
            return super().claim(node_id, now)

    def release(self, node_id: str) -> None:
        with self._locked():
            super().release(node_id)

    def _write(self, st: LeaseState) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump({"holder": st.holder, "expires": st.expires,
                       "generation": st.generation}, f)
        os.replace(tmp, self.path)

    def _locked(self):
        store = self

        class _Lock:
            def __enter__(self):
                lock = store.path + ".lock"
                deadline = time.monotonic() + store.lock_timeout_s
                while True:
                    try:
                        fd = os.open(lock, os.O_CREAT | os.O_EXCL
                                     | os.O_WRONLY)
                        os.close(fd)
                        return self
                    except FileExistsError:
                        if time.monotonic() >= deadline:
                            # claimant died holding the lock: break it
                            try:
                                os.unlink(lock)
                            except OSError:
                                pass
                            deadline = (time.monotonic()
                                        + store.lock_timeout_s)
                        time.sleep(0.005)

            def __exit__(self, *exc):
                try:
                    os.unlink(store.path + ".lock")
                except OSError:
                    pass

        return _Lock()


class _HaMetrics:
    """Role gauge, promotion counter, failover histogram, lag gauge."""

    FAILOVER_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0,
                        2.5, 5.0, 10.0, float("inf"))

    def __init__(self, registry: MetricsRegistry, node: "HANode"):
        registry.gauge(
            "controld_ha_role",
            "1 = leader, 0 = standby, by node.", labelnames=("node",)
        ).labels(node=node.node_id).set_function(
            lambda: 1.0 if node.role == "leader" else 0.0)
        registry.gauge(
            "controld_ha_replication_lag",
            "Journal entries the slowest live standby trails the leader "
            "by, by node (0 for standbys).", labelnames=("node",)
        ).labels(node=node.node_id).set_function(
            lambda: float(node.replicator.lag())
            if node.role == "leader" else 0.0)
        self.promotions = registry.counter(
            "controld_ha_promotions_total",
            "Standby-to-leader promotions, by node.",
            labelnames=("node",)).labels(node=node.node_id)
        self.failover_seconds = registry.histogram(
            "controld_ha_failover_seconds",
            "Leader-death-to-promotion duration as measured by the "
            "driving harness (sim / demo).", labelnames=("node",),
            buckets=self.FAILOVER_BUCKETS).labels(node=node.node_id)


class HANode:
    """One replica: a ``ControlDaemon`` + a lease-governed role.

    Transport-facing: ``handle(msg)`` is a drop-in for
    ``ControlDaemon.handle`` — hand an ``HANode`` to ``SocketServer`` or
    ``InProcTransport`` and it serves clients, replication and lease
    fencing on one endpoint."""

    def __init__(self, node_id: str, daemon: ControlDaemon,
                 store: LeaseStore,
                 clock: Optional[Callable[[], float]] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 faults=None):
        self.node_id = str(node_id)
        self.daemon = daemon
        self.store = store
        self.clock = daemon.clock if clock is None else clock
        self.faults = faults
        self.role = "standby"
        self.generation = 0
        self.alive = True
        self.replicator = Replicator(self.node_id, daemon.journal,
                                     faults=faults)
        #: standby transports a (future) leader replicates to, by name
        self.peers: dict[str, object] = {}
        self.promotions = 0
        self.promoted_at: Optional[float] = None
        self.promoted_digest: Optional[str] = None
        self._outbox: list = []
        # serializes handle()/step() when a socket deployment runs a
        # lease-renewal ticker thread next to the server's selector loop;
        # uncontended (in-proc, simnet) it is a few ns per call
        self._lock = threading.RLock()
        self._mx = None if metrics is None else _HaMetrics(metrics, self)

    # -- lifecycle -------------------------------------------------------------
    def add_peer(self, name: str, transport) -> None:
        """Declare a peer standby endpoint. A leader attaches it for
        replication immediately; a standby remembers it for when it
        promotes."""
        self.peers[name] = transport
        if self.role == "leader":
            self.replicator.attach(name, transport, self.generation)

    def kill(self) -> None:
        """Model a SIGKILL for in-proc chaos: the node stops answering
        (its transports raise ``TransportError``); state is NOT cleaned
        up, exactly like a dead process."""
        self.alive = False

    def step(self, now: Optional[float] = None) -> None:
        """One lease-protocol beat: a leader renews (and steps down if
        the arbiter says it lost the lease); a standby claims once the
        lease lapsed — promotion is lazy, driven by whoever calls this
        (each handled client message does, so a retrying client alone
        completes a failover)."""
        if not self.alive:
            return
        with self._lock:
            now = float(self.clock()) if now is None else float(now)
            if self.role == "leader":
                got = self.store.claim(self.node_id, now)
                if got is None or got.holder != self.node_id:
                    self._demote()
                else:
                    self.generation = got.generation
                return
            st = self.store.read()
            if st.holder == self.node_id or st.expires <= now:
                got = self.store.claim(self.node_id, now)
                if got is not None and got.holder == self.node_id:
                    self._promote(now, got)

    def reattach_dead_peers(self) -> None:
        """Leader-side repair beat (socket ticker / periodic caller):
        re-probe peers that were marked dead or never attached — a standby
        that came back is caught up from backlog and resumes synchronous
        replication."""
        with self._lock:
            if self.role != "leader":
                return
            for name, transport in self.peers.items():
                p = self.replicator.peers.get(name)
                if p is None or not p.alive:
                    self.replicator.attach(name, transport, self.generation)

    def _promote(self, now: float, lease: LeaseState) -> None:
        self.role = "leader"
        self.generation = lease.generation
        self.promotions += 1
        self.promoted_at = now
        # the digest the successor RESUMES at — captured before any new
        # client message applies, compared by the chaos gates against
        # the dead leader's last digest
        self.promoted_digest = self.daemon.state_digest()
        if self.daemon.journal is not None:
            self.daemon.journal.on_append = self._outbox.append
        if self._mx is not None:
            self._mx.promotions.inc()
        # fence + re-replicate: tell every reachable peer, attach the
        # live ones as this leader's standbys
        for name, transport in self.peers.items():
            try:
                transport.call(M.LeaseClaim(node=self.node_id,
                                            generation=self.generation,
                                            expires=lease.expires))
            except TransportError:
                continue
            self.replicator.attach(name, transport, self.generation)

    def _demote(self) -> None:
        self.role = "standby"
        if self.daemon.journal is not None:
            self.daemon.journal.on_append = None
        self._outbox.clear()
        self.replicator.peers.clear()

    def record_failover(self, duration_s: float) -> None:
        """Observed by the driving harness (sim window loop, --ha-demo):
        leader-death-to-promotion, onto the failover histogram."""
        if self._mx is not None:
            self._mx.failover_seconds.observe(float(duration_s))

    def _fault(self, point: str) -> None:
        if self.faults is not None:
            self.faults.crashpoint(point)

    # -- the transport-facing entry point -------------------------------------
    def handle(self, msg, now: Optional[float] = None) -> M.Reply:
        with self._lock:
            return self._handle(msg, now)

    def _handle(self, msg, now: Optional[float] = None) -> M.Reply:
        if msg.KIND == M.ReplicateEntries.KIND:
            return self._on_replicate(msg)
        if msg.KIND == M.LeaseClaim.KIND:
            return self._on_lease_claim(msg)
        now = float(self.clock()) if now is None else float(now)
        if msg.KIND not in M.MUTATING_KINDS:
            reply = self.daemon.handle(msg, now=now)
            if reply.ok and msg.KIND == M.Status.KIND:
                reply.data["ha"] = {"node": self.node_id, "role": self.role,
                                    "generation": self.generation}
            return reply
        self.step(now)
        if self.role != "leader":
            return M.Reply(False, error=(
                f"{NOT_LEADER}: node {self.node_id} is standby "
                f"(generation {self.generation}) — retry the leader"))
        reply = self.daemon.handle(msg, now=now)
        self._fault("ha.leader.before_ship")
        if self._outbox:
            # copy-and-clear IN PLACE: journal.on_append holds a bound
            # reference to this exact list
            batch = list(self._outbox)
            self._outbox.clear()
            fenced = self.replicator.ship(batch, self.generation)
            if fenced:
                # a peer holds a newer generation: we are an ex-leader
                # that somehow still answered — step down; the client's
                # request id makes its retry against the successor safe
                self._demote()
        self._fault("ha.leader.after_ship")
        return reply

    # -- HA protocol handlers --------------------------------------------------
    def _on_replicate(self, msg: M.ReplicateEntries) -> M.Reply:
        if msg.generation < self.generation:
            return M.Reply(False, error=(
                f"{STALE_GENERATION}: shipment generation "
                f"{msg.generation} < {self.generation}"))
        if msg.generation > self.generation and self.role == "leader":
            self._demote()  # fenced by a newer leader's shipment
        self.generation = max(self.generation, int(msg.generation))
        j = self.daemon.journal
        head = -1 if j is None else j.seq
        entries = [entry_from_wire(d) for d in msg.entries]
        if entries and entries[0].seq > head + 1:
            ack = M.ReplicaAck(node=self.node_id, ack_seq=head,
                               need_from=head + 1,
                               generation=self.generation)
            return M.Reply(True, data=M.to_wire(ack))
        fresh = [e for e in entries if e.seq > head]
        if fresh:
            self._fault("ha.standby.before_apply")
            apply_entries(self.daemon, fresh)
            self._fault("ha.standby.after_apply")
            head = self.daemon.journal.seq if j is not None else (
                fresh[-1].seq)
        ack = M.ReplicaAck(node=self.node_id, ack_seq=head, need_from=-1,
                           generation=self.generation)
        return M.Reply(True, data=M.to_wire(ack))

    def _on_lease_claim(self, msg: M.LeaseClaim) -> M.Reply:
        if msg.generation > self.generation:
            self.generation = int(msg.generation)
            if self.role == "leader":
                self._demote()
        return M.Reply(True, data={"node": self.node_id, "role": self.role,
                                   "generation": self.generation})


class NodeTransport(InProcTransport):
    """In-proc transport onto one ``HANode`` that models process death:
    calls against a killed node raise ``TransportError`` (a connection
    refused), which is what ``FailoverTransport`` fails over on."""

    def __init__(self, node: HANode):
        super().__init__(node)
        self.node = node

    def call(self, msg) -> M.Reply:
        if not self.node.alive:
            raise TransportError(f"node {self.node.node_id} is down")
        return super().call(msg)


class HACluster:
    """N in-proc ``HANode`` replicas over one arbiter — the wiring used
    by simnet's ``leader_failover``, the HA tests and ``bench_ha``.

    Node 0 claims the lease at construction (the initial leader); every
    node knows every other as a peer, so whichever standby promotes
    later re-attaches the survivors as its own standbys."""

    def __init__(self, n_nodes: int = 2,
                 clock: Callable[[], float] = time.time,
                 term_s: float = 1.0,
                 store: Optional[LeaseStore] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 faults=None,
                 daemon_kwargs: Optional[dict] = None):
        if n_nodes < 2:
            raise ValueError("an HA cluster needs >= 2 nodes")
        self.clock = clock
        self.term_s = float(term_s)
        self.store = (LeaseStore(term_s, clock) if store is None else store)
        kw = dict(daemon_kwargs or {})
        kw.setdefault("clock", clock)
        self._daemon_kwargs = kw
        self.nodes: list[HANode] = []
        for i in range(n_nodes):
            daemon = ControlDaemon(journal=Journal(), **kw)
            self.nodes.append(HANode(
                f"cd{i}", daemon, self.store, clock=clock,
                metrics=metrics, faults=faults))
        for node in self.nodes:
            for other in self.nodes:
                if other is not node:
                    node.peers[other.node_id] = NodeTransport(other)
        self.nodes[0].step()  # claim -> leader; attaches peers

    def leader(self) -> Optional[HANode]:
        for node in self.nodes:
            if node.alive and node.role == "leader":
                return node
        return None

    def standbys(self) -> list[HANode]:
        return [n for n in self.nodes
                if n.alive and n.role == "standby"]

    def kill_leader(self) -> HANode:
        leader = self.leader()
        if leader is None:
            raise RuntimeError("no live leader to kill")
        leader.kill()
        return leader

    def step(self, now: Optional[float] = None) -> None:
        for node in self.nodes:
            node.step(now)

    def revive(self, node: HANode) -> None:
        """Bring a killed node back as a *fresh* standby: new daemon,
        empty journal. Its first shipped batch won't attach (gap), the
        ack's ``need_from`` asks for seq 0, and the leader streams the
        whole backlog — full-history catch-up over the normal protocol.
        The node object (and the transports bound to it) is reused, so
        peers and failover endpoints keep working."""
        if node.alive:
            raise RuntimeError(f"node {node.node_id} is not dead")
        node.daemon = ControlDaemon(journal=Journal(), **self._daemon_kwargs)
        node.replicator = Replicator(node.node_id, node.daemon.journal,
                                     faults=node.faults)
        node.role = "standby"
        node.generation = self.store.read().generation
        node._outbox.clear()
        node.promoted_at = None
        node.promoted_digest = None
        node.alive = True
        lead = self.leader()
        if lead is not None and node.node_id in lead.peers:
            lead.replicator.attach(node.node_id, lead.peers[node.node_id],
                                   lead.generation)

    def client_endpoints(self) -> list[NodeTransport]:
        """One transport per node, in node order — feed these to a
        ``FailoverTransport``."""
        return [NodeTransport(n) for n in self.nodes]
