"""repro.controld — session-oriented control-plane service (DESIGN.md
§Controld).

The paper's control plane as a *service*, not a function call: compute nodes
reserve a virtual LB instance, register members, stream heartbeat telemetry,
and hold leases whose expiry triggers the same hit-less drain as an explicit
failure. Per-reservation pluggable reweighting policies (proportional / PID
fill controller), an event-sourced journal with snapshot + replay for
hit-less daemon restart, and two property-equal transports (in-process and
length-prefixed socket).
"""
from repro.controld.daemon import (ControlDaemon, MemberLanes, Session,
                                   SessionError)
from repro.controld.journal import Entry, Journal
from repro.controld.messages import (MESSAGE_TYPES, MUTATING_KINDS,
                                     Deregister, DeregisterBatch, Free,
                                     MessageError, Register, RegisterBatch,
                                     Reply, Reserve, ReserveFabric, SendState,
                                     SendStateBatch, Status, Tick)
from repro.controld.policy import (POLICIES, PIDFillPolicy, PolicyConfig,
                                   ProportionalPolicy, WeightPolicy,
                                   make_policy)
from repro.controld.transport import (ControldClient, ControldError,
                                      InProcTransport, SocketClient,
                                      SocketServer, TransportError)

__all__ = [
    "ControlDaemon", "MemberLanes", "Session", "SessionError",
    "Entry", "Journal",
    "MESSAGE_TYPES", "MUTATING_KINDS", "MessageError",
    "Reserve", "ReserveFabric", "Free", "Register", "RegisterBatch",
    "Deregister", "DeregisterBatch", "SendState",
    "SendStateBatch", "Tick", "Status", "Reply",
    "POLICIES", "PolicyConfig", "WeightPolicy", "ProportionalPolicy",
    "PIDFillPolicy", "make_policy",
    "ControldClient", "ControldError", "InProcTransport", "SocketClient",
    "SocketServer", "TransportError",
]
