"""repro.controld — session-oriented control-plane service (DESIGN.md
§Controld).

The paper's control plane as a *service*, not a function call: compute nodes
reserve a virtual LB instance, register members, stream heartbeat telemetry,
and hold leases whose expiry triggers the same hit-less drain as an explicit
failure. Per-reservation pluggable reweighting policies (proportional / PID
fill controller), an event-sourced journal with snapshot + replay for
hit-less daemon restart, two property-equal transports (in-process and
length-prefixed socket), and HA: warm-standby WAL replication with
lease-based leader failover (DESIGN.md §Controld-HA).
"""
from repro.controld.daemon import (ControlDaemon, MemberLanes, Session,
                                   SessionError)
from repro.controld.ha import (FileLeaseStore, HACluster, HANode, LeaseState,
                               LeaseStore, NodeTransport)
from repro.controld.journal import Entry, Journal
from repro.controld.messages import (HA_KINDS, MESSAGE_TYPES, MUTATING_KINDS,
                                     Deregister, DeregisterBatch, Free,
                                     LeaseClaim, MessageError, Register,
                                     RegisterBatch, ReplicaAck,
                                     ReplicateEntries, Reply, Reserve,
                                     ReserveFabric, SendState, SendStateBatch,
                                     Status, Tick)
from repro.controld.policy import (POLICIES, PIDFillPolicy, PolicyConfig,
                                   ProportionalPolicy, WeightPolicy,
                                   make_policy)
from repro.controld.replication import Replicator, apply_entries
from repro.controld.transport import (NOT_LEADER, ControldClient,
                                      ControldError, FailoverTransport,
                                      InProcTransport, RetryPolicy,
                                      SocketClient, SocketServer,
                                      TransportError)

__all__ = [
    "ControlDaemon", "MemberLanes", "Session", "SessionError",
    "Entry", "Journal",
    "MESSAGE_TYPES", "MUTATING_KINDS", "HA_KINDS", "MessageError",
    "Reserve", "ReserveFabric", "Free", "Register", "RegisterBatch",
    "Deregister", "DeregisterBatch", "SendState",
    "SendStateBatch", "Tick", "Status", "Reply",
    "ReplicateEntries", "ReplicaAck", "LeaseClaim",
    "POLICIES", "PolicyConfig", "WeightPolicy", "ProportionalPolicy",
    "PIDFillPolicy", "make_policy",
    "Replicator", "apply_entries",
    "LeaseStore", "FileLeaseStore", "LeaseState", "HANode", "HACluster",
    "NodeTransport",
    "ControldClient", "ControldError", "InProcTransport", "SocketClient",
    "SocketServer", "TransportError", "FailoverTransport", "RetryPolicy",
    "NOT_LEADER",
]
