"""Event-sourced state journal for the control daemon.

Every state-changing message the daemon accepts (Reserve / Register /
SendState / Tick / ...) is appended here *with the clock instant it was
handled at*, before it executes — a classic write-ahead log. The daemon is
deterministic given that sequence (token counters, epoch ids,
``build_calendar``, policy arithmetic are all pure functions of message
order), so replaying the journal through a fresh daemon reproduces
byte-identical calendar state: restart is a *scenario*, not an outage
(``ControlDaemon.recover``; exercised by simnet's ``cp_restart`` and
``scripts/run_controld.py --demo``).

Persistence follows ``checkpoint/ckpt.py``'s idioms: JSONL for the live
append path (one flushed line per entry — a torn final line is detected and
dropped on load, never replayed corrupt), and snapshots written to
``snap_<seq>/`` directories with a ``manifest.json`` and an atomic
tmp-then-rename so a killed snapshot never corrupts the restore source.
``restore`` = latest snapshot + any newer live-tail entries.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import IO, Iterable, Optional


@dataclasses.dataclass(frozen=True)
class Entry:
    seq: int
    kind: str
    payload: dict  # message fields + "now" (the clock instant handled at)

    def to_line(self) -> str:
        return json.dumps({"seq": self.seq, "kind": self.kind,
                           "payload": self.payload},
                          sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_line(cls, line: str) -> "Entry":
        d = json.loads(line)
        return cls(seq=int(d["seq"]), kind=str(d["kind"]),
                   payload=dict(d["payload"]))


class Journal:
    """Append-only entry log: in memory, on disk (JSONL), or both.

    An in-memory journal (``path=None``) retains every entry in ``entries``
    — it IS the replay source. A file-backed journal relies on the disk
    copy instead (``retain=False``): a long-running daemon's memory stays
    bounded no matter how many heartbeats it journals, and recovery reads
    the file back (``load``).

    **Auto-compaction** (``snapshot_dir`` + ``compact_every``): every N
    appends the journal rolls its WAL into a snapshot — the full history
    (previous snapshot + live tail) lands atomically under
    ``snapshot_dir/snap_<seq>/`` and the live file is truncated, so the WAL
    stays bounded by N entries no matter how long the daemon runs. Recovery
    for a compacted journal is ``Journal.restore(snapshot_dir,
    tail_path=path)`` (+ ``Journal.resume`` to keep appending); a bare
    ``load(path)`` only sees the tail."""

    def __init__(self, path: Optional[str] = None,
                 retain: Optional[bool] = None,
                 snapshot_dir: Optional[str] = None,
                 compact_every: int = 0):
        self.path = path
        self.retain = (path is None) if retain is None else retain
        self.snapshot_dir = snapshot_dir
        self.compact_every = int(compact_every)
        self.entries: list[Entry] = []
        self._seq = -1
        self._since_compact = 0
        self._compacted = False  # the live file no longer holds seq 0..
        self._fh: Optional[IO[str]] = None
        #: observer called with each freshly appended Entry — the HA
        #: leader's replication tap (``controld.ha``). Never fired by
        #: ``append_entry`` (a standby applying *shipped* entries) or
        #: ``adopt`` (recovery).
        self.on_append = None
        #: optional ``testing.faults.FaultInjector`` — threads named
        #: crash points through every write/rename step below
        self.faults = None
        if path is not None:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a", encoding="utf-8")

    @property
    def seq(self) -> int:
        """Sequence number of the last entry (-1 when empty)."""
        return self._seq

    def _fault(self, point: str) -> None:
        if self.faults is not None:
            self.faults.crashpoint(point)

    def _write_line(self, e: Entry) -> None:
        """One flushed JSONL line, with torn-write injection: a scheduled
        tear writes only a prefix of the line (a process killed inside
        ``write(2)``) and then crashes."""
        line = e.to_line() + "\n"
        if self.faults is not None:
            self._fault("journal.append.write")
            torn = self.faults.torn_bytes("journal.append.write",
                                          line.encode())
            if torn is not None:
                from repro.testing.faults import InjectedCrash
                self._fh.write(torn.decode("utf-8", "ignore"))
                self._fh.flush()
                raise InjectedCrash("injected torn write at "
                                    "journal.append.write")
        self._fh.write(line)
        self._fault("journal.append.flush")
        self._fh.flush()

    def append(self, kind: str, payload: dict) -> Entry:
        e = Entry(seq=self._seq + 1, kind=kind, payload=payload)
        self._seq = e.seq
        if self.retain:
            self.entries.append(e)
        if self._fh is not None:
            self._write_line(e)
            if self.compact_every and self.snapshot_dir is not None:
                self._since_compact += 1
                if self._since_compact >= self.compact_every:
                    self.compact()
        if self.on_append is not None:
            self.on_append(e)
        return e

    def append_entry(self, e: Entry) -> Entry:
        """Append an already-sequenced entry (a replicated WAL shipment):
        the standby's journal must mirror the leader's byte-for-byte, so
        the entry keeps its seq/payload exactly. Contiguity is enforced;
        ``on_append`` is NOT fired (shipped entries must not re-ship)."""
        if e.seq != self._seq + 1:
            raise ValueError(
                f"non-contiguous replicated seq {e.seq} (at {self._seq})")
        self._seq = e.seq
        if self.retain:
            self.entries.append(e)
        if self._fh is not None:
            self._write_line(e)
            if self.compact_every and self.snapshot_dir is not None:
                self._since_compact += 1
                if self._since_compact >= self.compact_every:
                    self.compact()
        return e

    def adopt(self, entries: Iterable[Entry]) -> None:
        """Install an already-replayed history as this journal's prefix (the
        recovered daemon keeps journaling *after* it, seq-contiguous). Only
        valid on an empty journal."""
        if self._seq != -1 or self.entries:
            raise ValueError("adopt() requires an empty journal")
        for e in entries:
            if e.seq != self._seq + 1:
                raise ValueError(f"non-contiguous journal seq {e.seq}")
            self._seq = e.seq
            if self.retain:
                self.entries.append(e)
            if self._fh is not None:
                self._fh.write(e.to_line() + "\n")
        if self._fh is not None:
            self._fh.flush()

    def release_replayed(self) -> None:
        """Drop the in-RAM entry list once it has been replayed, for
        journals whose durable copy lives on disk (``retain=False``)."""
        if not self.retain:
            self.entries = []

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def read_entries(self, from_seq: int = 0) -> list[Entry]:
        """Entries with ``seq >= from_seq`` — the HA leader's backlog
        source when a standby (re)attaches behind the log head. Retained
        journals slice memory; file-backed journals read the live file
        back, plus the latest snapshot when compaction moved the prefix
        out of it."""
        if self.retain:
            return [e for e in self.entries if e.seq >= from_seq]
        if self.path is None:
            return []
        if self._fh is not None:
            self._fh.flush()
        out: list[Entry] = []
        if self._compacted and self.snapshot_dir is not None:
            snap = self.latest_snapshot(self.snapshot_dir)
            if snap is not None:
                with open(os.path.join(snap, "entries.jsonl"),
                          encoding="utf-8") as f:
                    for line in f:
                        if line.strip():
                            e = Entry.from_line(line)
                            if e.seq >= from_seq:
                                out.append(e)
        floor = out[-1].seq if out else from_seq - 1
        if os.path.exists(self.path):
            with open(self.path, encoding="utf-8") as f:
                for line in f:
                    if not line.strip():
                        continue
                    try:
                        e = Entry.from_line(line)
                    except (json.JSONDecodeError, KeyError, ValueError):
                        break  # torn live tail: nothing after it is usable
                    if e.seq > floor:
                        out.append(e)
                        floor = e.seq
        return out

    # -- load / snapshot / restore -------------------------------------------
    @classmethod
    def load(cls, path: str, faults=None) -> "Journal":
        """Read a JSONL journal back (for recovery). A torn final line —
        a daemon killed mid-append — is dropped, not replayed corrupt.
        The loaded ``entries`` are there to be replayed once (recover()
        releases them afterwards; the file stays the durable copy)."""
        j = cls(path=None)
        j.faults = faults
        torn = False
        if os.path.exists(path):
            with open(path, encoding="utf-8") as f:
                lines = f.read().splitlines()
            for i, line in enumerate(lines):
                if not line.strip():
                    continue
                try:
                    j.entries.append(Entry.from_line(line))
                except (json.JSONDecodeError, KeyError, ValueError):
                    if i == len(lines) - 1:
                        torn = True
                        break  # torn tail from a mid-append kill
                    raise
        if torn:
            # rewrite without the partial line so future appends stay
            # valid — via tmp + atomic replace: a kill *during* the
            # rewrite must not take the good prefix down with the torn
            # tail (found by the crash-point sweep in tests/test_faults)
            tmp = path + ".rewrite.tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                for e in j.entries:
                    f.write(e.to_line() + "\n")
            if faults is not None:
                faults.crashpoint("journal.load.rewrite")
            os.replace(tmp, path)
        j._seq = j.entries[-1].seq if j.entries else -1
        j.path = path
        j.retain = False  # from here on the file is the source of truth
        j._fh = open(path, "a", encoding="utf-8")
        return j

    def snapshot(self, directory: str) -> str:
        """Atomic snapshot of the full entry history up to ``seq`` (ckpt.py
        idiom: write to ``.tmp``, manifest last, one ``os.rename``).

        Idempotent per seq: if ``snap_<seq+1>`` already exists it is
        complete (it can only appear via the final rename) and holds the
        identical append-only history, so it is returned as-is — the old
        rmtree-then-rename left a window where a kill destroyed the only
        good snapshot (found by the crash-point sweep)."""
        final = os.path.join(directory, f"snap_{self.seq + 1:08d}")
        if os.path.exists(final):
            return final
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        self._fault("journal.snapshot.start")
        if not self.retain and self.path is not None:
            # disk is the source of truth for a file-backed journal; after
            # a compaction the history is split between the latest snapshot
            # (the prefix) and the live file (the tail)
            if self._fh is not None:
                self._fh.flush()
            dst = os.path.join(tmp, "entries.jsonl")
            prev = (self.latest_snapshot(self.snapshot_dir)
                    if self._compacted and self.snapshot_dir else None)
            if prev is None:
                shutil.copyfile(self.path, dst)
            else:
                # concat prefix snapshot + live tail, dropping tail lines
                # whose seq the prefix already covers: a tail that still
                # holds pre-compaction entries (e.g. a kill between
                # snapshot and truncate, then Journal.resume) must not
                # snapshot the same seq twice (double-applied compaction,
                # found by the crash-point sweep)
                with open(os.path.join(prev, "manifest.json")) as f:
                    prev_seq = int(json.load(f)["seq"])
                with open(dst, "w", encoding="utf-8") as out:
                    with open(os.path.join(prev, "entries.jsonl"),
                              encoding="utf-8") as f:
                        shutil.copyfileobj(f, out)
                    with open(self.path, encoding="utf-8") as f:
                        for line in f:
                            if (line.strip() and
                                    Entry.from_line(line).seq > prev_seq):
                                out.write(line)
        else:
            with open(os.path.join(tmp, "entries.jsonl"), "w",
                      encoding="utf-8") as f:
                for e in self.entries:
                    f.write(e.to_line() + "\n")
        self._fault("journal.snapshot.entries")
        manifest = {"seq": self.seq, "n_entries": self.seq + 1,
                    "time": time.time()}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        self._fault("journal.snapshot.manifest")
        os.rename(tmp, final)
        self._fault("journal.snapshot.rename")
        return final

    def compact(self) -> str:
        """Roll the WAL: write a full-history snapshot under
        ``snapshot_dir``, then truncate the live file — the snapshot is now
        the durable prefix and the file only accumulates the newer tail.
        Recovery: ``restore(snapshot_dir, tail_path=path)``; resume
        appending with ``Journal.resume(path, seq, ...)``."""
        if self.path is None or self._fh is None:
            raise ValueError("compact() requires a file-backed journal")
        if self.snapshot_dir is None:
            raise ValueError("compact() requires snapshot_dir")
        final = self.snapshot(self.snapshot_dir)
        self._fault("journal.compact.snapshotted")
        self._fh.close()
        self._fh = open(self.path, "w", encoding="utf-8")  # truncate
        self._fault("journal.compact.truncated")
        self._compacted = True
        self._since_compact = 0
        return final

    @classmethod
    def resume(cls, path: str, base_seq: int,
               snapshot_dir: Optional[str] = None,
               compact_every: int = 0) -> "Journal":
        """Continue a compacted WAL at ``base_seq`` without rewriting the
        replayed history into it: the snapshot under ``snapshot_dir`` holds
        the prefix, ``path`` holds (and keeps accumulating) the tail. Hand
        this to ``ControlDaemon.recover(..., live_journal=...)``."""
        j = cls(path=path, retain=False, snapshot_dir=snapshot_dir,
                compact_every=compact_every)
        j._seq = int(base_seq)
        j._compacted = True
        return j

    @staticmethod
    def latest_snapshot(directory: str) -> Optional[str]:
        if not os.path.isdir(directory):
            return None
        snaps = [d for d in os.listdir(directory)
                 if d.startswith("snap_") and not d.endswith(".tmp")]
        if not snaps:
            return None
        return os.path.join(directory, max(snaps,
                                           key=lambda d: int(d.split("_")[1])))

    @classmethod
    def restore(cls, directory: str,
                tail_path: Optional[str] = None) -> "Journal":
        """Latest snapshot under ``directory`` plus any live-tail entries in
        ``tail_path`` with a newer seq. Returns an in-memory journal ready
        for ``ControlDaemon.recover``."""
        snap = cls.latest_snapshot(directory)
        if snap is None:
            raise FileNotFoundError(f"no snapshots under {directory}")
        with open(os.path.join(snap, "manifest.json")) as f:
            manifest = json.load(f)
        j = cls(path=None)
        with open(os.path.join(snap, "entries.jsonl"), encoding="utf-8") as f:
            for line in f.read().splitlines():
                if line.strip():
                    j.entries.append(Entry.from_line(line))
        j._seq = j.entries[-1].seq if j.entries else -1
        if j.seq != manifest["seq"]:
            raise ValueError(
                f"snapshot {snap} inconsistent: manifest seq "
                f"{manifest['seq']} vs entries {j.seq}")
        if tail_path is not None and os.path.exists(tail_path):
            tail = cls.load(tail_path)
            tail.close()
            for e in tail.entries:
                if e.seq > j.seq:
                    j.entries.append(e)
                    j._seq = e.seq
        return j
