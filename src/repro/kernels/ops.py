"""Jit'd public wrappers around the Pallas kernels, with shape/dtype checks
and payload combine helpers. This is the API the rest of the framework uses;
``use_pallas=False`` falls back to the jnp oracles (identical semantics),
which is also what the dry-run graphs use so cost_analysis stays meaningful.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as _dispatch
from repro.kernels import lb_route as _lb_route
from repro.kernels import ref as _ref


def route_packets(headers, tables, *, use_pallas: bool = True, interpret: bool = True):
    """Route headers u32[N,4] with DeviceTables -> (member, node, lane, valid)."""
    tt = _ref.tables_tuple(tables)
    if headers.ndim != 2 or headers.shape[-1] != 4:
        raise ValueError(f"headers must be [N, 4] u32 words, got {headers.shape}")
    if use_pallas:
        return _lb_route.lb_route(headers, tt, interpret=interpret)
    return _ref.lb_route_ref(headers, tt)


def plan_dispatch(member, n_members: int, *, use_pallas: bool = True,
                  interpret: bool = True):
    """Per-packet buffer positions + per-member totals."""
    if use_pallas:
        return _dispatch.dispatch_plan(member, n_members=n_members, interpret=interpret)
    return _ref.dispatch_plan_ref(member, n_members=n_members)


@functools.partial(jax.jit, static_argnames=("n_members", "capacity"))
def combine_payloads(payload, member, pos, *, n_members: int, capacity: int):
    """Scatter payloads by (member, pos) into [n_members, capacity, ...] buffers.

    Returns (buffers, occupancy, dropped_count). Drops (pos >= capacity) are
    counted, never silent.
    """
    keep = (member >= 0) & (pos >= 0) & (pos < capacity)
    # Masked packets are sent to an out-of-bounds index so mode="drop"
    # discards the write entirely (an in-bounds dummy index would clobber a
    # real packet's slot).
    m_idx = jnp.where(keep, member, n_members)
    p_idx = jnp.where(keep, pos, capacity)
    buf = jnp.zeros((n_members, capacity) + payload.shape[1:], payload.dtype)
    buf = buf.at[m_idx, p_idx].set(payload, mode="drop")
    occ = jnp.zeros((n_members, capacity), jnp.int32).at[m_idx, p_idx].set(
        jnp.ones_like(member, jnp.int32), mode="drop"
    )
    dropped = jnp.sum((member >= 0) & ~keep)
    return buf, occ, dropped
