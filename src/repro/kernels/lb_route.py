"""Pallas TPU kernel: the EJ-FAT data plane (parse -> validate -> epoch ->
calendar -> member rewrite) for a block of packets.

TPU adaptation of the paper's P4 pipeline (DESIGN.md §2): instead of one
packet per clock through match-action stages, we route a *block* of packet
headers per grid step on the VPU. All tables (epoch segments, calendars,
member rewrite) are small — a few KB — and live in VMEM for every block
(constant index_map), exactly mirroring the paper's point that EJ-FAT table
state is O(#compute-nodes), "a very small number of FPGA block RAM, with no
need for HBM". Header words stream through VMEM field-major (u32[4, N]) so
the packet dimension is lane-aligned (multiples of 128).

Tables arrive as a ``core.tables.DeviceTables`` pytree — either one instance
(1-D ``seg_row``) or stacked virtual instances (paper §I-C) with a leading
instance dim; the multi-instance kernel gathers each packet's own instance's
rows by ``instance_id`` in the same single pass. The only public caller is
``core/dataplane.DataPlane`` (backend="pallas").

Layout notes (TPU target):
  * BLOCK_N = 2048 packets/block => header block 4*2048*4B = 32KB VMEM,
    outputs 4*2048*4B = 32KB; tables < 64KB (x4 instances still < 256KB).
    Comfortably inside 16MB VMEM.
  * All per-packet math is elementwise/compare/sum on int32 vectors (VPU);
    the only gathers index 512-entry VMEM tables.
Validated in interpret mode on CPU against kernels/ref.py + core/router.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.protocol import MAGIC, SLOT_MASK, VERSION
from repro.core.tables import DeviceTables

BLOCK_N = 2048


def _parse(hdr_ref):
    """Parsing stage (paper §III-A): field extract + magic/version check."""
    w0 = hdr_ref[0, :]
    w1 = hdr_ref[1, :]
    e_hi = hdr_ref[2, :]
    e_lo = hdr_ref[3, :]
    magic = (w0 >> 16) & 0xFFFF
    version = (w0 >> 8) & 0xFF
    entropy = (w1 & 0xFFFF).astype(jnp.int32)
    ok = (magic == MAGIC) & (version == VERSION)
    return e_hi, e_lo, entropy, ok


def _route_kernel(
    hdr_ref,        # u32[4, B]   field-major header words
    seg_hi_ref,     # u32[S]
    seg_lo_ref,     # u32[S]
    seg_row_ref,    # i32[S]
    cal_ref,        # i32[R, 512]
    node_ref,       # i32[M]
    base_ref,       # i32[M]
    mask_ref,       # i32[M]
    mvalid_ref,     # i32[M]
    member_out,     # i32[B]
    node_out,       # i32[B]
    lane_out,       # i32[B]
    valid_out,      # i32[B]
):
    e_hi, e_lo, entropy, ok = _parse(hdr_ref)

    # --- Calendar Epoch Assignment: segment = (#starts <= event) - 1 ---
    s_hi = seg_hi_ref[:]
    s_lo = seg_lo_ref[:]
    ge = (e_hi[:, None] > s_hi[None, :]) | (
        (e_hi[:, None] == s_hi[None, :]) & (e_lo[:, None] >= s_lo[None, :])
    )
    idx = jnp.sum(ge.astype(jnp.int32), axis=1) - 1
    idx = jnp.clip(idx, 0, s_hi.shape[0] - 1)
    row = seg_row_ref[:][idx]

    # --- Calendar to Member Map: slot = 9 LSBs of the event number ---
    slot = (e_lo & SLOT_MASK).astype(jnp.int32)
    cal = cal_ref[:, :]
    member = cal[jnp.clip(row, 0, cal.shape[0] - 1), slot]

    # --- Member Lookup and Rewrite ---
    m = jnp.clip(member, 0, node_ref.shape[0] - 1)
    node = node_ref[:][m]
    lane = base_ref[:][m] + (entropy & mask_ref[:][m])
    ok = ok & (row >= 0) & (member >= 0) & (mvalid_ref[:][m] > 0)

    member_out[:] = jnp.where(ok, member, -1)
    node_out[:] = jnp.where(ok, node, -1)
    lane_out[:] = jnp.where(ok, lane, -1)
    valid_out[:] = ok.astype(jnp.int32)


def _route_kernel_mi(
    hdr_ref,        # u32[4, B]   field-major header words
    iid_ref,        # i32[B]      per-packet LB instance id
    seg_hi_ref,     # u32[I, S]
    seg_lo_ref,     # u32[I, S]
    seg_row_ref,    # i32[I, S]
    cal_ref,        # i32[I, R, 512]
    node_ref,       # i32[I, M]
    base_ref,       # i32[I, M]
    mask_ref,       # i32[I, M]
    mvalid_ref,     # i32[I, M]
    member_out,     # i32[B]
    node_out,       # i32[B]
    lane_out,       # i32[B]
    valid_out,      # i32[B]
):
    """Multi-instance variant: identical pipeline, every table read gathers
    the packet's own instance's row (one fused pass over all instances)."""
    e_hi, e_lo, entropy, ok = _parse(hdr_ref)
    iid = jnp.clip(iid_ref[:], 0, seg_row_ref.shape[0] - 1)

    s_hi = seg_hi_ref[...][iid]  # [B, S]
    s_lo = seg_lo_ref[...][iid]
    ge = (e_hi[:, None] > s_hi) | ((e_hi[:, None] == s_hi) & (e_lo[:, None] >= s_lo))
    idx = jnp.sum(ge.astype(jnp.int32), axis=1) - 1
    idx = jnp.clip(idx, 0, s_hi.shape[1] - 1)
    row = seg_row_ref[...][iid, idx]

    slot = (e_lo & SLOT_MASK).astype(jnp.int32)
    cal = cal_ref[...]
    member = cal[iid, jnp.clip(row, 0, cal.shape[1] - 1), slot]

    m = jnp.clip(member, 0, node_ref.shape[1] - 1)
    node = node_ref[...][iid, m]
    lane = base_ref[...][iid, m] + (entropy & mask_ref[...][iid, m])
    ok = ok & (row >= 0) & (member >= 0) & (mvalid_ref[...][iid, m] > 0)

    member_out[:] = jnp.where(ok, member, -1)
    node_out[:] = jnp.where(ok, node, -1)
    lane_out[:] = jnp.where(ok, lane, -1)
    valid_out[:] = ok.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lb_route(
    headers,
    tables: DeviceTables,
    instance_id=None,
    *,
    block_n: int = BLOCK_N,
    interpret: bool = True,
):
    """Route N packets. ``headers``: u32[N, 4] wire words (row-major).

    ``tables``: a DeviceTables pytree — single-instance (1-D ``seg_row``) or
    stacked (leading instance dim, see core/tables.stack_tables), in which
    case ``instance_id`` (i32[N], from the L3 filter) selects each packet's
    balancing context. Returns (member, node, lane, valid) int32[N]. N is
    padded internally to a multiple of ``block_n``.
    """
    multi = tables.seg_row.ndim == 2
    if multi and instance_id is None:
        raise ValueError("stacked tables require per-packet instance_id")
    if not multi and instance_id is not None:
        raise ValueError("instance_id given but tables are single-instance")

    n = headers.shape[0]
    n_pad = -(-n // block_n) * block_n
    hdr = jnp.zeros((n_pad, 4), jnp.uint32).at[:n].set(headers.astype(jnp.uint32))
    hdr = hdr.T  # field-major [4, N]

    grid = (n_pad // block_n,)
    vec_out = jax.ShapeDtypeStruct((n_pad,), jnp.int32)
    tbl_spec = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)
    tbl = (tables.seg_start_hi, tables.seg_start_lo, tables.seg_row,
           tables.calendars, tables.member_node, tables.member_base_lane,
           tables.member_lane_mask, tables.member_valid)

    in_specs = [pl.BlockSpec((4, block_n), lambda i: (0, i))]
    inputs = [hdr]
    kernel = _route_kernel
    if multi:
        iid = jnp.zeros((n_pad,), jnp.int32).at[:n].set(
            instance_id.astype(jnp.int32))
        in_specs.append(pl.BlockSpec((block_n,), lambda i: (i,)))
        inputs.append(iid)
        kernel = _route_kernel_mi
    in_specs.extend(tbl_spec(a) for a in tbl)
    inputs.extend(tbl)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_n,), lambda i: (i,))] * 4,
        out_shape=[vec_out] * 4,
        interpret=interpret,
    )(*inputs)
    member, node_o, lane, valid = (o[:n] for o in out)
    return member, node_o, lane, valid
