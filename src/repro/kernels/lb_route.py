"""Pallas TPU kernel: the EJ-FAT data plane (parse -> validate -> epoch ->
calendar -> member rewrite) for a block of packets.

TPU adaptation of the paper's P4 pipeline (DESIGN.md §2): instead of one
packet per clock through match-action stages, we route a *block* of packet
headers per grid step on the VPU. All tables (epoch segments, calendars,
member rewrite) are small — a few KB — and live in VMEM for every block
(constant index_map), exactly mirroring the paper's point that EJ-FAT table
state is O(#compute-nodes), "a very small number of FPGA block RAM, with no
need for HBM". Header words stream through VMEM field-major (u32[4, N]) so
the packet dimension is lane-aligned (multiples of 128).

Layout notes (TPU target):
  * BLOCK_N = 2048 packets/block => header block 4*2048*4B = 32KB VMEM,
    outputs 4*2048*4B = 32KB; tables < 64KB. Comfortably inside 16MB VMEM.
  * All per-packet math is elementwise/compare/sum on int32 vectors (VPU);
    the only gathers index 512-entry VMEM tables.
Validated in interpret mode on CPU against kernels/ref.py + core/router.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.protocol import MAGIC, SLOT_MASK, VERSION

BLOCK_N = 2048


def _route_kernel(
    hdr_ref,        # u32[4, B]   field-major header words
    seg_hi_ref,     # u32[S]
    seg_lo_ref,     # u32[S]
    seg_row_ref,    # i32[S]
    cal_ref,        # i32[R, 512]
    node_ref,       # i32[M]
    base_ref,       # i32[M]
    mask_ref,       # i32[M]
    mvalid_ref,     # i32[M]
    member_out,     # i32[B]
    node_out,       # i32[B]
    lane_out,       # i32[B]
    valid_out,      # i32[B]
):
    w0 = hdr_ref[0, :]
    w1 = hdr_ref[1, :]
    e_hi = hdr_ref[2, :]
    e_lo = hdr_ref[3, :]

    # --- Parsing stage (paper §III-A): magic/version check ---
    magic = (w0 >> 16) & 0xFFFF
    version = (w0 >> 8) & 0xFF
    entropy = (w1 & 0xFFFF).astype(jnp.int32)
    ok = (magic == MAGIC) & (version == VERSION)

    # --- Calendar Epoch Assignment: segment = (#starts <= event) - 1 ---
    s_hi = seg_hi_ref[:]
    s_lo = seg_lo_ref[:]
    ge = (e_hi[:, None] > s_hi[None, :]) | (
        (e_hi[:, None] == s_hi[None, :]) & (e_lo[:, None] >= s_lo[None, :])
    )
    idx = jnp.sum(ge.astype(jnp.int32), axis=1) - 1
    idx = jnp.clip(idx, 0, s_hi.shape[0] - 1)
    row = seg_row_ref[:][idx]

    # --- Calendar to Member Map: slot = 9 LSBs of the event number ---
    slot = (e_lo & SLOT_MASK).astype(jnp.int32)
    cal = cal_ref[:, :]
    member = cal[jnp.clip(row, 0, cal.shape[0] - 1), slot]

    # --- Member Lookup and Rewrite ---
    m = jnp.clip(member, 0, node_ref.shape[0] - 1)
    node = node_ref[:][m]
    lane = base_ref[:][m] + (entropy & mask_ref[:][m])
    ok = ok & (row >= 0) & (member >= 0) & (mvalid_ref[:][m] > 0)

    member_out[:] = jnp.where(ok, member, -1)
    node_out[:] = jnp.where(ok, node, -1)
    lane_out[:] = jnp.where(ok, lane, -1)
    valid_out[:] = ok.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lb_route(headers, tables_tuple, *, block_n: int = BLOCK_N, interpret: bool = True):
    """Route N packets. ``headers``: u32[N, 4] wire words (row-major).

    ``tables_tuple``: (seg_hi, seg_lo, seg_row, calendars, node, base, mask,
    valid) — see core/tables.DeviceTables. Returns (member, node, lane,
    valid) int32[N]. N is padded internally to a multiple of ``block_n``.
    """
    (seg_hi, seg_lo, seg_row, cal, node, base, mask, mvalid) = tables_tuple
    n = headers.shape[0]
    n_pad = -(-n // block_n) * block_n
    hdr = jnp.zeros((n_pad, 4), jnp.uint32).at[:n].set(headers.astype(jnp.uint32))
    hdr = hdr.T  # field-major [4, N]

    grid = (n_pad // block_n,)
    vec_out = jax.ShapeDtypeStruct((n_pad,), jnp.int32)
    tbl_spec = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)
    out = pl.pallas_call(
        _route_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, block_n), lambda i: (0, i)),
            tbl_spec(seg_hi), tbl_spec(seg_lo), tbl_spec(seg_row),
            tbl_spec(cal), tbl_spec(node), tbl_spec(base), tbl_spec(mask),
            tbl_spec(mvalid),
        ],
        out_specs=[pl.BlockSpec((block_n,), lambda i: (i,))] * 4,
        out_shape=[vec_out] * 4,
        interpret=interpret,
    )(hdr, seg_hi, seg_lo, seg_row, cal, node, base, mask, mvalid)
    member, node_o, lane, valid = (o[:n] for o in out)
    return member, node_o, lane, valid
