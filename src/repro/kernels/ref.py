"""Pure-jnp oracles for every Pallas kernel in this package.

The routing oracle is the core/router.py implementation itself (single source
of truth for the protocol semantics); the dispatch-plan oracle is the
sort-based pack from core/router.member_positions (itself property-tested
against the historical cumsum-of-one-hot semantics in tests/test_dataplane.py).
Tests sweep shapes and dtypes and assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import router as _router
from repro.core.protocol import decode_fields
from repro.core.tables import DeviceTables


def lb_route_ref(headers, tables: DeviceTables, instance_id=None):
    """Oracle for kernels/lb_route.lb_route (single or stacked tables).

    The multi-instance oracle is deliberately the naive N-way form — route
    through every instance's tables, then select by instance id — so it is
    an independent reference for the fused single-pass gather in
    core/router.route_instances (property-tested in tests/test_dataplane.py).
    """
    import dataclasses

    w = headers.astype(jnp.uint32)
    f = decode_fields(w)
    if instance_id is None:
        r = _router.route(tables, f["event_hi"], f["event_lo"], f["entropy"],
                          header_words=w)
        return r.member, r.node, r.lane, r.valid.astype(jnp.int32)

    n_inst = tables.seg_row.shape[0]
    iid = jnp.clip(instance_id.astype(jnp.int32), 0, n_inst - 1)
    per = []
    for i in range(n_inst):
        sub = DeviceTables(**{fld.name: getattr(tables, fld.name)[i]
                              for fld in dataclasses.fields(DeviceTables)})
        per.append(_router.route(sub, f["event_hi"], f["event_lo"],
                                 f["entropy"], header_words=w))
    sel = lambda field: jnp.select([iid == i for i in range(n_inst)],
                                   [getattr(r, field) for r in per])
    return (sel("member"), sel("node"), sel("lane"),
            sel("valid").astype(jnp.int32))


def dispatch_plan_ref(member, *, n_members: int):
    """Oracle for kernels/dispatch.dispatch_plan (capacity-free positions)."""
    pos, _keep, counts = _router.member_positions(member, n_members, capacity=2**30)
    pos = jnp.where(member >= 0, pos, -1)
    return pos.astype(jnp.int32), counts.astype(jnp.int32)


def seg_masks_ref(valid, ev_hi, ev_lo, daq, seg_index):
    """Oracle for kernels/reassembly.seg_masks (sorted-column row compare)."""
    valid = valid.astype(jnp.uint32)
    hi = ev_hi.astype(jnp.uint32)
    lo = ev_lo.astype(jnp.uint32)
    daq = daq.astype(jnp.uint32)
    seg = seg_index.astype(jnp.uint32)

    def prev(x):
        return jnp.concatenate([jnp.zeros((1,), x.dtype), x[:-1]])

    same = ((prev(valid) > 0) & (hi == prev(hi)) & (lo == prev(lo))
            & (daq == prev(daq)))
    ok = valid > 0
    new_group = (ok & ~same).astype(jnp.int32)
    dup = (ok & same & (seg == prev(seg))).astype(jnp.int32)
    return new_group, dup


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Oracle for kernels/flash_attention: plain softmax attention.

    q: [Lq, H, D], k/v: [Lk, H, D] (single example). fp32 accumulation.
    """
    import jax
    import numpy as np

    if scale is None:
        scale = 1.0 / np.sqrt(q.shape[-1])
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("qhd,khd->hqk", qf, kf) * scale
    if causal:
        lq, lk = q.shape[0], k.shape[0]
        mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq)
        logits = jnp.where(mask[None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,khd->qhd", w, vf).astype(q.dtype)
