"""Pure-jnp oracles for every Pallas kernel in this package.

The routing oracle is the core/router.py implementation itself (single source
of truth for the protocol semantics); the dispatch-plan oracle is the
cumsum-of-one-hot from core/router.member_positions. Tests sweep shapes and
dtypes and assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import router as _router
from repro.core.protocol import decode_fields
from repro.core.tables import DeviceTables


def tables_tuple(tables: DeviceTables):
    return (
        tables.seg_start_hi, tables.seg_start_lo, tables.seg_row,
        tables.calendars, tables.member_node, tables.member_base_lane,
        tables.member_lane_mask, tables.member_valid,
    )


def lb_route_ref(headers, tables_tuple_):
    """Oracle for kernels/lb_route.lb_route."""
    (seg_hi, seg_lo, seg_row, cal, node, base, mask, mvalid) = tables_tuple_
    t = DeviceTables(
        seg_start_hi=seg_hi, seg_start_lo=seg_lo, seg_row=seg_row,
        calendars=cal, member_node=node, member_base_lane=base,
        member_lane_mask=mask, member_valid=mvalid,
    )
    f = decode_fields(headers.astype(jnp.uint32))
    r = _router.route(t, f["event_hi"], f["event_lo"], f["entropy"],
                      header_words=headers.astype(jnp.uint32))
    return r.member, r.node, r.lane, r.valid.astype(jnp.int32)


def dispatch_plan_ref(member, *, n_members: int):
    """Oracle for kernels/dispatch.dispatch_plan (capacity-free positions)."""
    pos, _keep, counts = _router.member_positions(member, n_members, capacity=2**30)
    pos = jnp.where(member >= 0, pos, -1)
    return pos.astype(jnp.int32), counts.astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Oracle for kernels/flash_attention: plain softmax attention.

    q: [Lq, H, D], k/v: [Lk, H, D] (single example). fp32 accumulation.
    """
    import jax
    import numpy as np

    if scale is None:
        scale = 1.0 / np.sqrt(q.shape[-1])
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("qhd,khd->hqk", qf, kf) * scale
    if causal:
        lq, lk = q.shape[0], k.shape[0]
        mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq)
        logits = jnp.where(mask[None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,khd->qhd", w, vf).astype(q.dtype)
