"""Pallas TPU kernel: causal flash attention forward (beyond-paper compute
hot-spot; the LB data plane is the paper's kernel, this one serves the
prefill/serving path of the model substrate).

Tiling: grid = (batch*heads, T/BLOCK_Q). Each grid step holds one query tile
[BLOCK_Q, d] in VMEM and streams K/V tiles [BLOCK_K, d] with an online
softmax (m, l, acc) — the HBM<->VMEM traffic is O(T*d) per head instead of
O(T^2). MXU dims: BLOCK_Q x d x BLOCK_K matmuls with d, BLOCK_* multiples
of 128 on hardware (any size in interpret mode). Causality is enforced by
absolute position masks; the K loop is truncated at the query tile's end
(never reads future tiles at all).

Validated in interpret mode against kernels/ref.flash_attention_ref across
shape/dtype sweeps (tests/test_kernels_flash.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, scale,
                  seq_len, causal):
    j = pl.program_id(1)
    q = q_ref[0, :, :].astype(jnp.float32) * scale  # [Bq, d]
    q_pos = j * block_q + jax.lax.iota(jnp.int32, block_q)

    n_k = seq_len // block_k
    # causal: K tiles strictly after this query tile contribute nothing
    k_hi = jax.lax.min(n_k, (j + 1) * block_q // block_k + 1) if causal else n_k

    def body(kb, carry):
        m, l, acc = carry
        k_tile = k_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v_tile = v_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k_tile.T  # [Bq, Bk]
        if causal:
            k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v_tile
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, q_ref.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, k_hi, body, (m0, l0, acc0))
    out = jnp.where(l[:, None] > 0, acc / jnp.maximum(l, 1e-30)[:, None], 0.0)
    o_ref[0, :, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = BLOCK_Q,
                    block_k: int = BLOCK_K, interpret: bool = True):
    """q, k, v: [B, T, H, d] (MHA; GQA callers repeat kv heads). -> [B,T,H,d]."""
    b, t, h, d = q.shape
    scale = 1.0 / (d ** 0.5)
    bq = min(block_q, t)
    bk = min(block_k, t)
    pad = (-t) % max(bq, bk)
    tp = t + pad
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # [B, T, H, d] -> [B*H, T, d]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, tp, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, tp, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, tp, d)

    kernel = functools.partial(_flash_kernel, block_q=bq, block_k=bk,
                               scale=scale, seq_len=tp, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, tp // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tp, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, tp, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, tp, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, h, tp, d).transpose(0, 2, 1, 3)
    return out[:, :t]
