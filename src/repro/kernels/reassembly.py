"""Pallas TPU kernel: reassembly group/duplicate masks over sorted segments.

The batched reassembler (repro/data/reassembly.py) key-sorts a window of
segments by ``(event_hi, event_lo, daq_id, seg_index, arrival)``. On the
sorted columns, group boundaries and duplicate detection are a pure
previous-row comparison:

    new_group[i] = valid[i] and (ev, daq)[i] != (ev, daq)[i-1]
    dup[i]       = valid[i] and (ev, daq)[i] == (ev, daq)[i-1]
                            and seg_index[i] == seg_index[i-1]

Kernel structure mirrors kernels/dispatch.py: grid over 1-D row blocks (TPU
grid steps run sequentially) with a VMEM scratch row carrying the previous
block's last row across blocks. Row 0 compares against an invalid sentinel.
The pure-jnp oracle is ``kernels/ref.seg_masks_ref``; both are reached
through ``repro.data.reassembly.reassembly_plan`` (backend switch), nothing
else calls them directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_N = 1024


def _mask_kernel(valid_ref, hi_ref, lo_ref, daq_ref, seg_ref,
                 ng_out, dup_out, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)  # prev_valid = 0 sentinel

    valid = valid_ref[:]  # u32[B] (0/1)
    hi = hi_ref[:]
    lo = lo_ref[:]
    daq = daq_ref[:]
    seg = seg_ref[:]
    carry = carry_ref[0, :]  # u32[8]: [valid, hi, lo, daq, seg, 0, 0, 0]

    def prev(x, c):
        return jnp.concatenate([c[None], x[:-1]])

    p_valid = prev(valid, carry[0])
    same = ((p_valid > 0)
            & (hi == prev(hi, carry[1]))
            & (lo == prev(lo, carry[2]))
            & (daq == prev(daq, carry[3])))
    ok = valid > 0
    ng_out[:] = (ok & ~same).astype(jnp.int32)
    dup_out[:] = (ok & same & (seg == prev(seg, carry[4]))).astype(jnp.int32)
    carry_ref[0, 0] = valid[-1]
    carry_ref[0, 1] = hi[-1]
    carry_ref[0, 2] = lo[-1]
    carry_ref[0, 3] = daq[-1]
    carry_ref[0, 4] = seg[-1]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def seg_masks(valid, ev_hi, ev_lo, daq, seg_index, *,
              block_n: int = BLOCK_N, interpret: bool = True):
    """(new_group, dup) int32[N] masks over *sorted* segment columns."""
    n = valid.shape[0]
    n_pad = max(-(-n // block_n) * block_n, block_n)

    def pad(x):
        return jnp.zeros((n_pad,), jnp.uint32).at[:n].set(x.astype(jnp.uint32))

    grid = (n_pad // block_n,)
    spec = pl.BlockSpec((block_n,), lambda i: (i,))
    ng, dup = pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 8), jnp.uint32)],
        interpret=interpret,
    )(pad(valid), pad(ev_hi), pad(ev_lo), pad(daq), pad(seg_index))
    return ng[:n], dup[:n]
