# Pallas TPU kernels for the data-plane hot spots (routing, dispatch
# planning, reassembly group/dup masks, flash attention) plus their pure-jnp
# oracles in ref.py. The routing/dispatch/reassembly kernels are reached
# through core/dataplane.DataPlane (backend="pallas"); nothing else calls
# them directly.
