"""Pallas TPU kernel: dispatch planning — per-packet buffer positions.

The FPGA forwards each packet the moment it is routed; a TPU instead *packs*
routed packets into per-member contiguous buffers and ships them with one
``all_to_all`` (DESIGN.md §2). The packing plan (position of each packet
inside its member's buffer) is a cross-block running count: for packet i with
member m, pos_i = #packets j<i with member j == m.

Kernel structure: grid over packet blocks (TPU grid steps run sequentially),
with an f32[1, M] VMEM scratch carrying per-member running counts across
blocks. Within a block the exclusive cumsum of the one-hot membership matrix
is an (B x M) matrix op that maps onto the MXU (one-hot matmul dispatch, the
standard TPU MoE trick) — here expressed as jnp.cumsum on the one-hot which
Mosaic lowers to vector adds/rolls.

Capacity semantics: pos >= capacity => packet dropped (accounted, never
silently lost) — the bounded-buffer analogue of the paper's discard rule for
unprogrammed calendar slots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_N = 1024


def _plan_kernel(member_ref, pos_out, counts_out, carry_ref, *, n_members):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    member = member_ref[:]  # i32[B]
    onehot = (member[:, None] == jnp.arange(n_members, dtype=jnp.int32)[None, :])
    onehot = onehot.astype(jnp.float32)  # [B, M]
    excl = jnp.cumsum(onehot, axis=0) - onehot  # exclusive within-block count
    carry = carry_ref[0, :]  # f32[M]
    pos = jnp.sum((excl + carry[None, :]) * onehot, axis=1).astype(jnp.int32)
    pos = jnp.where(member >= 0, pos, -1)
    pos_out[:] = pos
    new_carry = carry + jnp.sum(onehot, axis=0)
    carry_ref[0, :] = new_carry
    counts_out[0, :] = new_carry.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_members", "block_n", "interpret"))
def dispatch_plan(member, *, n_members: int, block_n: int = BLOCK_N, interpret: bool = True):
    """Positions of each packet within its member's buffer.

    Returns (pos int32[N] — -1 for invalid members, counts int32[n_members]
    total per member). Combine with a capacity to build send buffers (ops.py).
    """
    n = member.shape[0]
    n_pad = -(-n // block_n) * block_n
    mem = jnp.full((n_pad,), -1, jnp.int32).at[:n].set(member.astype(jnp.int32))
    grid = (n_pad // block_n,)
    pos, counts = pl.pallas_call(
        functools.partial(_plan_kernel, n_members=n_members),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1, n_members), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((1, n_members), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, n_members), jnp.float32)],
        interpret=interpret,
    )(mem)
    return pos[:n], counts[0]
