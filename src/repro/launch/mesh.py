"""Production meshes. A function (not module-level constant) so importing
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dp_mesh(*, multi_pod: bool = False):
    """Perf-variant view of the SAME chips: pure data parallelism (tp=1).

    16x16 chips relabeled (256, 1) — a logical re-mapping, not different
    hardware. Used by the 'dponly' hillclimb variant (EXPERIMENTS.md §Perf):
    for <=20B archs, 256-way FSDP beats 16-way TP x 16-way DP because the
    per-layer weight all-gathers are far smaller than the TP activation
    all-reduces at these batch sizes.
    """
    shape = (2, 256, 1) if multi_pod else (256, 1)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_hybrid_mesh(tp: int, *, multi_pod: bool = False):
    """Perf-variant view of the same chips with a chosen TP degree.

    256 chips per pod relabeled (256/tp, tp) — trades TP activation
    all-reduces against FSDP weight gathers (EXPERIMENTS.md §Perf)."""
    dp = 256 // tp
    shape = (2, dp, tp) if multi_pod else (dp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many devices the host actually exposes."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
