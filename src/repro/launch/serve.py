"""Serving launcher: LB-front-door engine with batched synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--lane-bits", type=int, default=1)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, ServeConfig(n_replicas=args.replicas,
                                         lane_bits=args.lane_bits,
                                         max_len=256), params)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    reqs = [eng.submit(rng.integers(0, cfg.vocab, int(rng.integers(4, 16))),
                       max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    eng.run_until_done()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests / {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s on host)")
    print("per-replica routing:", dict(sorted(eng.stats["routed"].items())))


if __name__ == "__main__":
    main()
