import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs on the production meshes, and record
memory_analysis / cost_analysis / collective bytes to JSON artifacts.

MUST be run as its own process (the XLA_FLAGS line above executes before any
jax import — 512 placeholder host devices exist only here, never in tests or
benchmarks).

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import perfmodel
from repro.analysis.hlo import collective_stats
from repro.configs import ARCH_IDS, get_config
from repro.core.epoch import EpochManager
from repro.core.tables import MemberSpec
from repro.distributed import sharding as shd
from repro.distributed.context import use_rules
from repro.launch import shapes as SH
from repro.launch.mesh import make_dp_mesh, make_hybrid_mesh, make_production_mesh
from repro.launch.shardspecs import batch_shardings, decode_state_shardings
from repro.models import model as M
from repro.train import optimizer as OPT
from repro.train import train_step as TS

SDS = jax.ShapeDtypeStruct

# Per-arch training knobs (memory-critical archs get 8-bit Adam).
EIGHT_BIT = {"arctic-480b", "llama-3.2-vision-90b", "mixtral-8x22b"}
# Chunk sizes per shape (attention q/k blocking).
CHUNKS = {"train_4k": (1024, 1024), "prefill_32k": (2048, 2048),
          "decode_32k": (1, 2048), "long_500k": (1, 4096)}


def build_tables(n_members: int):
    em = EpochManager(max_members=max(64, n_members))
    members = {i: MemberSpec(node_id=i) for i in range(n_members)}
    em.initialize(members, {i: 1.0 for i in range(n_members)})
    return em.device_tables()


def model_flops(cfg, shape_name: str) -> float:
    s = SH.SHAPES[shape_name]
    n_total, n_active = cfg.param_count()
    if s.kind == "train":
        return 6.0 * n_active * s.global_batch * s.seq_len
    if s.kind == "prefill":
        return 2.0 * n_active * s.global_batch * s.seq_len
    return 2.0 * n_active * s.global_batch  # decode: one token


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline"):
    cfg = get_config(arch)
    reason = SH.skip_reason(cfg, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": reason}
    # Perf variants ('+'-joined tokens; EXPERIMENTS.md §Perf):
    #   dponly   -> same chips relabeled (256,1): pure 256-way FSDP/DP
    #   seqpar   -> Megatron-SP: residual stream seq-sharded on "model"
    #   moegroup -> shard-local grouped MoE dispatch (buffer never replicated)
    #   widetp   -> serving params sharded over ALL axes (no per-token gathers)
    #   rwkvchunk-> chunked WKV (matmul form) instead of per-token scan
    toks = set(variant.split("+")) if variant else {"baseline"}
    tp_tok = next((t for t in toks if t.startswith("tp") and t[2:].isdigit()), None)
    if "dponly" in toks:
        mesh = make_dp_mesh(multi_pod=multi_pod)
    elif tp_tok:
        mesh = make_hybrid_mesh(int(tp_tok[2:]), multi_pod=multi_pod)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    spec = SH.SHAPES[shape_name]
    qc, kc = CHUNKS[shape_name]
    rules = shd.logical_rules(mesh, seq_axis="model" if "seqpar" in toks else None)
    rwkv_chunk = 64 if (cfg.family == "ssm" and "rwkvchunk" in toks) else 1
    if "moegroup" in toks and cfg.family == "moe":
        dp_groups = int(np.prod([mesh.shape[a] for a in shd.data_axes(mesh)]))
        cfg = cfg.with_(moe_dispatch_groups=dp_groups)
    wide = "widetp" in toks

    with use_rules(rules):
        if spec.kind == "train":
            tcfg = TS.TrainConfig(
                adamw=OPT.AdamWConfig(eight_bit=arch in EIGHT_BIT),
                remat=True, lb_ingest=True, q_chunk=qc, k_chunk=kc,
                rwkv_chunk=64 if cfg.family == "ssm" else 1,
            )
            state_shapes = jax.eval_shape(
                lambda: TS.init_train_state(jax.random.PRNGKey(0), cfg, tcfg))
            batch = SH.batch_specs(cfg, shape_name)
            n_members = int(np.prod([mesh.shape[a] for a in shd.data_axes(mesh)]))
            tables = build_tables(n_members)
            shapes_for_jit = {
                "params": state_shapes["params"], "opt": state_shapes["opt"],
                "batch": batch, "tables": tables,
            }
            jitted = TS.jit_train_step(cfg, tcfg, mesh, shapes_for_jit,
                                       global_batch=spec.global_batch)
            lowered = jitted.lower(state_shapes, batch, tables)
        elif spec.kind == "prefill":
            params_shapes = jax.eval_shape(
                lambda: M.init_params(jax.random.PRNGKey(0), cfg))
            p_shard = shd.param_sharding(
                params_shapes, mesh, cfg, min_fsdp_size=2**24,
                wide_tp=wide, fsdp=not wide)
            batch = SH.batch_specs(cfg, shape_name)
            b_shard = batch_shardings(mesh, batch)
            if cfg.encoder_only:
                def fn(params, b):
                    logits, _ = M.forward(params, b, cfg, remat=False,
                                          q_chunk=qc, k_chunk=kc)
                    return logits
                jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
                lowered = jitted.lower(params_shapes, batch)
            else:
                state = SH.decode_state_specs(cfg, shape_name)
                if cfg.family == "vlm":
                    state.pop("vision")  # provided via batch at prefill
                s_shard = decode_state_shardings(cfg, mesh, state)

                def fn(params, b, st):
                    return M.prefill(params, b, st, cfg, q_chunk=qc, k_chunk=kc,
                                     rwkv_chunk=rwkv_chunk if cfg.family == "ssm" else 1)
                jitted = jax.jit(fn, in_shardings=(p_shard, b_shard, s_shard),
                                 donate_argnums=(2,))
                lowered = jitted.lower(params_shapes, batch, state)
        else:  # decode
            params_shapes = jax.eval_shape(
                lambda: M.init_params(jax.random.PRNGKey(0), cfg))
            p_shard = shd.param_sharding(
                params_shapes, mesh, cfg, min_fsdp_size=2**24,
                wide_tp=wide, fsdp=not wide)
            state = SH.decode_state_specs(cfg, shape_name)
            s_shard = decode_state_shardings(cfg, mesh, state)
            tok = SDS((spec.global_batch,), jnp.int32)
            d_size = int(np.prod([mesh.shape[a] for a in shd.data_axes(mesh)]))
            t_shard = (shd.batch_sharding(mesh, 1)
                       if spec.global_batch % d_size == 0
                       else shd.replicated(mesh))

            def fn(params, tokens, st):
                return M.decode_step(params, tokens, st, cfg, q_chunk=qc,
                                     k_chunk=kc)
            jitted = jax.jit(fn, in_shardings=(p_shard, t_shard, s_shard),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_shapes, tok, state)

        compiled = lowered.compile()

    # cost_analysis() returns a dict on newer jax, [dict] on older versions.
    raw_cost = compiled.cost_analysis() or {}
    if isinstance(raw_cost, (list, tuple)):
        raw_cost = raw_cost[0] if raw_cost else {}
    cost = dict(raw_cost)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)
        } if mem is not None else {}
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}
    text = compiled.as_text()
    colls = collective_stats(text)
    tp = mesh.shape.get("model", 1)
    dp = chips // tp
    est = perfmodel.estimate(cfg, shape_name, chips, dp, tp,
                             eight_bit_opt=arch in EIGHT_BIT)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": variant,
        "chips": chips, "dp": dp, "tp": tp,
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "memory": mem_info,
        "collectives": colls.to_json(),
        "analytic": est.to_json(),
        "model_flops": model_flops(cfg, shape_name),
        "hlo_bytes": len(text),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.all or args.arch is None else [args.arch]
    shapes = list(SH.SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch.replace('-', '_')}__{shape}__{mesh_kind}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                t0 = time.time()
                try:
                    art = lower_cell(arch, shape, mesh_kind == "multi",
                                     args.variant)
                    art["lower_compile_s"] = time.time() - t0
                    with open(path, "w") as f:
                        json.dump(art, f, indent=1)
                    status = art.get("skipped", "ok")
                    extra = ""
                    if "cost" in art:
                        extra = (f" flops/dev={art['cost'].get('flops', 0):.3e}"
                                 f" wire={art['collectives']['total_wire_bytes']:.3e}")
                    print(f"[{tag}] {status} ({art['lower_compile_s']:.1f}s){extra}",
                          flush=True)
                except Exception as e:
                    failures.append((tag, str(e)))
                    print(f"[{tag}] FAIL: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES"); raise SystemExit(1)
    print("\nall cells ok")


if __name__ == "__main__":
    main()
