"""Assigned input shapes x architectures: the 40-cell grid.

Every cell is (arch x shape) with ShapeDtypeStruct stand-ins (weak-type
correct, shardable, zero allocation). Skips are *documented
inapplicabilities* (DESIGN.md §4): long_500k needs sub-quadratic attention;
encoder-only archs have no decode step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# sub-quadratic decode support per family/config
def _supports_long(cfg: ModelConfig) -> bool:
    if cfg.family in ("hybrid", "ssm"):
        return True
    if cfg.swa_window is not None:  # SWA ring cache is O(window)
        return True
    return False


def skip_reason(cfg: ModelConfig, shape: str) -> Optional[str]:
    s = SHAPES[shape]
    if cfg.encoder_only and s.kind == "decode":
        return "encoder-only arch has no decode step"
    if shape == "long_500k" and not _supports_long(cfg):
        return "pure full-attention arch: quadratic attention inapplicable at 500k"
    return None


def runnable_cells(cfg: ModelConfig) -> list[str]:
    return [k for k in SHAPES if skip_reason(cfg, k) is None]


def batch_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for the model inputs of this cell."""
    s = SHAPES[shape]
    b, t = s.global_batch, s.seq_len
    if s.kind == "train":
        out = {
            "labels": SDS((b, t), jnp.int32),
            "headers": SDS((b, 4), jnp.uint32),
        }
        if cfg.family == "audio":
            out["embeds"] = SDS((b, t, cfg.d_model), jnp.dtype(cfg.dtype))
        else:
            out["tokens"] = SDS((b, t), jnp.int32)
        if cfg.family == "vlm":
            out["vision_embeds"] = SDS((b, cfg.n_vision_tokens, cfg.d_model),
                                       jnp.dtype(cfg.dtype))
        return out
    if s.kind == "prefill":
        out = {}
        if cfg.family == "audio":
            out["embeds"] = SDS((b, t, cfg.d_model), jnp.dtype(cfg.dtype))
        else:
            out["tokens"] = SDS((b, t), jnp.int32)
        if cfg.family == "vlm":
            out["vision_embeds"] = SDS((b, cfg.n_vision_tokens, cfg.d_model),
                                       jnp.dtype(cfg.dtype))
        return out
    # decode: one new token against a seq_len-deep cache
    return {"tokens": SDS((b,), jnp.int32)}


def decode_state_specs(cfg: ModelConfig, shape: str):
    """eval_shape of the decode cache for decode cells (includes 'vision'
    for the vlm family — present post-prefill)."""
    from repro.models import model as M

    s = SHAPES[shape]
    state = jax.eval_shape(
        lambda: M.init_decode_state(cfg, s.global_batch, s.seq_len))
    if cfg.family == "vlm":
        state["vision"] = SDS((s.global_batch, cfg.n_vision_tokens, cfg.d_model),
                              jnp.dtype(cfg.dtype))
    return state
