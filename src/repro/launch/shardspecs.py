"""Explicit sharding tables for decode/prefill states per family.

Rules (DESIGN.md §5): cache batch on data axes when divisible; when batch is
too small (long_500k, batch=1) shard the cache *sequence* dim on data
(sequence-parallel decode); heads / ssm-heads / feature dims on "model"
when divisible. Built by leaf-path dispatch so each family's cache layout is
handled explicitly rather than by shape guessing.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models.config import ModelConfig


def _div(n, by) -> bool:
    return by > 0 and n % by == 0


def decode_state_shardings(cfg: ModelConfig, mesh: Mesh, state_specs):
    """NamedSharding pytree for init_decode_state output (+'vision')."""
    d_ax = shd.data_axes(mesh)
    d_axes = d_ax if len(d_ax) > 1 else (d_ax[0] if d_ax else None)
    d_size = int(np.prod([mesh.shape[a] for a in d_ax])) if d_ax else 1
    m_ax = shd.model_axis(mesh)
    m_size = mesh.shape[m_ax] if m_ax else 1

    def batch_or_none(b):
        return d_axes if _div(b, d_size) else None

    def model_or_none(n):
        return m_ax if _div(n, m_size) else None

    def leaf(path, x):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
        nd = x.ndim
        shape = x.shape
        spec = [None] * nd
        if pstr.endswith("k") or pstr.endswith("v"):  # kv cache arrays
            # [..., B, S, H, hd]: batch at -4, seq -3, heads -2
            b_ax, s_ax, h_ax = nd - 4, nd - 3, nd - 2
            if batch_or_none(shape[b_ax]):
                spec[b_ax] = d_axes
            elif _div(shape[s_ax], d_size):
                spec[s_ax] = d_axes  # sequence-parallel cache (batch=1)
            spec[h_ax] = model_or_none(shape[h_ax])
        elif "kv/pos" in pstr or pstr.endswith("pos") and nd >= 2:
            # cache pos [..., B, S]
            b_ax, s_ax = nd - 2, nd - 1
            if batch_or_none(shape[b_ax]):
                spec[b_ax] = d_axes
            elif _div(shape[s_ax], d_size):
                spec[s_ax] = d_axes
        elif pstr.endswith("ssm/h") or pstr == "h":
            # [..., B, H, N, P]
            b_ax, h_ax = nd - 4, nd - 3
            spec[b_ax] = batch_or_none(shape[b_ax])
            spec[h_ax] = model_or_none(shape[h_ax])
        elif pstr.endswith("conv"):
            # [..., B, K-1, C]
            b_ax, c_ax = nd - 3, nd - 1
            spec[b_ax] = batch_or_none(shape[b_ax])
            spec[c_ax] = model_or_none(shape[c_ax])
        elif pstr.endswith("wkv"):
            # [L, B, H, P, P]
            spec[1] = batch_or_none(shape[1])
            spec[2] = model_or_none(shape[2])
        elif pstr.endswith("tshift") or pstr.endswith("cshift"):
            # [L, B, d]
            spec[1] = batch_or_none(shape[1])
            spec[2] = model_or_none(shape[2])
        elif pstr.endswith("vision"):
            # [B, Nv, d]
            spec[0] = batch_or_none(shape[0])
        elif pstr == "pos" and nd == 1:
            spec[0] = batch_or_none(shape[0])
        # length / scalars: replicated
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, state_specs)


def batch_shardings(mesh: Mesh, batch_specs):
    return jax.tree.map(lambda x: shd.batch_sharding(mesh, x.ndim), batch_specs)
