"""Training launcher.

Two modes:
  * --demo : run REAL steps on the host devices with a reduced config
    (CPU-runnable; exercises the full trainer: LB epochs, telemetry,
    checkpointing, straggler mitigation).
  * default: build the jitted, sharded production step for --arch on the
    production mesh and run it with synthetic device-resident data (on a
    real TPU slice this is the entry point; on CPU use --demo).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --demo --steps 20
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke_config
from repro.train import optimizer as OPT
from repro.train import train_step as TS
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--eight-bit", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--controld", action="store_true",
                    help="run the ingest control plane as a controld "
                         "session: DP workers register as leased members "
                         "and heartbeat in one batch per recalendar")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.demo else get_config(args.arch)
    tcfg = TS.TrainConfig(
        adamw=OPT.AdamWConfig(lr=1e-3, eight_bit=args.eight_bit,
                              decay_steps=max(args.steps, 10)),
        remat=not args.demo, lb_ingest=False,
        grad_compress=args.grad_compress,
        q_chunk=min(args.seq, 1024), k_chunk=min(args.seq, 1024),
    )
    tr = Trainer(cfg, tcfg, TrainerConfig(n_members=4, ckpt_dir=args.ckpt_dir,
                                          use_controld=args.controld))
    start = tr.init_or_restore(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={cfg.param_count()[0]/1e6:.1f}M "
          f"resume_step={start}")
    hist = tr.run(args.steps, batch=args.batch, seq=args.seq)
    losses = [h["loss"] for h in hist]
    print(f"steps={len(losses)} loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
