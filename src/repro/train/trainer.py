"""Training loop with the full fault-tolerance story:

  * checkpoint/restart (async sharded saves, atomic, resume-from-latest),
  * member failure handling: a failed DP worker is removed from the *next*
    calendar epoch (hit-less — in-flight events still route by the old
    epoch; the stateless data plane never stalls),
  * straggler mitigation: per-member step-time telemetry feeds the control
    plane's PI controller; slow members shed calendar slots,
  * elastic scaling: members can be added mid-run the same way (fig. 7c).

The loop is host-side orchestration; the math lives in the jitted step.
This trainer runs real steps on CPU for the examples/tests (tiny configs)
and is the same code the launcher uses under a production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core.control_plane import ControlPolicy, LoadBalancerControlPlane
from repro.core.epoch import EpochManager
from repro.core.protocol import encode_headers
from repro.core.tables import MemberSpec
from repro.models.config import ModelConfig
from repro.telemetry.metrics import TelemetryHub
from repro.train import train_step as TS


@dataclasses.dataclass
class TrainerConfig:
    n_members: int = 4
    lane_bits: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    recalendar_every: int = 10
    epoch_horizon: int = 64  # events; small so epochs drain & rows recycle
    seed: int = 0
    # Run the ingest control plane as a controld session (like serve/simnet):
    # DP workers become leased members of a daemon reservation, and the
    # recalendar cadence becomes one batched heartbeat window + a Tick.
    use_controld: bool = False
    lease_s: float = 30.0        # DP-worker lease (wall clock)


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        train_cfg: TS.TrainConfig,
        trainer_cfg: TrainerConfig,
        *,
        step_fn: Optional[Callable] = None,
        mesh=None,
    ):
        self.model_cfg = model_cfg
        self.train_cfg = train_cfg
        self.cfg = trainer_cfg
        self.mesh = mesh
        self.step_fn = step_fn or jax.jit(
            TS.make_train_step(model_cfg, train_cfg, mesh))
        self.hub = TelemetryHub()
        if trainer_cfg.use_controld:
            # the control plane as a service: DP workers are leased members
            # of a daemon reservation; default (proportional) policy built
            # from the same gains as the embedded path
            from repro.controld import (ControlDaemon, ControldClient,
                                        InProcTransport)
            self.daemon = ControlDaemon(
                n_instances=1, lease_s=trainer_cfg.lease_s,
                epoch_horizon=trainer_cfg.epoch_horizon,
                max_members=max(64, trainer_cfg.n_members), journal=None)
            self.client = ControldClient(InProcTransport(self.daemon))
            self.token = self.client.reserve()["token"]
            for i in range(trainer_cfg.n_members):
                self.client.register(self.token, member_id=i, node_id=i,
                                     lane_bits=trainer_cfg.lane_bits)
            self.client.tick(current_event=0)  # starts the session
            session = self.daemon.sessions[self.token]
            self.manager = session.manager
            self.cp = session.cp
        else:
            self.daemon = None
            self.manager = EpochManager(
                max_members=max(64, trainer_cfg.n_members))
            self.cp = LoadBalancerControlPlane(
                self.manager,
                ControlPolicy(epoch_horizon=trainer_cfg.epoch_horizon))
            members = {
                i: MemberSpec(node_id=i, base_lane=0,
                              lane_bits=trainer_cfg.lane_bits)
                for i in range(trainer_cfg.n_members)
            }
            self.cp.start(members)
        self.saver = ckpt.AsyncSaver()
        self.state = None
        self.next_event = 0
        self.history: list[dict] = []

    # -- lifecycle -------------------------------------------------------------
    def init_or_restore(self, rng):
        self.state = TS.init_train_state(rng, self.model_cfg, self.train_cfg)
        latest = ckpt.latest_step(self.cfg.ckpt_dir)
        if latest is not None:
            sub = {"params": self.state["params"], "opt": self.state["opt"],
                   "step": self.state["step"]}
            restored, step = ckpt.restore(self.cfg.ckpt_dir, sub)
            self.state.update(restored)
            return step
        return 0

    # -- control-plane integration ---------------------------------------------
    def handle_failure(self, member_ids) -> None:
        """Remove failed workers from the next epoch (hit-less)."""
        for m in member_ids:
            self.hub.report_failure(m)
        if self.daemon is not None:
            from repro.controld import ControldError
            for m in member_ids:
                try:
                    self.client.deregister(self.token, m)
                except ControldError:
                    # already drained — keep the embedded path's
                    # idempotence (mark_failed pops with a default)
                    pass
            self.client.tick(current_event=self.next_event,
                             gc_event=self.next_event)
            return
        self.cp.mark_failed(member_ids)
        self.cp.garbage_collect(self.next_event)
        self.cp.schedule_epoch(self.next_event)

    def add_members(self, member_ids) -> None:
        if self.daemon is not None:
            for m in member_ids:
                self.client.register(self.token, member_id=m, node_id=m,
                                     lane_bits=self.cfg.lane_bits)
            self.client.tick(current_event=self.next_event,
                             gc_event=self.next_event)
            return
        specs = {m: MemberSpec(node_id=m, lane_bits=self.cfg.lane_bits)
                 for m in member_ids}
        self.cp.add_members(specs)
        self.cp.garbage_collect(self.next_event)
        self.cp.schedule_epoch(self.next_event)

    def maybe_recalendar(self, step: int) -> None:
        if step and step % self.cfg.recalendar_every == 0:
            if self.daemon is not None:
                # one batched heartbeat window + a Tick: the daemon runs the
                # fused policy update, lease expiry and epoch GC in-service
                # (lapsed leases between slow steps re-register + resend)
                snap = {m: t for m, t in self.hub.snapshot().items()
                        if m in self.cp.members}
                self.client.heartbeat_window(self.token, snap,
                                             lane_bits=self.cfg.lane_bits)
                self.client.tick(current_event=self.next_event,
                                 gc_event=self.next_event)
                return
            self.cp.update_weights(self.hub.snapshot())
            self.cp.garbage_collect(self.next_event)
            self.cp.schedule_epoch(self.next_event)

    # -- data ------------------------------------------------------------------
    def synthetic_batch(self, batch: int, seq: int, rng: np.random.Generator):
        tokens = rng.integers(0, self.model_cfg.vocab, (batch, seq)).astype(np.int32)
        evs = self.next_event + np.arange(batch, dtype=np.uint64)
        self.next_event += batch
        entropy = rng.integers(0, 1 << 16, batch).astype(np.uint32)
        headers = encode_headers(evs, entropy)
        return {"tokens": tokens, "labels": tokens.copy(), "headers": headers}

    # -- loop --------------------------------------------------------------------
    def run(self, n_steps: int, batch: int, seq: int,
            failure_at: Optional[dict] = None):
        """failure_at: {step: [member_ids]} simulated failures."""
        rng = np.random.default_rng(self.cfg.seed)
        start = int(self.state["step"])
        for s in range(start, start + n_steps):
            if failure_at and s in failure_at:
                self.handle_failure(failure_at[s])
            b = self.synthetic_batch(batch, seq, rng)
            tables = self.manager.device_tables() if self.train_cfg.lb_ingest else None
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, b, tables)
            dt = time.perf_counter() - t0
            for m in self.cp.members:
                self.hub.report_step(m, dt * (1 + 0.01 * m))
            self.maybe_recalendar(s + 1)
            if (s + 1) % self.cfg.ckpt_every == 0:
                self.saver.save(self.cfg.ckpt_dir, s + 1,
                                {"params": self.state["params"],
                                 "opt": self.state["opt"],
                                 "step": self.state["step"]})
            self.history.append({k: float(v) for k, v in metrics.items()
                                 if np.ndim(v) == 0})
        self.saver.wait()
        return self.history
