"""AdamW with optional int8 block-quantized moments (8-bit Adam) and
ZeRO-1-style state sharding.

8-bit moments store m/v as int8 + per-256-block fp32 scales (paper-adjacent
distributed-optimization trick; also what makes arctic-480b training states
fit v5e HBM — see EXPERIMENTS.md §Dry-run). State sharding: moment pytrees
inherit the param sharding; ZeRO-1 additionally shards the largest dim over
the data axes via distributed.sharding.param_sharding(fsdp=True) applied to
the *states* even when params are TP-only.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.distributed.compression import quantize_int8

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    eight_bit: bool = False
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _q_state(x):
    """Shape-preserving per-row int8 quantization.

    The q tensor keeps the param's shape, so it inherits the param's
    sharding; a flat-blocked layout (compression.quantize_int8) would force
    GSPMD to all-gather the full f32 moments at the re-shape (measured: 10x
    625GB gathers/step on arctic — see EXPERIMENTS.md §Perf iteration 5).
    """
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale}


def _deq_state(st, shape, n):
    return (st["q"].astype(F32) * st["s"])


def init(params, cfg: AdamWConfig):
    def one(p):
        z = jnp.zeros(p.shape, F32)
        if cfg.eight_bit:
            return {"m": _q_state(z), "v": _q_state(z)}
        return {"m": z, "v": z}

    return {"mu": jax.tree.map(one, params), "count": jnp.zeros((), jnp.int32)}


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule(cfg, count)

    # global-norm clip
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(F32) ** 2) for g in leaves))
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1c = 1 - cfg.b1 ** count.astype(F32)
    b2c = 1 - cfg.b2 ** count.astype(F32)

    def one(g, mu, p):
        gf = g.astype(F32) * clip
        if cfg.eight_bit:
            m = _deq_state(mu["m"], p.shape, p.size)
            v = _deq_state(mu["v"], p.shape, p.size)
        else:
            m, v = mu["m"], mu["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(F32)
        new_p = (p.astype(F32) - lr * upd).astype(p.dtype)
        new_mu = ({"m": _q_state(m), "v": _q_state(v)} if cfg.eight_bit
                  else {"m": m, "v": v})
        return new_p, new_mu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    out = [one(g, mu, p) for g, mu, p in zip(flat_g, flat_mu, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    return new_params, {"mu": new_mu, "count": count}, {"grad_norm": gnorm, "lr": lr}
