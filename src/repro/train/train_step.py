"""The jitted training step, with the EJ-FAT ingest stage as a first-class
graph component.

Pipeline inside one step (config.lb_ingest):
  1. Arrival-ordered event shards (tokens/labels/headers) land on each DP
     member — this is what the network delivered, NOT who owns the events.
  2. The LB data plane routes each event header through the epoch calendar
     (pure function of tables — stateless, paper §I-B.3).
  3. ``all_to_all`` redistribution (core/router.make_redistribute) moves each
     event to its owning member: the paper's "in-network sorting" realized on
     the ICI fabric. Capacity overflow is dropped+accounted (masked labels).
  4. Standard fwd/bwd (+microbatch accumulation), AdamW update.

The dry-run lowers exactly this function, so the ingest collectives are part
of every compiled multi-pod graph.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.dataplane import DataPlane
from repro.core.tables import DeviceTables
from repro.distributed import sharding as shd
from repro.distributed.compression import compress_decompress
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train import optimizer as opt

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)
    remat: bool = True
    accum_steps: int = 1
    lb_ingest: bool = True
    lb_capacity_factor: float = 1.0   # per (src, member) slack
    grad_compress: bool = False
    q_chunk: int = 1024
    k_chunk: int = 1024
    rwkv_chunk: int = 1


def init_train_state(rng, model_cfg: ModelConfig, train_cfg: TrainConfig):
    params = M.init_params(rng, model_cfg)
    return {
        "params": params,
        "opt": opt.init(params, train_cfg.adamw),
        "efb": None,  # error-feedback residual (grad compression), lazy
        "step": jnp.zeros((), jnp.int32),
    }


def _ingest(batch, tables: DeviceTables, mesh: Mesh, global_batch: int):
    """LB route + on-mesh redistribution: a distributed counting sort.

    Each arrival-ordered event is routed through the calendar (stateless) to
    its owning member m; its destination row is ``m * cap + position`` where
    position is the exclusive running count of member-m events (the same
    sort-based plan the data plane's dispatch uses). The global scatter
    across the batch dim is what GSPMD turns into the inter-chip exchange —
    the paper's "in-network sorting" on the ICI fabric. Capacity cap = B/W
    (cf 1.0): output batch identical to input, overflow events dropped +
    accounted (the paper's discard rule; a few % at these shapes).

    Routing goes through the DataPlane facade built over the traced tables
    (jnp backend: this runs inside the jitted step under GSPMD).
    """
    d_ax = shd.data_axes(mesh)
    n_members = int(np.prod([mesh.shape[a] for a in d_ax]))
    dp = DataPlane(tables, backend="jnp")
    r = dp.route(batch["headers"].astype(jnp.uint32))
    b = batch["labels"].shape[0]
    cap = max(b // n_members, 1)
    pos, keep, _counts = dp.member_positions(r.node, n_members, cap)
    dest = jnp.where(keep, r.node * cap + pos, n_members * cap)  # OOB => drop

    from repro.distributed.context import constrain

    def scatter_field(x, fill):
        buf = jnp.full((n_members * cap,) + x.shape[1:], fill, x.dtype)
        buf = buf.at[dest].set(x, mode="drop")
        return constrain(buf, ("batch",) + (None,) * (x.ndim - 1))

    out = {
        k: scatter_field(v, -1 if k == "labels" else 0)
        for k, v in batch.items() if k != "headers"
    }
    occ = jnp.zeros((n_members * cap,), jnp.int32).at[dest].set(
        jnp.ones_like(dest, jnp.int32), mode="drop")
    return out, occ


def make_train_step(
    model_cfg: ModelConfig,
    train_cfg: TrainConfig,
    mesh: Optional[Mesh] = None,
    global_batch: Optional[int] = None,
):
    """Returns step(state, batch, tables) -> (state, metrics). ``tables`` may
    be None when lb_ingest is off."""

    def loss_fn(params, mb):
        return M.train_loss(
            params, mb, model_cfg, remat=train_cfg.remat,
            q_chunk=train_cfg.q_chunk, k_chunk=train_cfg.k_chunk,
            rwkv_chunk=train_cfg.rwkv_chunk,
        )

    def grads_of(params, mb):
        if train_cfg.accum_steps <= 1:
            (loss, met), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            return loss, met, grads
        a = train_cfg.accum_steps

        def slice_mb(mb, i):
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // a), x.shape[0] // a, 0)
                if x.ndim >= 1 else x,
                mb,
            )

        def body(carry, i):
            acc, lsum = carry
            (loss, _met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, slice_mb(mb, i))
            acc = jax.tree.map(lambda A, G: A + G.astype(F32), acc, g)
            return (acc, lsum + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (zeros, 0.0), jnp.arange(a))
        grads = jax.tree.map(lambda g: g / a, gsum)
        return lsum / a, {}, grads

    def step(state, batch, tables):
        metrics = {}
        if train_cfg.lb_ingest:
            assert mesh is not None and tables is not None
            mb, occ = _ingest(batch, tables, mesh, global_batch
                              or batch["labels"].shape[0])
            metrics["ingest_occupancy"] = occ.astype(F32).mean()
        else:
            mb = {k: v for k, v in batch.items() if k != "headers"}

        loss, lmet, grads = grads_of(state["params"], mb)
        metrics.update(lmet)

        if train_cfg.grad_compress:
            # int8 round-trip + error feedback (collective-payload analogue;
            # see distributed/compression.py for the explicit psum variant).
            efb = state["efb"]
            if efb is None:
                efb = jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads)
            grads_fb = jax.tree.map(lambda g, e: g.astype(F32) + e, grads, efb)
            deq = jax.tree.map(compress_decompress, grads_fb)
            new_efb = jax.tree.map(lambda g, d: g - d, grads_fb, deq)
            grads = deq
            state = dict(state, efb=new_efb)

        new_params, new_opt, omet = opt.update(
            grads, state["opt"], state["params"], train_cfg.adamw)
        metrics.update(omet)
        metrics["loss"] = loss
        new_state = dict(state, params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        return new_state, metrics

    return step


def jit_train_step(
    model_cfg: ModelConfig,
    train_cfg: TrainConfig,
    mesh: Mesh,
    state_shapes,
    *,
    global_batch: int,
    donate: bool = True,
):
    """jit with in/out shardings derived from the sharding rules."""
    step = make_train_step(model_cfg, train_cfg, mesh, global_batch)
    p_shard = shd.param_sharding(state_shapes["params"], mesh, model_cfg)
    o_shard = shd.param_sharding(state_shapes["opt"], mesh, model_cfg)
    repl = shd.replicated(mesh)
    state_shardings = {
        "params": p_shard,
        "opt": o_shard,
        "efb": None,
        "step": repl,
    }
    batch_shardings = jax.tree.map(
        lambda x: shd.batch_sharding(mesh, x.ndim), state_shapes["batch"])
    tbl_shardings = jax.tree.map(lambda _: repl, state_shapes["tables"]) \
        if state_shapes.get("tables") is not None else None
    return jax.jit(
        step,
        in_shardings=(state_shardings, batch_shardings, tbl_shardings),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,) if donate else (),
    )
