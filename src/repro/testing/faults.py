"""Deterministic fault-injection harness for the control plane.

Robustness claims are only as good as the failures they were tested
against, and ad-hoc monkeypatching produces failures nobody can replay.
This module is the one place faults come from:

* **Crash points** — named locations threaded through the journal
  (``journal.append.write`` ...), the HA replication pipeline
  (``ha.leader.before_ship`` ...) and anything else that opts in call
  ``FaultInjector.crashpoint(name)``; the injector raises
  ``InjectedCrash`` on exactly the scheduled hits.  A crash-point sweep
  (tests/test_faults.py) kills the journal at *every* write/rename step
  and proves recovery from what is left on disk.
* **Torn writes** — ``torn_bytes`` truncates a payload at a
  deterministic fraction, modeling a process killed mid-``write(2)``.
* **Frame faults** — ``FaultyTransport`` wraps any controld transport
  and drops, duplicates or delays request/reply frames per a seeded
  schedule.  With client request-ids (idempotent resend) a dropped
  reply or a duplicated request must be invisible to daemon state.
* **Frozen clocks** — ``FrozenClock`` is a manually-advanced clock for
  lease/heartbeat timing tests.

Everything is driven by one seeded ``random.Random`` plus explicit hit
schedules, and every decision is appended to ``injector.log`` — same
seed, same call sequence => same failure schedule, byte for byte
(asserted by tests/test_faults.py), which is what lets the chaos
scenarios gate on digest equality.
"""
from __future__ import annotations

import random
from typing import Iterable, Optional


class InjectedCrash(RuntimeError):
    """A scheduled crash fired. Deliberately *not* a SessionError or
    TransportError subclass: production code must never swallow it."""


class FrozenClock:
    """A clock that only moves when told to — lease semantics in tests."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot go backwards (dt={dt})")
        self._t += float(dt)
        return self._t

    def __call__(self) -> float:  # usable directly as ``clock=...``
        return self._t


class FaultInjector:
    """One seeded source of scheduled failures.

    ``crash_at`` maps crash-point name -> which hit (1-based) should
    crash; ``torn_at`` maps a crash-point name -> fraction of the
    payload to keep (the rest is torn off).  Frame fault rates are
    probabilities evaluated on the seeded RNG in call order.  Every
    decision lands in ``log`` as ``(point, hit_index, action)`` so a
    schedule can be compared across runs.
    """

    def __init__(self, seed: int = 0,
                 crash_at: Optional[dict] = None,
                 torn_at: Optional[dict] = None,
                 drop_request: float = 0.0,
                 drop_reply: float = 0.0,
                 dup_request: float = 0.0,
                 delay_s: float = 0.0,
                 delay_rate: float = 0.0):
        self.seed = int(seed)
        self.rng = random.Random(self.seed)
        self.crash_at = dict(crash_at or {})
        self.torn_at = dict(torn_at or {})
        self.drop_request = float(drop_request)
        self.drop_reply = float(drop_reply)
        self.dup_request = float(dup_request)
        self.delay_s = float(delay_s)
        self.delay_rate = float(delay_rate)
        self.hits: dict[str, int] = {}
        self.log: list[tuple] = []

    # -- crash points ---------------------------------------------------------
    def crashpoint(self, name: str) -> None:
        """Count a hit on ``name``; raise ``InjectedCrash`` iff this hit
        is the scheduled one (``crash_at[name]``, 1-based)."""
        n = self.hits.get(name, 0) + 1
        self.hits[name] = n
        if self.crash_at.get(name) == n:
            self.log.append((name, n, "crash"))
            raise InjectedCrash(f"injected crash at {name} (hit {n})")
        self.log.append((name, n, "pass"))

    def torn_bytes(self, name: str, data: bytes) -> Optional[bytes]:
        """If ``name`` is scheduled for a torn write, return the prefix
        that 'made it to disk' (deterministic fraction); else None."""
        frac = self.torn_at.get(name)
        if frac is None:
            return None
        keep = max(0, min(len(data), int(len(data) * float(frac))))
        self.log.append((name, self.hits.get(name, 0), f"torn:{keep}"))
        return data[:keep]

    # -- frame fates ----------------------------------------------------------
    def frame_fate(self, point: str = "frame") -> str:
        """One deterministic fate draw for an outgoing request frame:
        ``deliver`` | ``drop_request`` | ``drop_reply`` | ``dup_request``
        (plus an independent ``delay`` draw via :meth:`frame_delay`)."""
        n = self.hits.get(point, 0) + 1
        self.hits[point] = n
        r = self.rng.random()
        edge = self.drop_request
        if r < edge:
            fate = "drop_request"
        elif r < (edge := edge + self.drop_reply):
            fate = "drop_reply"
        elif r < edge + self.dup_request:
            fate = "dup_request"
        else:
            fate = "deliver"
        self.log.append((point, n, fate))
        return fate

    def frame_delay(self) -> float:
        """Deterministic per-frame delay in seconds (0.0 = none)."""
        if self.delay_rate <= 0.0 or self.delay_s <= 0.0:
            return 0.0
        return self.delay_s if self.rng.random() < self.delay_rate else 0.0

    def schedule(self) -> tuple:
        """The full decision log as a hashable value (determinism gate:
        same seed + same call sequence => identical schedule)."""
        return tuple(self.log)


class FaultyTransport:
    """Wrap any controld transport (``call``/``call_many``/``close``)
    with seeded frame faults.

    * ``drop_request`` — the request never reaches the daemon; the
      caller sees a ``TransportError`` (as if the connection died).
    * ``drop_reply``   — the daemon handled the request but the reply
      is lost; the caller sees a ``TransportError``.  Only an
      idempotent resend (client request-ids) makes this safe.
    * ``dup_request``  — the request is delivered twice (a retransmit
      racing the original); the duplicate's reply is discarded.
    * delays           — ``sleep(delay)`` before delivery; pass the
      virtual clock's ``advance`` to model delay in simulated time.
    """

    def __init__(self, inner, injector: FaultInjector, sleep=None):
        # late import keeps repro.testing importable without controld
        from repro.controld.transport import TransportError
        self._TransportError = TransportError
        self.inner = inner
        self.injector = injector
        self.sleep = sleep

    def call(self, msg):
        inj = self.injector
        fate = inj.frame_fate()
        delay = inj.frame_delay()
        if delay and self.sleep is not None:
            self.sleep(delay)
        if fate == "drop_request":
            raise self._TransportError("injected fault: request dropped")
        if fate == "dup_request":
            self.inner.call(msg)  # the duplicate delivery
            return self.inner.call(msg)
        reply = self.inner.call(msg)
        if fate == "drop_reply":
            raise self._TransportError("injected fault: reply dropped")
        return reply

    def call_many(self, msgs) -> list:
        return [self.call(m) for m in msgs]

    def close(self) -> None:
        self.inner.close()


def crash_sweep(points: Iterable[str], run, check) -> list[str]:
    """Drive ``run(injector)`` once per crash point with a crash
    scheduled at that point's first hit, then call ``check(point)`` to
    assert recovery.  ``run`` must raise ``InjectedCrash`` through (the
    sweep asserts the point actually fired).  Returns the points that
    fired — a point that never fired is a sweep bug (stale name) and
    raises ``AssertionError``."""
    fired = []
    for point in points:
        inj = FaultInjector(seed=0, crash_at={point: 1})
        try:
            run(inj)
        except InjectedCrash:
            fired.append(point)
        else:
            raise AssertionError(
                f"crash point {point!r} never fired — stale sweep entry?")
        check(point)
    return fired
