"""Test-support utilities shipped with the package (hypothesis compat shim)."""
