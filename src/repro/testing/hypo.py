"""Property-testing front end: real hypothesis when installed, otherwise a
seeded random-sampling fallback with the same decorator surface.

The test suite is written against ``given``/``settings``/``st`` from this
module. When hypothesis is available (``pip install -e .[test]``) the tests
get real shrinking and example databases; in minimal containers the fallback
draws a fixed number of deterministic pseudo-random examples per test so the
properties are still exercised (no silent skips). Only the strategy
combinators the suite actually uses are implemented.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(10_000):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate too strict for fallback")

            return _Strategy(draw)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=None):
            hi = (min_value + 2**63) if max_value is None else max_value
            return _Strategy(lambda rng: rng.randint(min_value, hi))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            hi = min_size + 10 if max_size is None else max_size

            def draw(rng):
                return [elements.draw(rng) for _ in range(rng.randint(min_size, hi))]

            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(lambda rng: tuple(e.draw(rng) for e in elements))

    st = _Strategies()

    def given(*garg_strategies, **gkw_strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            # hypothesis semantics: positional strategies bind the rightmost
            # parameters; keyword strategies bind by name.
            free = [p for p in names if p not in gkw_strategies]
            pos_targets = free[len(free) - len(garg_strategies):] if garg_strategies else []
            bound = set(gkw_strategies) | set(pos_targets)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # Read at call time so @settings works above OR below @given
                # (above: set on this wrapper; below: copied from fn by wraps).
                n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_EXAMPLES)
                for i in range(n):
                    rng = random.Random(0xE1FA7 * 2654435761 + i)
                    kw = dict(kwargs)
                    for name, s in zip(pos_targets, garg_strategies):
                        kw[name] = s.draw(rng)
                    for name, s in gkw_strategies.items():
                        kw[name] = s.draw(rng)
                    fn(*args, **kw)

            # Hide strategy-bound parameters so pytest doesn't see fixtures.
            wrapper.__signature__ = sig.replace(
                parameters=[sig.parameters[p] for p in names if p not in bound]
            )
            return wrapper

        return deco

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_kw):
        """Decorator form only; global profiles are a no-op in the fallback."""

        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco
