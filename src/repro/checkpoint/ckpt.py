"""Sharded checkpointing with manifest + async save + restart.

Layout: <dir>/step_<N>/arrays.npz  (leaf path -> array) and manifest.json
(step, leaf index, dtypes, optional metadata). On a multi-host cluster each
process writes only the shards it owns (addressable_shards); in this
single-process container that degenerates to full arrays — the path layout
and manifest format already carry shard metadata so the restore path is the
same code. Atomic rename guards against torn checkpoints (fault tolerance:
a killed save never corrupts the restore source).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        out[key] = leaf
    return out


def save(directory: str, step: int, tree, *, metadata: dict | None = None) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    flat = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    def savable(v):
        a = np.asarray(v)
        # npz can't round-trip extension dtypes (bfloat16 etc.): widen to
        # f32 (lossless for bf16); the restore path casts back per-leaf.
        if a.dtype.kind not in "biufc":
            a = a.astype(np.float32)
        return a

    arrays = {k: savable(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for k, a in arrays.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncSaver:
    """Fire-and-forget background saves (one in flight; newer wins)."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, directory: str, step: int, tree, **kw) -> None:
        # Snapshot to host memory on the caller's thread (device buffers may
        # be donated right after).
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(directory, step, host_tree), kwargs=kw)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, tree_like, *, step: int | None = None):
    """Restore into the structure of ``tree_like``. Returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat_saved = dict(z)
    flat_like = _flatten(tree_like)
    missing = set(flat_like) - set(flat_saved)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    new_leaves = []
    for path_k, leaf in leaves_paths:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path_k
        )
        arr = flat_saved[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step
