"""Model assembly: all 10 assigned architectures behind one API.

Layer stacks use ``jax.lax.scan`` over *stacked* per-layer params so compiled
HLO size is O(1) in depth (required: 100-layer models compile on the 512-way
dry-run meshes). Heterogeneous stacks (llama-vision cross-attn every 10th
layer, zamba2's shared attention every 6th mamba block) are grouped nested
scans; shared-parameter blocks (zamba2) are closure constants of the scan
body, applied once per group.

Public API:
    init_params(rng, cfg)                       -> params pytree
    forward(params, batch, cfg, remat=...)      -> logits [B, T, V]
    train_loss(params, batch, cfg)              -> (loss, metrics)
    init_decode_state(cfg, batch, max_len)      -> cache pytree
    prefill(params, batch, state, cfg)          -> (logits_last, state)
    decode_step(params, token, state, cfg)      -> (logits, state)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv6 as R6
from repro.models.config import ModelConfig

F32 = jnp.float32


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _stack_init(init_fn, key, n: int):
    """vmap an init over n layer keys -> params with leading layer dim."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# Per-family block bodies
# ---------------------------------------------------------------------------

def _attn_block(p, x, cfg, positions, cache, q_chunk, k_chunk):
    h, new_cache = L.self_attention_block(
        p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
        positions=positions, cache=cache, q_chunk=q_chunk, k_chunk=k_chunk,
    )
    x = x + h
    x = constrain(x, ("batch", "seq", None))
    y = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        ff, aux = MOE.moe_ffn(p["moe"], y, cfg)
    else:
        ff, aux = L.mlp(p["mlp"], y, cfg.act), None
    x = x + ff
    x = constrain(x, ("batch", "seq", None))
    return x, new_cache, aux


def _attn_block_init(cfg, dtype, with_moe):
    def init(key):
        ks = jax.random.split(key, 2)
        p = {
            "attn": L.attn_init(ks[0], cfg, dtype),
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
        }
        if with_moe:
            p["moe"] = MOE.moe_init(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act,
                                  cfg.n_layers, dtype)
        return p
    return init


def _cross_block_init(cfg, dtype):
    def init(key):
        ks = jax.random.split(key, 2)
        return {
            "attn": L.attn_init(ks[0], cfg, dtype, cross=True),
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act,
                              cfg.n_layers, dtype),
        }
    return init


def _cross_block(p, x, cfg, vision, q_chunk, k_chunk):
    h = L.cross_attention_block(
        p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps), vision, cfg,
        q_chunk=q_chunk, k_chunk=k_chunk,
    )
    x = x + h
    ff = L.mlp(p["mlp"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
    return constrain(x + ff, ("batch", "seq", None))


def _mamba_block_init(cfg, dtype):
    def init(key):
        return {
            "mamba": M2.mamba2_init(key, cfg, dtype),
            "ln": jnp.ones((cfg.d_model,), dtype),
        }
    return init


def _mamba_block(p, x, cfg, state):
    h, new_state = M2.mamba2_block(
        p["mamba"], L.rms_norm(x, p["ln"], cfg.norm_eps), cfg, state=state
    )
    return constrain(x + h, ("batch", "seq", None)), new_state


def _rwkv_block_init(cfg, dtype):
    def init(key):
        return {
            "rwkv": R6.rwkv6_init(key, cfg, dtype),
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
        }
    return init


def _rwkv_block(p, x, cfg, state, chunk_size):
    st_t = None if state is None else {"shift": state["tshift"], "wkv": state["wkv"]}
    h, new_t = R6.rwkv6_time_mix(
        p["rwkv"], L.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
        state=st_t, chunk_size=chunk_size,
    )
    x = x + h
    st_c = None if state is None else state["cshift"]
    h2, new_c = R6.rwkv6_channel_mix(
        p["rwkv"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cfg, state=st_c
    )
    x = constrain(x + h2, ("batch", "seq", None))
    new_state = {"tshift": new_t["shift"], "wkv": new_t["wkv"], "cshift": new_c}
    return x, new_state


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(rng, cfg: ModelConfig):
    dtype = _dtype(cfg)
    k_emb, k_layers, k_cross, k_shared, k_head = jax.random.split(rng, 5)
    params: dict[str, Any] = {
        "embed": L.dense_init(k_emb, (cfg.vocab, cfg.d_model), scale=1.0, dtype=dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "head": L.dense_init(k_head, (cfg.d_model, cfg.vocab), dtype=dtype),
    }
    if cfg.family in ("dense", "audio"):
        params["layers"] = _stack_init(
            _attn_block_init(cfg, dtype, with_moe=False), k_layers, cfg.n_layers)
    elif cfg.family == "moe":
        params["layers"] = _stack_init(
            _attn_block_init(cfg, dtype, with_moe=True), k_layers, cfg.n_layers)
    elif cfg.family == "vlm":
        g = cfg.n_layers // cfg.cross_attn_every
        s = cfg.cross_attn_every - 1

        def group_init(key):
            k1, k2 = jax.random.split(key)
            return {
                "self": _stack_init(_attn_block_init(cfg, dtype, False), k1, s),
                "cross": _cross_block_init(cfg, dtype)(k2),
            }
        params["groups"] = _stack_init(group_init, k_layers, g)
    elif cfg.family == "hybrid":
        g = cfg.n_layers // cfg.attn_every

        def group_init(key):
            return {"mamba": _stack_init(_mamba_block_init(cfg, dtype), key,
                                         cfg.attn_every)}
        params["groups"] = _stack_init(group_init, k_layers, g)
        params["shared_attn"] = _attn_block_init(cfg, dtype, False)(k_shared)
    elif cfg.family == "ssm":
        params["layers"] = _stack_init(_rwkv_block_init(cfg, dtype), k_layers,
                                       cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# forward (training / no-cache path)
# ---------------------------------------------------------------------------

def _embed(params, batch, cfg):
    """Token or stub-frontend embedding. batch: dict with 'tokens' [B,T] int
    or 'embeds' [B,T,d] (audio frames / any precomputed stream)."""
    if "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = params["embed"][batch["tokens"]]
    return constrain(x, ("batch", "seq", None))


def forward(params, batch, cfg: ModelConfig, *, remat: bool = True,
            q_chunk: int = 1024, k_chunk: int = 1024, rwkv_chunk: int = 1):
    """Full-sequence forward -> logits [B, T, V] (f32). ``batch`` may carry
    'vision_embeds' [B, Nv, d] for the vlm family."""
    x = _embed(params, batch, cfg)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    aux_acc = jnp.zeros((), F32)

    if cfg.family in ("dense", "moe", "audio"):
        def body(x, p):
            y, _, aux = _attn_block(p, x, cfg, positions, None, q_chunk, k_chunk)
            return y, (aux["aux_loss"] if aux else jnp.zeros((), F32))
        body_fn = jax.checkpoint(body) if remat else body
        x, auxs = jax.lax.scan(body_fn, x, params["layers"])
        aux_acc = auxs.sum()
    elif cfg.family == "vlm":
        vision = batch["vision_embeds"].astype(_dtype(cfg))

        def group(x, gp):
            def self_body(x, p):
                y, _, _ = _attn_block(p, x, cfg, positions, None, q_chunk, k_chunk)
                return y, None
            sb = jax.checkpoint(self_body) if remat else self_body
            x, _ = jax.lax.scan(sb, x, gp["self"])
            cb = jax.checkpoint(
                lambda x, p: (_cross_block(p, x, cfg, vision, q_chunk, k_chunk), None)
            ) if remat else (lambda x, p: (_cross_block(p, x, cfg, vision, q_chunk, k_chunk), None))
            x, _ = cb(x, gp["cross"])
            return x, None
        x, _ = jax.lax.scan(group, x, params["groups"])
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group(x, gp):
            def mb(x, p):
                y, _ = _mamba_block(p, x, cfg, None)
                return y, None
            mb_fn = jax.checkpoint(mb) if remat else mb
            x, _ = jax.lax.scan(mb_fn, x, gp["mamba"])
            y, _, _ = _attn_block(shared, x, cfg, positions, None, q_chunk, k_chunk)
            return y, None
        x, _ = jax.lax.scan(group, x, params["groups"])
    elif cfg.family == "ssm":
        def body(x, p):
            y, _ = _rwkv_block(p, x, cfg, None, rwkv_chunk)
            return y, None
        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, x, params["layers"])

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", x, params["head"],
                        preferred_element_type=F32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = constrain(logits, ("batch", None, "vocab"))
    return logits, aux_acc


def train_loss(params, batch, cfg: ModelConfig, *, remat: bool = True,
               q_chunk: int = 1024, k_chunk: int = 1024, rwkv_chunk: int = 1):
    """Next-token CE for causal archs; per-frame CE for encoder-only (labels
    supplied by the masked-prediction stub). Adds MoE aux loss + z-loss."""
    logits, aux = forward(params, batch, cfg, remat=remat, q_chunk=q_chunk,
                          k_chunk=k_chunk, rwkv_chunk=rwkv_chunk)
    labels = batch["labels"]
    if cfg.causal:
        logits_s = logits[:, :-1]
        labels_s = labels[:, 1:]
    else:
        logits_s, labels_s = logits, labels
    logp = jax.nn.log_softmax(logits_s, axis=-1)
    ll = jnp.take_along_axis(logp, labels_s[..., None], axis=-1)[..., 0]
    mask = (labels_s >= 0).astype(F32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / denom
    # z-loss keeps the softmax normalizer tame (standard at scale).
    zl = 1e-4 * ((jax.scipy.special.logsumexp(logits_s, axis=-1) ** 2) * mask).sum() / denom
    loss = ce + zl + 0.01 * aux
    return loss, {"ce": ce, "z_loss": zl, "moe_aux": aux}


# ---------------------------------------------------------------------------
# decode path (serving)
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    """Cache pytree for serving. Attention caches are ring buffers of size
    min(max_len, swa_window or max_len); SSM/RWKV states are O(1)."""
    dtype = _dtype(cfg)
    if cfg.family in ("dense", "moe"):
        size = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
        cache = jax.vmap(
            lambda _: L.init_kv_cache(batch, size, cfg.n_kv_heads, cfg.hd, dtype)
        )(jnp.arange(cfg.n_layers))
        return {"kv": cache, "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "vlm":
        g = cfg.n_layers // cfg.cross_attn_every
        s = cfg.cross_attn_every - 1
        size = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
        cache = jax.vmap(jax.vmap(
            lambda _: L.init_kv_cache(batch, size, cfg.n_kv_heads, cfg.hd, dtype)
        ))(jnp.arange(g * s).reshape(g, s))
        return {"kv": cache, "pos": jnp.zeros((batch,), jnp.int32), "vision": None}
    if cfg.family == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        kv = jax.vmap(
            lambda _: L.init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, dtype)
        )(jnp.arange(g))
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        ssm = {
            "h": jnp.zeros((g, cfg.attn_every, batch, cfg.ssm_heads,
                            cfg.ssm_state, cfg.ssm_head_dim), F32),
            "conv": jnp.zeros((g, cfg.attn_every, batch, cfg.conv_kernel - 1,
                               conv_dim), dtype),
        }
        return {"kv": kv, "ssm": ssm, "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "ssm":
        h, p = cfg.rwkv_heads, cfg.ssm_head_dim
        return {
            "wkv": jnp.zeros((cfg.n_layers, batch, h, p, p), F32),
            "tshift": jnp.zeros((cfg.n_layers, batch, cfg.d_model), F32),
            "cshift": jnp.zeros((cfg.n_layers, batch, cfg.d_model), F32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    raise ValueError(f"{cfg.name}: family {cfg.family} has no decode path")


def _logits_last(params, x, cfg):
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return jnp.einsum("btd,dv->btv", x, params["head"], preferred_element_type=F32)


def step_with_cache(params, batch, state, cfg: ModelConfig, *,
                    q_chunk: int = 1024, k_chunk: int = 1024,
                    rwkv_chunk: int = 1):
    """Run T tokens (T=1 decode, T>1 prefill) against the cache pytree."""
    x = _embed(params, batch, cfg)
    b, t, _ = x.shape
    pos0 = state["pos"]  # int32[B] — lanes advance independently
    positions = pos0[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    new_state = dict(state)
    new_state["pos"] = pos0 + t

    if cfg.family in ("dense", "moe"):
        def body(x, xs):
            p, cache = xs
            y, nc, _ = _attn_block(p, x, cfg, positions, cache, q_chunk, k_chunk)
            return y, nc
        x, new_kv = jax.lax.scan(body, x, (params["layers"], state["kv"]))
        new_state["kv"] = new_kv
    elif cfg.family == "vlm":
        # Vision tokens are static across decode: captured at prefill, reused
        # from state for subsequent steps.
        if "vision_embeds" in batch:
            vision = batch["vision_embeds"].astype(_dtype(cfg))
            new_state["vision"] = vision
        else:
            vision = state["vision"]

        def group(x, xs):
            gp, caches = xs

            def sb(x, xs2):
                p, c = xs2
                y, nc, _ = _attn_block(p, x, cfg, positions, c, q_chunk, k_chunk)
                return y, nc
            x, ncs = jax.lax.scan(sb, x, (gp["self"], caches))
            x = _cross_block(gp["cross"], x, cfg, vision, q_chunk, k_chunk)
            return x, ncs
        x, new_kv = jax.lax.scan(group, x, (params["groups"], state["kv"]))
        new_state["kv"] = new_kv
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group(x, xs):
            gp, kvc, ssm = xs

            def mb(x, xs2):
                p, st = xs2
                y, ns = _mamba_block(p, x, cfg, st)
                return y, ns
            x, nss = jax.lax.scan(mb, x, (gp["mamba"],
                                          {"h": ssm["h"], "conv": ssm["conv"]}))
            y, nkv, _ = _attn_block(shared, x, cfg, positions, kvc, q_chunk, k_chunk)
            return y, (nkv, nss)
        x, (new_kv, new_ssm) = jax.lax.scan(
            group, x, (params["groups"], state["kv"], state["ssm"]))
        new_state["kv"] = new_kv
        new_state["ssm"] = new_ssm
    elif cfg.family == "ssm":
        def body(x, xs):
            p, st = xs
            y, ns = _rwkv_block(p, x, cfg, st, rwkv_chunk)
            return y, ns
        st = {"tshift": state["tshift"], "wkv": state["wkv"], "cshift": state["cshift"]}
        x, ns = jax.lax.scan(body, x, (params["layers"], st))
        new_state.update(ns)
    else:
        raise ValueError(cfg.family)

    logits = _logits_last(params, x[:, -1:, :], cfg)
    return logits[:, 0], new_state


def prefill(params, batch, state, cfg: ModelConfig, **kw):
    return step_with_cache(params, batch, state, cfg, **kw)


def decode_step(params, tokens, state, cfg: ModelConfig, **kw):
    """tokens: int32[B] -> (logits [B, V], new_state)."""
    return step_with_cache(params, {"tokens": tokens[:, None]}, state, cfg, **kw)
