"""RWKV-6 "Finch" block for rwkv6-7b: attention-free time-mix with
data-dependent decay + channel-mix.

Per head (head_dim P): state S in R^{P x P};

    w_t = exp(-exp(w0 + lora_w(x~_t)))          (data-dependent decay)
    o_t = r_t . (S_{t-1} + (u (x) 1) * k_t^T v_t)
    S_t = S_{t-1} * diag(w_t) + k_t^T v_t

Baseline path: lax.scan over time (exact). An optimized chunked-WKV path
(flash-linear-attention-style, exp-rescaled matmuls per chunk) is selectable
with ``chunk_size > 1`` — used by the perf phase; it matches the scan path to
fp32 tolerance (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

F32 = jnp.float32
LORA = 64


def rwkv6_init(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    h, p = cfg.rwkv_heads, cfg.ssm_head_dim
    ks = jax.random.split(key, 12)
    out_scale = 1.0 / (2 * cfg.n_layers) ** 0.5
    return {
        # time-mix
        "mu": 0.5 * jnp.ones((5, d), F32),  # token-shift lerp for r,k,v,g,w
        "wr": dense_init(ks[0], (d, d), dtype=dtype),
        "wk": dense_init(ks[1], (d, d), dtype=dtype),
        "wv": dense_init(ks[2], (d, d), dtype=dtype),
        "wg": dense_init(ks[3], (d, d), dtype=dtype),
        "w0": jnp.full((d,), -6.0, F32),
        "w_lora_a": dense_init(ks[4], (d, LORA), dtype=F32),
        "w_lora_b": dense_init(ks[5], (LORA, d), dtype=F32),
        "bonus_u": jnp.zeros((h, p), F32),
        "ln_x": jnp.ones((d,), dtype),
        "wo": dense_init(ks[6], (d, d), scale=out_scale, dtype=dtype),
        # channel-mix
        "mu_c": 0.5 * jnp.ones((2, d), F32),
        "ck": dense_init(ks[7], (d, ff), dtype=dtype),
        "cv": dense_init(ks[8], (ff, d), scale=out_scale, dtype=dtype),
        "cr": dense_init(ks[9], (d, d), dtype=dtype),
    }


def _token_shift(x, prev):
    """x: [B, T, d]; prev: [B, d] (last token of previous segment)."""
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def rwkv6_time_mix(params, x, cfg, *, state=None, chunk_size: int = 1):
    """x: [B, T, d]. state: dict(shift [B,d], wkv [B,H,P,P]) or None."""
    b, t, d = x.shape
    h, p = cfg.rwkv_heads, cfg.ssm_head_dim
    prev = jnp.zeros((b, d), x.dtype) if state is None else state["shift"].astype(x.dtype)
    xs = _token_shift(x, prev)
    mu = params["mu"]
    xr, xk, xv, xg, xw = (
        x + (mu[i] * (xs.astype(F32) - x.astype(F32))).astype(x.dtype)
        for i in range(5)
    )
    r = (xr @ params["wr"]).reshape(b, t, h, p).astype(F32)
    k = (xk @ params["wk"]).reshape(b, t, h, p).astype(F32)
    v = (xv @ params["wv"]).reshape(b, t, h, p).astype(F32)
    g = xg @ params["wg"]
    lora = jnp.tanh(xw.astype(F32) @ params["w_lora_a"]) @ params["w_lora_b"]
    w = jnp.exp(-jnp.exp(params["w0"] + lora))  # [B,T,d] in (0,1)
    w = w.reshape(b, t, h, p)

    wkv0 = None if state is None else state["wkv"]
    if chunk_size > 1:
        o, s_fin = _wkv_chunked_with_state(r, k, v, w, params["bonus_u"], chunk_size, wkv0)
    else:
        o, s_fin = _wkv_scan_with_state(r, k, v, w, params["bonus_u"], wkv0)

    o = o.reshape(b, t, d).astype(x.dtype)
    o = rms_norm(o, params["ln_x"], cfg.norm_eps)
    o = (o * jax.nn.silu(g)) @ params["wo"]
    new_state = {"shift": x[:, -1, :].astype(F32), "wkv": s_fin}
    return o, new_state


def _wkv_scan_with_state(r, k, v, w, u, s0):
    b, t, h, p = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, p, p), F32)

    def step(s, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        o = jnp.einsum("bhp,bhpq->bhq", rt, s + u[None, :, :, None] * kv)
        s_new = s * wt[..., :, None] + kv
        return s_new, o

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    s_fin, os = jax.lax.scan(step, s0.astype(F32), xs)
    return os.transpose(1, 0, 2, 3), s_fin


def _wkv_chunked_with_state(r, k, v, w, u, chunk, s0):
    b, t, h, p = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, p, p), F32)
    # Reuse _wkv_chunked but thread s0 through the scan carry.
    out, s_fin = _wkv_chunked_carry(r, k, v, w, u, chunk, s0.astype(F32))
    return out, s_fin


def _wkv_chunked_carry(r, k, v, w, u, chunk, s0):
    b, t, h, p = r.shape
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        r, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))) for a in (r, k, v))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    logw = jnp.log(jnp.maximum(w, 1e-30))

    def to_chunks(a):
        return a.reshape(b, n_chunks, chunk, h, p).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))
    li = jnp.arange(chunk)
    strict = (li[:, None] > li[None, :])

    def step(s, xs):
        rt, kt, vt, lw = xs
        cum = jnp.cumsum(lw, axis=1)
        cum_im1 = jnp.concatenate([jnp.zeros_like(cum[:, :1]), cum[:, :-1]], axis=1)
        m = jnp.max(cum, axis=1, keepdims=True)
        r_t = rt * jnp.exp(cum_im1 - m)
        k_t = kt * jnp.exp(m - cum)
        scores = jnp.einsum("bihp,bjhp->bhij", r_t, k_t)
        scores = scores * strict[None, None]
        o_intra = jnp.einsum("bhij,bjhq->bihq", scores, vt)
        diag = jnp.einsum("bihp,bihp->bih", rt, u[None, None] * kt)
        o_intra = o_intra + diag[..., None] * vt
        o_inter = jnp.einsum("bihp,bhpq->bihq", rt * jnp.exp(cum_im1), s)
        suffix = jnp.exp(cum[:, -1:] - cum)
        s_new = s * jnp.exp(cum[:, -1])[..., None] + jnp.einsum(
            "bjhp,bjhq->bhpq", kt * suffix, vt
        )
        return s_new, o_intra + o_inter

    s_fin, os = jax.lax.scan(step, s0, (rc, kc, vc, lwc))
    o = os.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h, p)[:, :t]
    return o, s_fin


def rwkv6_channel_mix(params, x, cfg, *, state=None):
    """Channel-mix (relu^2 FFN with token shift). state: [B, d] prev token."""
    b, t, d = x.shape
    prev = jnp.zeros((b, d), x.dtype) if state is None else state.astype(x.dtype)
    xs = _token_shift(x, prev)
    mu = params["mu_c"]
    xk = x + (mu[0] * (xs.astype(F32) - x.astype(F32))).astype(x.dtype)
    xr = x + (mu[1] * (xs.astype(F32) - x.astype(F32))).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ params["ck"]))
    out = jax.nn.sigmoid(xr @ params["cr"]) * (kk @ params["cv"])
    return out, x[:, -1, :].astype(F32)
