"""Model configuration shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str          # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int         # query heads (0 for attention-free archs)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention variants
    rope_theta: float = 1e4
    rope_fraction: float = 1.0      # chatglm "2d rope" => 0.5
    swa_window: Optional[int] = None
    causal: bool = True             # False => encoder-only (hubert)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # >1: shard-local grouped dispatch (beyond-paper perf; DESIGN.md §Perf).
    moe_dispatch_groups: int = 1

    # VLM (modality frontend is a stub: precomputed patch embeddings)
    cross_attn_every: int = 0       # every k-th layer is a cross-attn layer
    n_vision_tokens: int = 0

    # hybrid / ssm
    block_kind: str = "attn"        # attn | mamba2 | rwkv6
    attn_every: int = 0             # zamba2: shared attn after every k mamba blocks
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_kernel: int = 4

    act: str = "swiglu"             # swiglu | gelu
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    logit_softcap: float = 0.0

    def __post_init__(self):
        if self.family in ("dense", "moe", "vlm", "audio") and self.n_heads <= 0:
            raise ValueError(f"{self.name}: attention family needs heads")
        if self.family == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError(f"{self.name}: moe family needs experts/top_k")
        if self.cross_attn_every:
            if self.n_layers % self.cross_attn_every:
                raise ValueError(f"{self.name}: n_layers must divide into cross-attn groups")
        if self.attn_every and self.n_layers % self.attn_every:
            raise ValueError(f"{self.name}: n_layers must divide into attn_every groups")

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.ssm_head_dim

    @property
    def encoder_only(self) -> bool:
        return not self.causal

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for roofline MODEL_FLOPS = 6 N D) ---------------
    def param_count(self) -> tuple[int, int]:
        """Returns (total_params, active_params) — active differs for MoE."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

        def ffn_params(width):
            n_mats = 3 if self.act == "swiglu" else 2
            return n_mats * d * width

        total = active = 0
        if self.family in ("dense", "audio"):
            per = attn + ffn_params(ff) + 2 * d
            total = active = self.n_layers * per
        elif self.family == "vlm":
            n_cross = self.n_layers // self.cross_attn_every
            n_self = self.n_layers - n_cross
            per_self = attn + ffn_params(ff) + 2 * d
            per_cross = attn + ffn_params(ff) + 3 * d  # extra kv-src norm
            total = active = n_self * per_self + n_cross * per_cross
        elif self.family == "moe":
            router = d * self.n_experts
            experts = self.n_experts * ffn_params(ff)
            act_experts = self.top_k * ffn_params(ff)
            dense = ffn_params(ff) if self.moe_dense_residual else 0
            per_total = attn + router + experts + dense + 2 * d
            per_active = attn + router + act_experts + dense + 2 * d
            total = self.n_layers * per_total
            active = self.n_layers * per_active
        elif self.family == "hybrid":
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            mamba = (
                d * (2 * di + 2 * N + H)      # in projections (z, x, B, C, dt)
                + self.conv_kernel * (di + 2 * N)
                + 2 * H                        # A_log, D
                + di * d                       # out proj
                + 2 * d
            )
            n_attn_apps = self.n_layers // self.attn_every if self.attn_every else 0
            shared_attn = attn + ffn_params(ff) + 2 * d if n_attn_apps else 0
            total = active = self.n_layers * mamba + shared_attn
        elif self.family == "ssm":  # rwkv6
            H = self.rwkv_heads
            tmix = 4 * d * d + d * d  # r,k,v,g + out
            decay = d * 64 * 2 + d    # lora for data-dependent decay + w0
            cmix = 2 * d * ff // 2 if False else d * ff + ff * d  # k', v' projections
            per = tmix + decay + cmix + 2 * d + 2 * d  # + token-shift mixes
            total = active = self.n_layers * per
        emb = v * d * 2  # in + out embeddings (untied)
        return total + emb, active + emb
