"""Mamba2 (SSD) block for zamba2-2.7b — chunked state-space recurrence.

Implements the state-space-duality form: within a chunk of length L the
output is an (L x L) decay-masked matmul (MXU-friendly), across chunks a
small recurrent state h [H, N, P] is carried by lax.scan. Matches a
step-by-step recurrence oracle (tests/test_models.py).

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . h_t + D x_t

with per-head scalar A < 0, B_t/C_t in R^N (single group), x_t in R^{H x P}.
A depthwise causal conv (kernel 4) precedes the SSM as in the reference
implementation; z-gating and an RMSNorm follow it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

F32 = jnp.float32


def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ck = cfg.conv_kernel
    ks = jax.random.split(key, 8)
    out_scale = 1.0 / (2 * cfg.n_layers) ** 0.5
    return {
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * n + h), dtype=dtype),  # z,x,B,C,dt
        "conv_w": dense_init(ks[1], (ck, di + 2 * n), scale=ck ** 0.5, dtype=dtype),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "a_log": jnp.zeros((h,), F32),          # A = -exp(a_log)  in [-1, 0)-ish
        "d_skip": jnp.ones((h,), F32),
        "dt_bias": jnp.full((h,), -2.0, F32),   # softplus(dt_bias) ~ 0.12
        "ssm_norm": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[2], (di, d), scale=out_scale, dtype=dtype),
    }


def _split_proj(cfg, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : 2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, state=None):
    """Depthwise causal conv. xbc: [B, T, C]; state: [B, K-1, C] carry.

    Returns (out [B, T, C], new_state [B, K-1, C]).
    """
    k = conv_w.shape[0]
    b, t, c = xbc.shape
    if state is None:
        state = jnp.zeros((b, k - 1, c), xbc.dtype)
    full = jnp.concatenate([state, xbc], axis=1)  # [B, T+K-1, C]
    out = jnp.zeros((b, t, c), F32)
    for i in range(k):
        out = out + full[:, i : i + t, :].astype(F32) * conv_w[i].astype(F32)
    out = jax.nn.silu(out + conv_b.astype(F32)).astype(xbc.dtype)
    new_state = full[:, t:, :]
    return out, new_state


def _ssd_chunk(carry_h, xs, *, nheads, headdim, nstate):
    """One chunk of the SSD recurrence. carry_h: [B, H, N, P]."""
    xh, bmat, cmat, log_a = xs  # [B,L,H,P], [B,L,N], [B,L,N], [B,L,H]
    cum = jnp.cumsum(log_a, axis=1)  # [B, L, H]
    # Intra-chunk: decay-masked (L x L) attention-like matmul.
    scores = jnp.einsum("bin,bjn->bij", cmat, bmat)  # [B, L, L]
    decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # [B, L, L, H]
    li = jnp.arange(xh.shape[1])
    causal = (li[:, None] >= li[None, :])[None, :, :, None]
    w = scores[..., None] * jnp.where(causal, decay, 0.0)  # [B, L, L, H]
    y_intra = jnp.einsum("bijh,bjhp->bihp", w, xh)
    # Inter-chunk: contribution of the carried state.
    y_inter = jnp.einsum("bin,bhnp->bihp", cmat, carry_h) * jnp.exp(cum)[..., None]
    # State update.
    suffix = jnp.exp(cum[:, -1:, :] - cum)  # [B, L, H]
    h_new = carry_h * jnp.exp(cum[:, -1])[:, :, None, None] + jnp.einsum(
        "bjn,bjhp->bhnp", bmat, xh * suffix[..., None]
    )
    return h_new, y_intra + y_inter


def mamba2_block(params, x, cfg, *, state=None, chunk: int = 128):
    """x: [B, T, d]. state: dict(h [B,H,N,P], conv [B,K-1,C]) or None.

    Returns (out [B, T, d], new_state).
    """
    b, t, d = x.shape
    di, n, h_heads, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ params["w_in"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xs = xbc[..., :di]
    bmat = xbc[..., di : di + n].astype(F32)
    cmat = xbc[..., di + n :].astype(F32)
    dt = jax.nn.softplus(dt_raw.astype(F32) + params["dt_bias"])  # [B, T, H]
    a = -jnp.exp(params["a_log"])  # [H]
    log_a = dt * a  # [B, T, H]
    xh = xs.reshape(b, t, h_heads, p).astype(F32)
    xdt = xh * dt[..., None]

    h0 = jnp.zeros((b, h_heads, n, p), F32) if state is None else state["h"].astype(F32)
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(arr):
        return arr.reshape((b, n_chunks, chunk) + arr.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, arr.ndim + 1))
        )

    import functools
    step = functools.partial(_ssd_chunk, nheads=h_heads, headdim=p, nstate=n)
    h_final, ys = jax.lax.scan(
        step, h0, (to_chunks(xdt), to_chunks(bmat), to_chunks(cmat), to_chunks(log_a))
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, h_heads, p)[:, :t]
    y = y + xh * params["d_skip"][None, None, :, None]
    y = y.reshape(b, t, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["ssm_norm"], cfg.norm_eps)
    out = y @ params["w_out"]
    new_state = {"h": h_final.astype(F32), "conv": new_conv}
    return out, new_state
