"""Mixture-of-Experts FFN with capacity-based dispatch.

The dispatch math is the same machinery as the EJ-FAT calendar dispatch
(core/router.member_positions): each (token, k) assignment is a "packet"
whose "member" is the chosen expert; positions come from the exclusive
cumsum-of-one-hot; capacity overflow is dropped *and accounted* — the paper's
discard rule, applied to tokens. Experts are tensor-parallel: expert d_ff is
sharded on the mesh "model" axis (128 experts x 304 ff/chip for arctic).

Dispatch groups (``cfg.moe_dispatch_groups > 1``, beyond-paper perf feature —
EXPERIMENTS.md §Perf): the token stream splits into g groups matching the
data shards and each group dispatches into its own capacity slice of a
[g, E, C/g, d] buffer constrained to the data axes. The scatter then stays
shard-local and GSPMD never replicates (nor all-reduces) the full expert
buffer — the fix for the worst baseline roofline cell (mixtral train_4k).

arctic-480b additionally runs a dense residual FFN in parallel with the MoE
output (config.moe_dense_residual).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.router import member_positions
from repro.distributed.context import constrain
from repro.models.layers import dense_init, mlp, mlp_init

F32 = jnp.float32


def moe_init(key, cfg, dtype):
    ks = jax.random.split(key, 5)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    out_scale = 1.0 / (2 * cfg.n_layers) ** 0.5
    p = {
        "router": dense_init(ks[0], (d, e), dtype=F32),  # router in f32
        "w_gate": dense_init(ks[1], (e, d, ff), dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype=dtype),
        "w_down": dense_init(ks[3], (e, ff, d), scale=out_scale, dtype=dtype),
    }
    if cfg.moe_dense_residual:
        p["dense"] = mlp_init(ks[4], d, ff, cfg.act, cfg.n_layers, dtype)
    return p


def moe_ffn(params, x, cfg):
    """x: [B, T, d] -> ([B, T, d], aux) with load-balance aux loss + drop count."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(b * t, d)
    n = b * t

    logits = xt.astype(F32) @ params["router"]  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [N, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    g = max(int(getattr(cfg, "moe_dispatch_groups", 1) or 1), 1)
    if n % g:
        g = 1
    ng = n // g

    # k-major flatten within each group: first-choice packets dispatch before
    # any second-choice ones (first choices win capacity contention).
    # Capacity floor of 8 keeps small serving batches drop-free; ng*k cap
    # means a capacity larger than every assignment is never allocated.
    capacity = min(ng * k, max(int(cfg.capacity_factor * ng * k / e) + 1, 8))
    member_g = gate_idx.reshape(g, ng, k).transpose(0, 2, 1).reshape(g, k * ng)
    pos, keep, _counts = jax.vmap(
        lambda m: member_positions(m, e, capacity))(member_g)

    # Scatter tokens into [g, E, C, d] buffers (OOB index => dropped write).
    # vmap over the group dim keeps the scatter structurally group-local
    # (batched scatter dims partition trivially; an explicit g_idx gather
    # index would defeat GSPMD's locality analysis and replicate the buffer).
    m_idx = jnp.where(keep, member_g, e)
    p_idx = jnp.where(keep, pos, capacity)
    src = jnp.tile(xt.reshape(g, ng, d), (1, k, 1))  # [g, K*ng, d]
    buf = jax.vmap(
        lambda s, m, p: jnp.zeros((e, capacity, d), x.dtype)
        .at[m, p].set(s, mode="drop")
    )(src, m_idx, p_idx)
    if g > 1:
        buf = constrain(buf, ("batch", None, None, None))

    # Expert computation: batched matmuls (d_ff sharded on "model").
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]))
        h = h * jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buf, params["w_up"]))
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_down"])  # [g, E, C, d]
    if g > 1:
        out_buf = constrain(out_buf, ("batch", None, None, None))

    # Gather back and combine with gates; dropped assignments contribute 0.
    got = jax.vmap(lambda ob, m, p: ob[m, p])(
        out_buf, m_idx % e, p_idx % capacity)  # [g, K*ng, d]
    got = jnp.where(keep[..., None], got, 0)
    gates_g = gate_vals.reshape(g, ng, k).transpose(0, 2, 1).reshape(g, k * ng)
    combined = (got.astype(F32) * gates_g[..., None]).reshape(g, k, ng, d).sum(1)
    y = combined.astype(x.dtype).reshape(b, t, d)

    if cfg.moe_dense_residual:
        y = y + mlp(params["dense"], x, cfg.act)

    # Aux: Switch-style load-balance loss + drop accounting.
    me = probs.mean(0)  # [E] mean router prob
    ce = jnp.zeros(e, F32).at[member_g.reshape(-1)].add(
        keep.reshape(-1).astype(F32)) / jnp.maximum(n * k, 1)
    aux_loss = e * jnp.sum(me * ce)
    dropped = jnp.sum((member_g < e) & ~keep)
    return y, {"aux_loss": aux_loss, "dropped": dropped}
