"""Shared layer primitives: norms, RoPE, MLP, GQA attention (+SWA, cross),
KV caches. Pure functions over param pytrees; attention uses a chunked
online-softmax formulation so 32k-token prefill never materializes a full
score matrix (memory-roofline critical at the assigned shapes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: float = 1.0, dtype=jnp.bfloat16):
    fan_in = shape[0]
    std = scale / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2, 2, shape, F32) * std).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-5):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (fractional: chatglm applies rotary to half the head dims)
# ---------------------------------------------------------------------------

def rope_tables(positions, rot_dim: int, theta: float):
    """positions int32[...] -> (cos, sin) f32[..., rot_dim/2]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=F32) / rot_dim))
    angles = positions.astype(F32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, positions, *, fraction: float = 1.0, theta: float = 1e4):
    """x: [..., T, H, hd]; positions broadcastable to [..., T]."""
    hd = x.shape[-1]
    rot = int(hd * fraction) // 2 * 2
    if rot == 0:
        return x
    cos, sin = rope_tables(positions, rot, theta)  # [..., T, rot/2]
    cos = cos[..., None, :]  # add head dim
    sin = sin[..., None, :]
    xr = x[..., :rot].astype(F32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    rotated = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([rotated, x[..., rot:]], axis=-1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str, n_layers: int, dtype):
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / (2 * n_layers) ** 0.5
    if act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), scale=out_scale, dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), scale=out_scale, dtype=dtype),
    }


def mlp(params, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Chunked online-softmax attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_chunk_scan(q, k, v, qpos, kpos, kvalid, *, causal, window, k_chunk, scale):
    """Online softmax over k chunks.

    q: [B, Hkv, G, Tq, hd]; k/v: [B, Tk, Hkv, hd]; qpos [B, Tq]; kpos [B, Tk];
    kvalid bool[B, Tk]. Returns [B, Hkv, G, Tq, hd] (f32).
    """
    b, hkv, g, tq, hd = q.shape
    tk = k.shape[1]
    n_chunks = -(-tk // k_chunk)
    pad = n_chunks * k_chunk - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)))
        kvalid = jnp.pad(kvalid, ((0, 0), (0, pad)))
    # -> [n_chunks, B, C, ...]
    kc = k.reshape(b, n_chunks, k_chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, k_chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    pc = kpos.reshape(b, n_chunks, k_chunk).transpose(1, 0, 2)
    mc = kvalid.reshape(b, n_chunks, k_chunk).transpose(1, 0, 2)

    qf = q.astype(F32)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, pb, vb_mask = xs
        logits = jnp.einsum("bhgqd,bchd->bhgqc", qf, kb.astype(F32)) * scale
        mask = vb_mask[:, None, None, None, :]
        if causal:
            ok = pb[:, None, :] <= qpos[:, :, None]  # [B, Tq, C]
            if window is not None:
                ok &= qpos[:, :, None] - pb[:, None, :] < window
            mask = mask & ok[:, None, None, :, :]
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqc,bchd->bhgqd", p, vb.astype(F32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, tq), NEG_INF, F32)
    l0 = jnp.zeros((b, hkv, g, tq), F32)
    acc0 = jnp.zeros((b, hkv, g, tq, hd), F32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kc, vc, pc, mc))
    return jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None], 0.0)


def attention(
    q, k, v, *,
    qpos, kpos, kvalid=None,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
):
    """GQA attention. q: [B, Tq, Hq, hd]; k/v: [B, Tk, Hkv, hd].

    qpos/kpos: int32[B, Tq]/[B, Tk] absolute positions (ring caches pass
    per-slot positions; invalid slots masked by kvalid). Returns [B, Tq, Hq, hd].
    """
    b, tq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / (hd ** 0.5)
    if kvalid is None:
        kvalid = jnp.ones(k.shape[:2], bool)
    qg = q.transpose(0, 2, 1, 3).reshape(b, hkv, g, tq, hd)

    if tq <= q_chunk:
        out = _attn_chunk_scan(qg, k, v, qpos, kpos, kvalid, causal=causal,
                               window=window, k_chunk=k_chunk, scale=scale)
    else:
        n_q = -(-tq // q_chunk)
        pad = n_q * q_chunk - tq
        qg_p = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        qpos_p = jnp.pad(qpos, ((0, 0), (0, pad)))
        qs = qg_p.reshape(b, hkv, g, n_q, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
        ps = qpos_p.reshape(b, n_q, q_chunk).transpose(1, 0, 2)

        def qstep(_, xs):
            qb, pb = xs
            o = _attn_chunk_scan(qb, k, v, pb, kpos, kvalid, causal=causal,
                                 window=window, k_chunk=k_chunk, scale=scale)
            return None, o

        _, outs = jax.lax.scan(qstep, None, (qs, ps))
        out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, n_q * q_chunk, hd)
        out = out[..., :tq, :]
    return out.reshape(b, hq, tq, hd).transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (self / cross) + KV cache
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 5)
    out_scale = 1.0 / (2 * cfg.n_layers) ** 0.5
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (hq * hd, d), scale=out_scale, dtype=dtype),
    }
    if cross:
        p["kv_norm"] = jnp.ones((d,), dtype)
    return p


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Ring-capable KV cache. ``pos[b, s]`` = absolute position in slot s
    (-1 invalid). Full cache: size >= max_len; SWA: size == window."""

    k: jnp.ndarray    # [B, S, Hkv, hd]
    v: jnp.ndarray    # [B, S, Hkv, hd]
    pos: jnp.ndarray  # int32[B, S]
    length: jnp.ndarray  # int32 scalar — tokens seen so far


def init_kv_cache(batch, size, n_kv, hd, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, size, n_kv, hd), dtype),
        v=jnp.zeros((batch, size, n_kv, hd), dtype),
        pos=jnp.full((batch, size), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32),
    )


def self_attention_block(params, x, cfg, *, positions, cache: Optional[KVCache] = None,
                         q_chunk: int = 1024, k_chunk: int = 1024):
    """x: [B, T, d]. Returns (out [B, T, d], new_cache)."""
    b, t, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ params["wq"]).reshape(b, t, hq, hd)
    k = (x @ params["wk"]).reshape(b, t, hkv, hd)
    v = (x @ params["wv"]).reshape(b, t, hkv, hd)
    q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)

    new_cache = None
    if cache is None:
        kk, vv = k, v
        kpos, kvalid = positions, jnp.ones((b, t), bool)
    elif t > 1:
        # Prefill: attend over the fresh sequence (a ring cache smaller than
        # T would otherwise evict keys that early queries still need), then
        # write only the last `size` positions into the cache.
        size = cache.k.shape[1]
        keep = min(t, size)
        tail = slice(t - keep, t)
        tail_pos = positions[:, tail].astype(jnp.int32)
        slots = (tail_pos % size).astype(jnp.int32)
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        ck = cache.k.at[bidx, slots].set(k[:, tail])
        cv = cache.v.at[bidx, slots].set(v[:, tail])
        cpos = cache.pos.at[bidx, slots].set(tail_pos)
        new_cache = KVCache(k=ck, v=cv, pos=cpos, length=cache.length + t)
        kk, vv = k, v
        kpos, kvalid = positions, jnp.ones((b, t), bool)
    else:
        # Decode: single token -> distinct ring slot.
        size = cache.k.shape[1]
        slots = (positions % size).astype(jnp.int32)  # [B, 1]
        bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
        ck = cache.k.at[bidx, slots].set(k)
        cv = cache.v.at[bidx, slots].set(v)
        cpos = cache.pos.at[bidx, slots].set(positions.astype(jnp.int32))
        new_cache = KVCache(k=ck, v=cv, pos=cpos, length=cache.length + t)
        kk, vv = ck, cv
        kpos, kvalid = cpos, cpos >= 0

    o = attention(q, kk, vv, qpos=positions, kpos=kpos, kvalid=kvalid,
                  causal=cfg.causal, window=cfg.swa_window,
                  q_chunk=q_chunk, k_chunk=k_chunk)
    return o.reshape(b, t, hq * hd) @ params["wo"], new_cache


def cross_attention_block(params, x, kv_src, cfg, *, q_chunk=1024, k_chunk=1024):
    """Cross-attn to (vision) tokens. kv_src: [B, Nv, d]. No RoPE, no mask."""
    b, t, d = x.shape
    nv = kv_src.shape[1]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = rms_norm(kv_src, params["kv_norm"], cfg.norm_eps)
    q = (x @ params["wq"]).reshape(b, t, hq, hd)
    k = (src @ params["wk"]).reshape(b, nv, hkv, hd)
    v = (src @ params["wv"]).reshape(b, nv, hkv, hd)
    zeros_q = jnp.zeros((b, t), jnp.int32)
    zeros_k = jnp.zeros((b, nv), jnp.int32)
    o = attention(q, k, v, qpos=zeros_q, kpos=zeros_k, causal=False,
                  q_chunk=q_chunk, k_chunk=k_chunk)
    return o.reshape(b, t, hq * hd) @ params["wo"]
