"""UDP-over-WAN simulation: serialization, random path delay, reordering and
loss injection (paper fig. 7b shows exactly this at the LB input: "packet
serialization and random path delays are built into the traffic generator").
Unidirectional, no backpressure, no retransmit (paper §I-B.6).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TransportConfig:
    reorder_window: int = 32      # max positions a packet can be displaced
    loss_prob: float = 0.0
    duplicate_prob: float = 0.0
    seed: int = 0


class WANTransport:
    """Applies loss/duplication/reordering to a packet sequence."""

    def __init__(self, cfg: TransportConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.n_lost = 0
        self.n_dup = 0

    def deliver(self, packets: list) -> list:
        out = []
        for p in packets:
            if self.rng.random() < self.cfg.loss_prob:
                self.n_lost += 1
                continue
            out.append(p)
            if self.rng.random() < self.cfg.duplicate_prob:
                out.append(p)
                self.n_dup += 1
        if len(out) > 1 and self.cfg.reorder_window > 0:
            # bounded displacement: sort by (index + jitter)
            idx = np.arange(len(out), dtype=np.float64)
            jitter = self.rng.uniform(0, self.cfg.reorder_window, len(out))
            order = np.argsort(idx + jitter, kind="stable")
            out = [out[i] for i in order]
        return out
