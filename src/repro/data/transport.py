"""UDP-over-WAN simulation: serialization, random path delay, reordering and
loss injection (paper fig. 7b shows exactly this at the LB input: "packet
serialization and random path delays are built into the traffic generator").
Unidirectional, no backpressure, no retransmit (paper §I-B.6).

The production path is **batched**: ``deliver_batch`` applies loss as one
mask, duplication as a masked row copy, and reordering as a single
jitter-keyed permutation over the whole ``PacketBatch`` — drawn from a
``jax.random`` PRNG (one fold_in per window), replacing the per-packet
``rng.random()`` host loop. ``deliver`` keeps the per-packet list form for
the reference pipeline and tests.

Duplicate ordering: a duplicate models the *same* serialized packet taking a
second (never earlier) path, so its sort key is the original's key plus a
strictly non-negative extra delay — a duplicate can never overtake the first
copy (ties break original-first). The old implementation drew an independent
jitter for the duplicate, which could deliver the copy *before* its original
and effectively doubled the reorder window for duplicated packets.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.segmentation import PacketBatch


@dataclasses.dataclass
class TransportConfig:
    reorder_window: int = 32      # max positions a packet can be displaced
    loss_prob: float = 0.0
    duplicate_prob: float = 0.0
    seed: int = 0


class WANTransport:
    """Applies loss/duplication/reordering to a packet sequence.

    ``last_delivery`` exposes per-output-row bookkeeping from the most recent
    call — ``(src_index, is_dup)`` arrays aligned with the delivered order —
    so tests can assert the duplicate-follows-original constraint directly.
    """

    def __init__(self, cfg: TransportConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.n_lost = 0
        self.n_dup = 0
        self._window = 0
        self.last_delivery: tuple[np.ndarray, np.ndarray] | None = None

    # -- batched path (one vectorized pass per window) ------------------------
    def deliver_batch(self, batch: PacketBatch) -> PacketBatch:
        """Loss mask + duplicate copy + jitter-keyed permutation, one pass."""
        import jax
        import jax.numpy as jnp

        n = len(batch)
        if n == 0:
            self.last_delivery = (np.empty((0,), np.int64),
                                  np.zeros((0,), bool))
            return batch
        key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed),
                                 self._window)
        self._window += 1
        k_loss, k_dup, k_jit, k_extra = jax.random.split(key, 4)
        keep = np.asarray(
            jax.random.uniform(k_loss, (n,)) >= self.cfg.loss_prob)
        dup = keep & np.asarray(
            jax.random.uniform(k_dup, (n,)) < self.cfg.duplicate_prob)
        w = float(max(self.cfg.reorder_window, 0))
        idx = jnp.arange(n, dtype=jnp.float32)
        jitter = jax.random.uniform(k_jit, (n,), minval=0.0, maxval=w) if w else 0.0
        extra = jax.random.uniform(k_extra, (n,), minval=0.0, maxval=w) if w else 0.0
        key_orig = np.asarray(idx + jitter, np.float64)
        key_dup = np.asarray(idx + jitter + extra, np.float64)

        self.n_lost += int((~keep).sum())
        self.n_dup += int(dup.sum())
        src = np.concatenate([np.flatnonzero(keep), np.flatnonzero(dup)])
        is_dup = np.concatenate(
            [np.zeros(int(keep.sum()), bool), np.ones(int(dup.sum()), bool)])
        keys = np.concatenate([key_orig[keep], key_dup[dup]])
        # lexsort: primary = delay key, tie-break originals before duplicates.
        order = np.lexsort((is_dup, keys))
        self.last_delivery = (src[order], is_dup[order])
        return batch.take(src[order])

    # -- per-packet reference path --------------------------------------------
    def deliver(self, packets: list) -> list:
        out_src, out_dup = [], []
        for i, _p in enumerate(packets):
            if self.rng.random() < self.cfg.loss_prob:
                self.n_lost += 1
                continue
            out_src.append(i)
            out_dup.append(False)
            if self.rng.random() < self.cfg.duplicate_prob:
                out_src.append(i)
                out_dup.append(True)
                self.n_dup += 1
        src = np.asarray(out_src, np.int64)
        is_dup = np.asarray(out_dup, bool)
        keys = src.astype(np.float64)
        if len(src) > 1 and self.cfg.reorder_window > 0:
            # bounded displacement: sort by (index + jitter); a duplicate's
            # key adds a non-negative extra delay on top of its original's.
            jitter = self.rng.uniform(0, self.cfg.reorder_window, len(packets))
            extra = self.rng.uniform(0, self.cfg.reorder_window, len(packets))
            keys = src + jitter[src] + np.where(is_dup, extra[src], 0.0)
        order = np.lexsort((is_dup, keys))
        self.last_delivery = (src[order], is_dup[order])
        return [packets[i] for i in src[order]]
