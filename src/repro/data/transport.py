"""UDP-over-WAN simulation: serialization, random path delay, reordering and
loss injection (paper fig. 7b shows exactly this at the LB input: "packet
serialization and random path delays are built into the traffic generator").
Unidirectional, no backpressure, no retransmit (paper §I-B.6).

Both delivery paths draw from the SAME per-window ``jax.random`` stream
(``_draw_window``: one ``fold_in`` per window, loss as one mask, duplication
as a masked row copy, reordering as a single jitter-keyed permutation).
``deliver_batch`` applies the plan to a ``PacketBatch`` with one row gather;
``deliver`` applies the identical plan to a per-packet list — so under the
same seed and window sequence the two paths produce the same delivery order,
``n_lost``/``n_dup`` counters and ``last_delivery`` bookkeeping (asserted by
tests/test_ingest.py). Historically ``deliver`` drew from an independent
``np.random`` stream and the two paths could silently diverge.

Duplicate ordering: a duplicate models the *same* serialized packet taking a
second (never earlier) path, so its sort key is the original's key plus a
strictly non-negative extra delay — a duplicate can never overtake the first
copy (ties break original-first). The old implementation drew an independent
jitter for the duplicate, which could deliver the copy *before* its original
and effectively doubled the reorder window for duplicated packets.

This positional model is the zero-rate degenerate case of the virtual-time
link model in ``repro.simnet.links``: with no serialization (rate=0), no
propagation delay and unit-spaced emissions, a link's arrival times reduce to
``index + jitter`` — exactly the keys below (property-tested equivalent in
tests/test_simnet.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np

from repro.data.segmentation import PacketBatch


@dataclasses.dataclass
class TransportConfig:
    reorder_window: int = 32      # max positions a packet can be displaced
    loss_prob: float = 0.0
    duplicate_prob: float = 0.0
    seed: int = 0


@functools.partial(jax.jit, static_argnames=("m",))
def _uniform_block_jit(seed, window, *, m: int):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), window)
    return jax.random.uniform(key, (4, m), dtype=jax.numpy.float32)


def _uniform_block(seed: int, window: int, m: int) -> np.ndarray:
    """``float64[4, m]`` uniforms in [0, 1) for one window — one jitted
    device call (fold_in + split + draws fused); ``m`` is padded to a power
    of two by the caller so the jit cache stays bounded."""
    return np.asarray(_uniform_block_jit(seed, window, m=m), np.float64)


def draw_window(seed: int, window: int, n: int, *, loss_prob: float,
                duplicate_prob: float, jitter_scale: float):
    """The per-window randomness both delivery paths (and the simnet link
    model) share: one fold_in per window, then a loss mask, a duplicate
    mask (only surviving packets can duplicate) and two non-negative delay
    draws in ``[0, jitter_scale)`` — ``jitter`` delays the original copy,
    ``extra`` is the duplicate's additional (never negative) path delay.

    Returns host arrays ``(keep, dup, jitter, extra)``.
    """
    from repro.data.segmentation import next_pow2

    u = _uniform_block(seed, window, next_pow2(n))[:, :n]
    keep = u[0] >= loss_prob
    dup = keep & (u[1] < duplicate_prob)
    w = float(max(jitter_scale, 0.0))
    jitter = u[2] * w
    extra = u[3] * w
    return keep, dup, jitter, extra


def delivery_order(keep: np.ndarray, dup: np.ndarray, key_orig: np.ndarray,
                   key_dup: np.ndarray):
    """Assemble one window's delivery plan from masks + delay keys.

    Surviving originals and duplicate copies are concatenated and sorted by
    delay key with originals winning ties — the one implementation of the
    duplicate-never-overtakes-its-original rule, shared by ``WANTransport``
    and the simnet ``Link`` (whose keys are arrival *times* instead of
    positions). Returns ``(src, is_dup, keys)`` in delivery order.
    """
    src = np.concatenate([np.flatnonzero(keep), np.flatnonzero(dup)])
    is_dup = np.concatenate(
        [np.zeros(int(keep.sum()), bool), np.ones(int(dup.sum()), bool)])
    keys = np.concatenate([key_orig[keep], key_dup[dup]])
    order = np.lexsort((is_dup, keys))
    return src[order], is_dup[order], keys[order]


class WANTransport:
    """Applies loss/duplication/reordering to a packet sequence.

    ``last_delivery`` exposes per-output-row bookkeeping from the most recent
    call — ``(src_index, is_dup)`` arrays aligned with the delivered order —
    so tests can assert the duplicate-follows-original constraint directly.
    """

    def __init__(self, cfg: TransportConfig):
        self.cfg = cfg
        self.n_lost = 0
        self.n_dup = 0
        self._window = 0
        self.last_delivery: tuple[np.ndarray, np.ndarray] | None = None

    def _plan(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """One window's delivery plan: ``(src, is_dup)`` in delivery order.
        Shared by both paths; advances the window counter and the counters."""
        keep, dup, jitter, extra = draw_window(
            self.cfg.seed, self._window, n,
            loss_prob=self.cfg.loss_prob,
            duplicate_prob=self.cfg.duplicate_prob,
            jitter_scale=self.cfg.reorder_window)
        self._window += 1
        idx = np.arange(n, dtype=np.float64)
        key_orig = idx + jitter

        self.n_lost += int((~keep).sum())
        self.n_dup += int(dup.sum())
        src, is_dup, _keys = delivery_order(keep, dup, key_orig,
                                            key_orig + extra)
        self.last_delivery = (src, is_dup)
        return self.last_delivery

    # -- batched path (one vectorized pass per window) ------------------------
    def deliver_batch(self, batch: PacketBatch) -> PacketBatch:
        """Loss mask + duplicate copy + jitter-keyed permutation, one pass."""
        n = len(batch)
        if n == 0:
            self.last_delivery = (np.empty((0,), np.int64),
                                  np.zeros((0,), bool))
            return batch
        src, _ = self._plan(n)
        return batch.take(src)

    # -- per-packet reference path --------------------------------------------
    def deliver(self, packets: list) -> list:
        """List form of the identical plan (reference pipeline and tests)."""
        if not packets:
            self.last_delivery = (np.empty((0,), np.int64),
                                  np.zeros((0,), bool))
            return []
        src, _ = self._plan(len(packets))
        return [packets[i] for i in src]
