"""End-to-end data pipeline: DAQs -> segmentation -> WAN transport -> LB
route -> per-member receive lanes -> reassembly -> training batches.

This is the host-side of the system (what runs on CN ingest daemons); the
device-side ingest (all_to_all redistribution inside train_step) consumes
the batches this pipeline emits. Every stage is batched (DESIGN.md §Ingest):
one vectorized segmentation pass per trigger window (``segment_bundles``),
one masked-permutation WAN pass (``WANTransport.deliver_batch``), one
``DataPlane.route`` device call, and one sort-based reassembly plan per
receive lane (``BatchReassembler``) — no per-packet Python loop anywhere.
The pipeline is also the test harness for the paper's fig. 7 experiments
(benchmarks/).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

from repro.core.dataplane import DataPlane, DataPlaneCache
from repro.core.epoch import EpochManager
from repro.data.daq import DAQConfig, DAQFleet
from repro.data.reassembly import BatchReassembler, ReassemblyStats
from repro.data.segmentation import (
    DEFAULT_MTU_PAYLOAD,
    PacketBatch,
    group_rows,
    segment_bundles,
)
from repro.data.transport import TransportConfig, WANTransport


@dataclasses.dataclass
class PipelineStats:
    n_packets: int = 0
    n_routed: int = 0
    n_discarded: int = 0
    per_member: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    per_lane: dict = dataclasses.field(default_factory=lambda: defaultdict(int))


class StreamingPipeline:
    """Drives DAQ traffic through the LB into per-member reassembly lanes."""

    def __init__(self, daq_cfg: DAQConfig, transport_cfg: TransportConfig,
                 manager: EpochManager, backend: str = "auto",
                 mtu_payload: int = DEFAULT_MTU_PAYLOAD,
                 reassembly_timeout_windows: int | None = None):
        self.fleet = DAQFleet(daq_cfg)
        self.wan = WANTransport(transport_cfg)
        self.manager = manager
        self.backend = backend
        self.mtu_payload = mtu_payload
        self._timeout = reassembly_timeout_windows
        # lane-indexed batched reassemblers per member (entropy RSS lanes)
        self.lanes: dict[tuple[int, int], BatchReassembler] = {}
        self.stats = PipelineStats()
        self.routed_log: list[tuple[int, int, int]] = []  # (event, member, lane)
        self._dp_cache = DataPlaneCache(manager, backend=backend)

    def _dataplane(self) -> DataPlane:
        """Tables recompile only after the epoch state changes (audit-log
        watermark), not once per arrival window."""
        return self._dp_cache.get()

    def _lane(self, member: int, lane: int) -> BatchReassembler:
        key = (member, lane)
        if key not in self.lanes:
            self.lanes[key] = self._dataplane().make_reassembler(
                mtu_payload=self.mtu_payload, timeout_windows=self._timeout)
        return self.lanes[key]

    def _route_batch(self, batch: PacketBatch):
        """One batched DataPlane call for the whole arrival window."""
        return self._dataplane().route_window(batch)

    def pump(self, n_triggers: int) -> list[np.ndarray]:
        """Run n triggers end to end; returns completed bundle payloads."""
        bundles = self.fleet.bundle_window(n_triggers)
        batch = segment_bundles(bundles, self.mtu_payload)
        arrived = self.wan.deliver_batch(batch)
        if len(arrived) == 0:
            return []
        member, _node, lane, valid = self._route_batch(arrived)
        ok = valid.astype(bool)
        self.stats.n_packets += len(arrived)
        self.stats.n_discarded += int((~ok).sum())
        self.stats.n_routed += int(ok.sum())
        rows_ok = np.flatnonzero(ok)
        mm, ll = member[rows_ok], lane[rows_ok]
        self.routed_log.extend(
            zip(arrived.event_number[rows_ok].tolist(), mm.tolist(),
                ll.tolist()))
        if not len(rows_ok):
            return []
        pairs, groups = group_rows(np.stack([mm, ll], axis=1))
        done = []
        for (m, l), grp in zip(pairs.tolist(), groups):
            self.stats.per_member[m] += len(grp)
            self.stats.per_lane[(m, l)] += len(grp)
            done.extend(self._lane(m, l).push_batch(arrived.take(rows_ok[grp])))
        return done

    def event_member_map(self) -> dict[int, set[int]]:
        """event number -> set of members that received any of its packets.
        The paper's atomicity invariant: every set has size 1."""
        out: dict[int, set[int]] = defaultdict(set)
        for ev, m, _l in self.routed_log:
            out[ev].add(m)
        return out

    # -- ingest telemetry (feeds the control plane) ---------------------------
    def ingest_backlog(self) -> dict[int, int]:
        """Per-member incomplete-buffer backlog across its receive lanes."""
        out: dict[int, int] = defaultdict(int)
        for (m, _l), ra in self.lanes.items():
            out[m] += ra.n_incomplete
        return dict(out)

    def reassembly_stats(self) -> ReassemblyStats:
        """Aggregated loss/timeout/duplicate accounting over all lanes."""
        agg = ReassemblyStats()
        for ra in self.lanes.values():
            s = ra.stats
            agg.n_pushed += s.n_pushed
            agg.n_duplicate += s.n_duplicate
            agg.n_completed += s.n_completed
            agg.n_timed_out_groups += s.n_timed_out_groups
            agg.n_timed_out_segments += s.n_timed_out_segments
        return agg


def batches_from_bundles(payloads: list[np.ndarray], seq_len: int,
                         batch_size: int) -> list[np.ndarray]:
    """Decode token payloads (first seq_len*4 bytes) into [B, T] batches."""
    toks = []
    for p in payloads:
        t = np.frombuffer(p[: seq_len * 4].tobytes(), "<i4")
        if len(t) == seq_len:
            toks.append(t)
    out = []
    for i in range(0, len(toks) - batch_size + 1, batch_size):
        out.append(np.stack(toks[i : i + batch_size]))
    return out
