"""End-to-end data pipeline: DAQs -> segmentation -> WAN transport -> LB
route -> per-member receive lanes -> reassembly -> training batches.

This is the host-side of the system (what runs on CN ingest daemons); the
device-side ingest (all_to_all redistribution inside train_step) consumes
the batches this pipeline emits. The pipeline is also the test harness for
the paper's fig. 7 experiments (benchmarks/).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

from repro.core.dataplane import DataPlane
from repro.core.epoch import EpochManager
from repro.data.daq import DAQConfig, DAQFleet
from repro.data.segmentation import Reassembler, Segment, segment_bundle
from repro.data.transport import TransportConfig, WANTransport


@dataclasses.dataclass
class PipelineStats:
    n_packets: int = 0
    n_routed: int = 0
    n_discarded: int = 0
    per_member: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    per_lane: dict = dataclasses.field(default_factory=lambda: defaultdict(int))


class StreamingPipeline:
    """Drives DAQ traffic through the LB into per-member reassembly lanes."""

    def __init__(self, daq_cfg: DAQConfig, transport_cfg: TransportConfig,
                 manager: EpochManager, backend: str = "auto"):
        self.fleet = DAQFleet(daq_cfg)
        self.wan = WANTransport(transport_cfg)
        self.manager = manager
        self.backend = backend
        # lane-indexed reassemblers per member (entropy RSS lanes)
        self.lanes: dict[tuple[int, int], Reassembler] = defaultdict(Reassembler)
        self.stats = PipelineStats()
        self.routed_log: list[tuple[int, int, int]] = []  # (event, member, lane)
        self._dp: DataPlane | None = None
        self._dp_version = -1

    def _dataplane(self) -> DataPlane:
        """Tables recompile only after the epoch state changes (audit-log
        watermark), not once per arrival window."""
        version = len(self.manager.audit)
        if self._dp is None or version != self._dp_version:
            self._dp = DataPlane.from_manager(self.manager, backend=self.backend)
            self._dp_version = version
        return self._dp

    def _route_batch(self, segments: list[Segment]):
        """One batched DataPlane call for the whole arrival window."""
        import jax.numpy as jnp
        words = jnp.asarray(np.stack([s.lb_words for s in segments]))
        r = self._dataplane().route(words)
        return (np.asarray(r.member), np.asarray(r.node),
                np.asarray(r.lane), np.asarray(r.valid))

    def pump(self, n_triggers: int) -> list[np.ndarray]:
        """Run n triggers end to end; returns completed bundle payloads."""
        segments: list[Segment] = []
        for bundles in self.fleet.stream(n_triggers):
            for b in bundles:
                segments.extend(segment_bundle(b))
        arrived = self.wan.deliver(segments)
        if not arrived:
            return []
        member, node, lane, valid = self._route_batch(arrived)
        done = []
        for seg, m, l, ok in zip(arrived, member, lane, valid):
            self.stats.n_packets += 1
            if not ok:
                self.stats.n_discarded += 1
                continue
            self.stats.n_routed += 1
            self.stats.per_member[int(m)] += 1
            self.stats.per_lane[(int(m), int(l))] += 1
            self.routed_log.append((seg.event_number, int(m), int(l)))
            got = self.lanes[(int(m), int(l))].push(seg)
            if got is not None:
                done.append(got)
        return done

    def event_member_map(self) -> dict[int, set[int]]:
        """event number -> set of members that received any of its packets.
        The paper's atomicity invariant: every set has size 1."""
        out: dict[int, set[int]] = defaultdict(set)
        for ev, m, _l in self.routed_log:
            out[ev].add(m)
        return out


def batches_from_bundles(payloads: list[np.ndarray], seq_len: int,
                         batch_size: int) -> list[np.ndarray]:
    """Decode token payloads (first seq_len*4 bytes) into [B, T] batches."""
    toks = []
    for p in payloads:
        t = np.frombuffer(p[: seq_len * 4].tobytes(), "<i4")
        if len(t) == seq_len:
            toks.append(t)
    out = []
    for i in range(0, len(toks) - batch_size + 1, batch_size):
        out.append(np.stack(toks[i : i + batch_size]))
    return out
