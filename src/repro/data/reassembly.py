"""Batched CN-side reassembly: sort-based completion detection (paper §II-C).

The per-packet reference (`data/segmentation.Reassembler`) fills a dict
buffer per ``(event_number, daq_id)`` — one Python dict op per segment. The
batched path mirrors PR 1's dispatch algorithm instead: the whole arrival
window is key-sorted on ``(event_hi, event_lo, daq_id, seg_index, arrival)``
with one multi-operand ``lax.sort``; group boundaries and duplicates fall out
of a previous-row comparison on the sorted columns (jnp reference or the
Pallas kernel ``kernels/reassembly.seg_masks``); per-group unique-segment
counts come from one segment-scatter, and a group is complete iff its unique
count equals its ``n_segs``. O(N log N) work, no per-packet host loop.

``BatchReassembler`` carries incomplete groups across windows (loss shows up
as pending buffers), ages them, and times them out after
``timeout_windows`` — every loss/timeout/duplicate is *accounted*, never a
corrupt bundle. The backlog (``n_incomplete``) feeds the control plane via
``telemetry.metrics.TelemetryHub.report_ingest``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import split64
from repro.data.segmentation import (
    DEFAULT_MTU_PAYLOAD,
    PacketBatch,
    next_pow2 as _next_pow2,
)


def reassembly_plan_np(ev_hi, ev_lo, daq, seg_index, n_segs):
    """Host (numpy) form of ``reassembly_plan`` — same sort-based algorithm,
    no padding (host arrays are dynamically shaped). The CN reassembly daemon
    is a host component in the paper (the LB does not participate in
    reassembly), so this is ``BatchReassembler``'s default engine; the jnp /
    Pallas form exists for device-resident ingest and is property-tested
    equal (tests/test_ingest.py). Returns the same fields in sorted order.
    """
    n = len(ev_hi)
    # np.lexsort is stable: arrival order breaks ties, so the first copy of
    # a duplicated segment stays first (as in the jnp form's arrival key).
    order = np.lexsort((seg_index, daq, ev_lo, ev_hi))
    s_hi, s_lo = ev_hi[order], ev_lo[order]
    s_daq, s_seg = daq[order], seg_index[order]
    same = np.zeros((n,), bool)
    same[1:] = ((s_hi[1:] == s_hi[:-1]) & (s_lo[1:] == s_lo[:-1])
                & (s_daq[1:] == s_daq[:-1]))
    new_group = ~same
    dup = np.zeros((n,), bool)
    dup[1:] = same[1:] & (s_seg[1:] == s_seg[:-1])
    unique = ~dup
    gid = np.cumsum(new_group) - 1
    counts = np.bincount(gid[unique], minlength=int(gid[-1]) + 1 if n else 0)
    gsegs = n_segs[order][new_group]  # each group's first row
    complete = (counts == gsegs)[gid]
    return {
        "perm": order.astype(np.int32), "new_group": new_group, "dup": dup,
        "unique": unique, "complete": complete, "group_id": gid,
        "n_groups": int(new_group.sum()),
    }


@functools.partial(jax.jit, static_argnames=("backend", "interpret"))
def reassembly_plan(ev_hi, ev_lo, daq, seg_index, n_segs, valid, *,
                    backend: str = "jnp", interpret: bool = True):
    """The device-side reassembly program over one (padded) window.

    All inputs are [N] columns; ``valid`` masks padding rows. Returns a dict
    of [N] arrays *in sorted order* plus the sort permutation:

      perm       int32: original row index of each sorted slot
      new_group  int32: 1 at each group's first sorted row
      dup        int32: 1 on duplicate rows (same (event, daq, seg) as prev)
      unique     bool : valid and not duplicate
      complete   bool : row belongs to a group whose unique count == n_segs
      group_id   int32: dense group index (valid rows; padding rows clamp)
      n_groups   int32 scalar
    """
    n = ev_hi.shape[0]
    arrival = jnp.arange(n, dtype=jnp.int32)
    inval = (~valid).astype(jnp.uint32)  # invalid rows sort last
    s_inval, s_hi, s_lo, s_daq, s_seg, s_arr, s_nsegs = jax.lax.sort(
        (inval, ev_hi.astype(jnp.uint32), ev_lo.astype(jnp.uint32),
         daq.astype(jnp.uint32), seg_index.astype(jnp.uint32),
         arrival, n_segs.astype(jnp.int32)),
        num_keys=6,
    )
    s_valid = (s_inval == 0).astype(jnp.uint32)
    if backend == "pallas":
        from repro.kernels import reassembly as _k

        new_group, dup = _k.seg_masks(s_valid, s_hi, s_lo, s_daq, s_seg,
                                      interpret=interpret)
    else:
        from repro.kernels import ref as _ref

        new_group, dup = _ref.seg_masks_ref(s_valid, s_hi, s_lo, s_daq, s_seg)
    ok = s_valid > 0
    unique = ok & (dup == 0)
    gid = jnp.cumsum(new_group) - 1  # dense group id along sorted order
    gid_c = jnp.clip(gid, 0, n - 1)
    # Per-group unique-segment counts + expected size, one scatter each
    # (padding/duplicate rows are routed to a spill slot at index n).
    counts = jnp.zeros((n + 1,), jnp.int32).at[
        jnp.where(unique, gid_c, n)].add(1)
    # Expected size = the group's *first* row's n_segs (same definition as
    # the host plan; only group-start rows contribute to the scatter).
    gsegs = jnp.zeros((n + 1,), jnp.int32).at[
        jnp.where(ok & (new_group > 0), gid_c, n)].max(s_nsegs)
    complete_g = (counts[:n] > 0) & (counts[:n] == gsegs[:n])
    complete = ok & complete_g[gid_c]
    return {
        "perm": s_arr, "new_group": new_group, "dup": dup, "unique": unique,
        "complete": complete, "group_id": gid_c,
        "n_groups": jnp.sum(new_group),
    }


@dataclasses.dataclass
class ReassemblyStats:
    n_pushed: int = 0            # segments seen (incl. duplicates)
    n_duplicate: int = 0
    n_completed: int = 0         # bundles assembled
    n_timed_out_groups: int = 0
    n_timed_out_segments: int = 0


class BatchReassembler:
    """Stateful window-at-a-time reassembler over ``PacketBatch`` columns.

    ``push_batch`` merges the window with carried-over incomplete segments,
    runs the plan once, assembles every completed bundle with one gather over
    the payload matrix, and retains the rest with an age bump. A group whose
    newest segment has waited more than ``timeout_windows`` pushes (no
    activity) is dropped whole and accounted once.

    ``backend``: "np" (default — the CN daemon is a host component; numpy
    lexsort form), "jnp" or "pallas" (the device plan, padded to a power of
    two so the jit cache stays small; property-tested equal to "np").
    """

    def __init__(self, mtu_payload: int = DEFAULT_MTU_PAYLOAD,
                 timeout_windows: Optional[int] = None,
                 backend: str = "np", interpret: bool = True):
        self.pending = PacketBatch.empty(mtu_payload)
        self.pending_age = np.empty((0,), np.int32)
        self.timeout_windows = timeout_windows
        self.backend = backend
        self.interpret = interpret
        self.stats = ReassemblyStats()
        self.completed: list[tuple[tuple[int, int], np.ndarray]] = []
        # (event, daq) keys expired by the most recent push (empty when none)
        # — callers tracking per-bundle state (simnet's emit-time table) use
        # this to purge entries that will never complete.
        self.last_timed_out_keys: list[tuple[int, int]] = []

    # -- accounting -----------------------------------------------------------
    @property
    def n_incomplete(self) -> int:
        """Distinct (event, daq) groups currently buffered (the backlog)."""
        if len(self.pending) == 0:
            return 0
        keys = np.stack([self.pending.event_number.astype(np.uint64),
                         self.pending.daq_id.astype(np.uint64)], axis=1)
        return int(np.unique(keys, axis=0).shape[0])

    @property
    def n_duplicate(self) -> int:
        return self.stats.n_duplicate

    def drain_completed(self):
        out, self.completed = self.completed, []
        return out

    # -- the batched push -----------------------------------------------------
    def push_batch(self, batch: PacketBatch) -> list[np.ndarray]:
        """Ingest one arrival window; returns payloads completed by it."""
        self.last_timed_out_keys = []
        self.stats.n_pushed += len(batch)
        merged = PacketBatch.concat([self.pending, batch])
        ages = np.concatenate(
            [self.pending_age, np.zeros((len(batch),), np.int32)])
        n = len(merged)
        if n == 0:
            return []
        hi, lo = split64(merged.event_number)
        if self.backend == "np":
            plan = reassembly_plan_np(hi, lo, merged.daq_id,
                                      merged.seg_index, merged.n_segs)
            perm = plan["perm"]
            unique = plan["unique"]
            dup = plan["dup"]
            complete = plan["complete"]
            new_group = plan["new_group"]
        else:
            n_pad = _next_pow2(n)

            def pad(x, dtype):
                out = np.zeros((n_pad,), dtype)
                out[:n] = x
                return jnp.asarray(out)

            valid = np.zeros((n_pad,), bool)
            valid[:n] = True
            plan = reassembly_plan(
                pad(hi, np.uint32), pad(lo, np.uint32),
                pad(merged.daq_id, np.int32), pad(merged.seg_index, np.int32),
                pad(merged.n_segs, np.int32), jnp.asarray(valid),
                backend=self.backend, interpret=self.interpret)
            perm = np.asarray(plan["perm"])
            unique = np.asarray(plan["unique"])
            dup = np.asarray(plan["dup"]) > 0
            complete = np.asarray(plan["complete"])
            new_group = np.asarray(plan["new_group"]) > 0
        group_id = np.asarray(plan["group_id"])
        self.stats.n_duplicate += int(dup.sum())

        done = self._assemble(merged, perm, unique, complete, new_group)

        # Retain incomplete survivors (unique, not complete), age them, and
        # expire groups by *activity*: a group times out only when even its
        # newest segment has waited longer than the window, and then the
        # whole group leaves at once — a group is never split across the
        # timeout boundary or counted twice.
        keep_sorted = unique & ~complete
        rows = perm[keep_sorted]
        self.pending = merged.take(rows)
        self.pending_age = ages[rows] + 1
        if self.timeout_windows is not None and len(self.pending):
            _, gid = np.unique(group_id[keep_sorted], return_inverse=True)
            gmin = np.full((int(gid.max()) + 1,), np.iinfo(np.int32).max)
            np.minimum.at(gmin, gid, self.pending_age)
            expired = gmin[gid] > self.timeout_windows
            if expired.any():
                self.stats.n_timed_out_groups += int(
                    (gmin > self.timeout_windows).sum())
                self.stats.n_timed_out_segments += int(expired.sum())
                rows_exp = np.flatnonzero(expired)
                keys = np.unique(np.stack(
                    [self.pending.event_number[rows_exp].astype(np.uint64),
                     self.pending.daq_id[rows_exp].astype(np.uint64)],
                    axis=1), axis=0)
                self.last_timed_out_keys = [
                    (int(e), int(d)) for e, d in keys.tolist()]
                live = np.flatnonzero(~expired)
                self.pending = self.pending.take(live)
                self.pending_age = self.pending_age[live]
        return done

    def _assemble(self, merged: PacketBatch, perm, unique, complete,
                  new_group) -> list[np.ndarray]:
        """Gather every completed group's bytes in (group, seg) order."""
        sel = unique & complete  # sorted rows of complete groups
        if not sel.any():
            return []
        rows = perm[sel]                       # original rows, in (group, seg) order
        lens = merged.payload_len[rows].astype(np.int64)
        mtu = merged.mtu_payload
        if int(lens.min(initial=mtu)) == mtu:
            if np.array_equal(rows, np.arange(len(rows))):
                flat = merged.payload.reshape(-1)  # in-order window: zero copy
            else:
                flat = merged.payload[rows].reshape(-1)
        else:
            # Piecewise concatenate: full-row runs flatten as-is, the (rare)
            # partial rows are trimmed — no per-byte boolean mask.
            gathered = merged.payload[rows]
            pieces, prev = [], 0
            for p in np.flatnonzero(lens < mtu):
                if p > prev:
                    pieces.append(gathered[prev:p].reshape(-1))
                pieces.append(gathered[p, : lens[p]])
                prev = int(p) + 1
            if prev < len(rows):
                pieces.append(gathered[prev:].reshape(-1))
            flat = np.concatenate(pieces)
        starts = new_group[sel]                # group boundary within selection
        byte_off = np.concatenate([[0], np.cumsum(lens)])
        bounds = byte_off[
            np.concatenate([np.flatnonzero(starts), [len(rows)]])]
        first_rows = rows[starts]
        keys = list(zip(merged.event_number[first_rows].tolist(),
                        merged.daq_id[first_rows].tolist()))
        done = [flat[bounds[g] : bounds[g + 1]] for g in range(len(keys))]
        self.completed.extend(zip(keys, done))
        self.stats.n_completed += len(done)
        return done
