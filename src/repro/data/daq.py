"""Synthetic DAQ event sources.

Models the paper's traffic: several DAQs observing the same triggers emit
Event Data Bundles tagged with a *common*, monotonically increasing Event
Number (hardware-trigger-synchronized, §II-A: "a common method to assign an
Event Number is to use the high resolution timestamp from the DAQ trigger").
Payloads here are token sequences (the framework trains LMs on the streamed
events), with per-DAQ variable bundle sizes as in fig. 7a.

Event numbers advance by a random stride (timestamp-like) while keeping the
9 LSBs uniform — the paper's requirement for statistically even balancing.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class EventBundle:
    event_number: int
    daq_id: int
    entropy: int
    payload: np.ndarray  # uint8 bytes (serialized tokens)


@dataclasses.dataclass
class DAQConfig:
    n_daqs: int = 5
    seq_len: int = 128
    vocab: int = 256
    mean_bundle_bytes: int = 24_000  # > 9KB MTU => multiple segments
    seed: int = 0
    timestamp_stride: tuple[int, int] = (1, 7)  # uniform stride range


class DAQFleet:
    """Generates per-trigger bundles from all DAQs (synchronized numbers)."""

    def __init__(self, cfg: DAQConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.event_number = int(self.rng.integers(1, 1 << 20))

    def tokens_for_event(self, event_number: int) -> np.ndarray:
        r = np.random.default_rng(event_number)  # reproducible per event
        return r.integers(0, self.cfg.vocab, self.cfg.seq_len).astype(np.int32)

    def next_trigger(self) -> list[EventBundle]:
        """One hardware trigger: every DAQ emits a bundle for this event."""
        ev = self.event_number
        lo, hi = self.cfg.timestamp_stride
        self.event_number += int(self.rng.integers(lo, hi + 1))
        entropy = int(self.rng.integers(0, 1 << 16))
        tokens = self.tokens_for_event(ev)
        out = []
        for d in range(self.cfg.n_daqs):
            nbytes = int(self.rng.normal(self.cfg.mean_bundle_bytes,
                                         self.cfg.mean_bundle_bytes / 8))
            nbytes = max(1024, nbytes)
            r = np.random.default_rng((ev << 3) ^ d)
            payload = r.integers(0, 256, nbytes).astype(np.uint8)
            # First bytes carry the token payload so CN-side reassembly can
            # rebuild the training sample.
            tok_bytes = tokens.astype("<i4").tobytes()
            payload[: len(tok_bytes)] = np.frombuffer(tok_bytes, np.uint8)
            out.append(EventBundle(ev, d, entropy, payload))
        return out

    def stream(self, n_triggers: int) -> Iterator[list[EventBundle]]:
        for _ in range(n_triggers):
            yield self.next_trigger()

    def bundle_window(self, n_triggers: int) -> list[EventBundle]:
        """One ingest window: all bundles of ``n_triggers`` triggers, flat —
        the unit the batched segmentation pass (``segment_bundles``) and the
        WAN ``deliver_batch`` consume (DESIGN.md §Ingest)."""
        return [b for bs in self.stream(n_triggers) for b in bs]
