"""Synthetic DAQ event sources.

Models the paper's traffic: several DAQs observing the same triggers emit
Event Data Bundles tagged with a *common*, monotonically increasing Event
Number (hardware-trigger-synchronized, §II-A: "a common method to assign an
Event Number is to use the high resolution timestamp from the DAQ trigger").
Payloads here are token sequences (the framework trains LMs on the streamed
events), with per-DAQ variable bundle sizes as in fig. 7a.

Event numbers advance by a random stride (timestamp-like) while keeping the
9 LSBs uniform — the paper's requirement for statistically even balancing.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class EventBundle:
    event_number: int
    daq_id: int
    entropy: int
    payload: np.ndarray  # uint8 bytes (serialized tokens)


@dataclasses.dataclass
class DAQConfig:
    n_daqs: int = 5
    seq_len: int = 128
    vocab: int = 256
    mean_bundle_bytes: int = 24_000  # > 9KB MTU => multiple segments
    seed: int = 0
    timestamp_stride: tuple[int, int] = (1, 7)  # uniform stride range
    # Prefix payloads with the event's reproducible token sample (the LM
    # training flow decodes it). Traffic-only consumers (simnet) turn it
    # off — the per-event token RNG is the one per-trigger host cost.
    token_payload: bool = True


class DAQFleet:
    """Generates per-trigger bundles from all DAQs (synchronized numbers)."""

    def __init__(self, cfg: DAQConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.event_number = int(self.rng.integers(1, 1 << 20))

    def tokens_for_event(self, event_number: int) -> np.ndarray:
        r = np.random.default_rng(event_number)  # reproducible per event
        return r.integers(0, self.cfg.vocab, self.cfg.seq_len).astype(np.int32)

    def next_trigger(self) -> list[EventBundle]:
        """One hardware trigger: every DAQ emits a bundle for this event."""
        return self.bundle_window(1)

    def stream(self, n_triggers: int) -> Iterator[list[EventBundle]]:
        for _ in range(n_triggers):
            yield self.next_trigger()

    def bundle_window(self, n_triggers: int) -> list[EventBundle]:
        """One ingest window: all bundles of ``n_triggers`` triggers, flat —
        the unit the batched segmentation pass (``segment_bundles``) and the
        WAN ``deliver_batch`` consume (DESIGN.md §Ingest).

        Draws the whole window in one pass (strides, entropies, sizes, one
        payload blob); per-bundle work is an ``EventBundle`` wrapper around a
        blob slice, so traffic generation keeps up with the vectorized
        ingest path and the virtual-time simulator.
        """
        cfg = self.cfg
        t, d = n_triggers, cfg.n_daqs
        if t <= 0:
            return []
        lo, hi = cfg.timestamp_stride
        strides = self.rng.integers(lo, hi + 1, t)
        evs = self.event_number + np.concatenate(
            [[0], np.cumsum(strides[:-1])])
        self.event_number = int(self.event_number + strides.sum())
        ents = self.rng.integers(0, 1 << 16, t)
        nbytes = np.maximum(1024, self.rng.normal(
            cfg.mean_bundle_bytes, cfg.mean_bundle_bytes / 8,
            (t, d)).astype(np.int64))
        blob = self.rng.integers(0, 256, int(nbytes.sum()), dtype=np.uint8)
        bounds = np.concatenate([[0], np.cumsum(nbytes.reshape(-1))])
        out = []
        for k in range(t):
            tok_bytes = None
            if cfg.token_payload:
                tokens = self.tokens_for_event(int(evs[k]))
                tok_bytes = np.frombuffer(tokens.astype("<i4").tobytes(),
                                          np.uint8)
            for q in range(d):
                payload = blob[bounds[k * d + q]: bounds[k * d + q + 1]]
                if tok_bytes is not None:
                    # First bytes carry the token payload so CN-side
                    # reassembly can rebuild the training sample.
                    payload[: len(tok_bytes)] = tok_bytes
                out.append(EventBundle(int(evs[k]), q, int(ents[k]), payload))
        return out
