"""Application-layer segmentation & reassembly protocol (paper §II-C).

"A dedicated, application layer segmentation and reassembly protocol is
required. This protocol runs between the DAQ and the compute node. The load
balancer does not participate." Each segment carries the LB header (same
Event Number + same Entropy for all segments of a bundle => same CN, same
receive lane) plus an opaque-to-the-LB segmentation header:

    seg_hdr = (daq_id u16, seg_index u16, n_segs u16, payload_len u16)

Reassembly is stateless per (event, daq): a buffer keyed by
(event_number, daq_id) fills as segments arrive in any order; completion is
detected by count. Losses surface as incomplete buffers (accounted + timed
out), never as corrupt bundles.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.protocol import MAX_SEGMENT_PAYLOAD, encode_headers
from repro.data.daq import EventBundle

SEG_HDR_BYTES = 8


@dataclasses.dataclass
class Segment:
    """One wire packet: LB header words + segmentation header + payload."""

    lb_words: np.ndarray  # uint32[4]
    daq_id: int
    seg_index: int
    n_segs: int
    payload: np.ndarray   # uint8
    event_number: int     # host-side convenience (also in lb_words)
    entropy: int


def segment_bundle(bundle: EventBundle,
                   mtu_payload: int = MAX_SEGMENT_PAYLOAD - SEG_HDR_BYTES) -> list[Segment]:
    """Split one Event Data Bundle into <=9KB segments, all sharing the
    bundle's (Event Number, Entropy)."""
    data = bundle.payload
    n_segs = max(1, -(-len(data) // mtu_payload))
    words = encode_headers(
        np.asarray([bundle.event_number], np.uint64),
        np.asarray([bundle.entropy], np.uint32),
    )[0]
    return [
        Segment(
            lb_words=words, daq_id=bundle.daq_id, seg_index=i, n_segs=n_segs,
            payload=data[i * mtu_payload : (i + 1) * mtu_payload],
            event_number=bundle.event_number, entropy=bundle.entropy,
        )
        for i in range(n_segs)
    ]


class Reassembler:
    """CN-side reassembly, one instance per receive lane (entropy/RSS lane:
    the paper's fix for the single-core reassembly bottleneck)."""

    def __init__(self):
        self.buffers: dict[tuple[int, int], dict] = {}
        self.completed: list[tuple[tuple[int, int], np.ndarray]] = []
        self.n_duplicate = 0

    def push(self, seg: Segment) -> Optional[np.ndarray]:
        key = (seg.event_number, seg.daq_id)
        buf = self.buffers.get(key)
        if buf is None:
            buf = {"parts": {}, "n_segs": seg.n_segs}
            self.buffers[key] = buf
        if seg.seg_index in buf["parts"]:
            self.n_duplicate += 1
            return None
        buf["parts"][seg.seg_index] = seg.payload
        if len(buf["parts"]) == buf["n_segs"]:
            data = np.concatenate([buf["parts"][i] for i in range(buf["n_segs"])])
            del self.buffers[key]
            self.completed.append((key, data))
            return data
        return None

    @property
    def n_incomplete(self) -> int:
        return len(self.buffers)

    def drain_completed(self):
        out, self.completed = self.completed, []
        return out
