"""Application-layer segmentation & reassembly protocol (paper §II-C).

"A dedicated, application layer segmentation and reassembly protocol is
required. This protocol runs between the DAQ and the compute node. The load
balancer does not participate." Each segment carries the LB header (same
Event Number + same Entropy for all segments of a bundle => same CN, same
receive lane) plus an opaque-to-the-LB segmentation header:

    seg_hdr = (daq_id u16, seg_index u16, n_segs u16, payload_len u16)

The production representation is **batched**: a window of wire packets is a
``PacketBatch`` — struct-of-arrays with stacked ``uint32[N, 4]`` LB words,
seg-header columns and a padded ``uint8[N, mtu]`` payload matrix — built by
``segment_bundles`` in one vectorized pass per bundle batch (no per-packet
Python work; see DESIGN.md §Ingest). Reassembly of a batch is the sort-based
``repro.data.reassembly.BatchReassembler``; completion is detected by
per-(event, daq) unique-segment counts, losses surface as incomplete buffers
(accounted + timed out), never as corrupt bundles.

``Segment``/``segment_bundle``/``Reassembler`` below are the per-packet
host-loop *reference* implementation: the oracle for round-trip parity tests
and the baseline that ``benchmarks/bench_ingest.py`` measures the batched
path against.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.protocol import (
    MAX_SEGMENT_PAYLOAD,
    encode_headers,
    encode_seg_headers,
)
from repro.data.daq import EventBundle

SEG_HDR_BYTES = 8
DEFAULT_MTU_PAYLOAD = MAX_SEGMENT_PAYLOAD - SEG_HDR_BYTES


def next_pow2(n: int, lo: int = 16) -> int:
    """Smallest power of two >= n (floor ``lo``) — the window padding grid
    that keeps device-call shapes (and so the jit cache) bounded."""
    p = lo
    while p < n:
        p <<= 1
    return p


@dataclasses.dataclass
class PacketBatch:
    """A window of wire packets as struct-of-arrays (one row per segment).

    ``headers`` are the LB protocol words consumed by ``DataPlane.route``;
    the seg-header columns (opaque to the LB) drive reassembly; ``payload``
    is row-padded to the batch's MTU payload width with ``payload_len`` valid
    bytes per row. ``event_number``/``entropy`` are host-side convenience
    columns (also encoded in ``headers``).
    """

    headers: np.ndarray       # uint32[N, 4]  LB words
    daq_id: np.ndarray        # int32[N]
    seg_index: np.ndarray     # int32[N]
    n_segs: np.ndarray        # int32[N]
    payload_len: np.ndarray   # int32[N]
    payload: np.ndarray       # uint8[N, mtu]
    event_number: np.ndarray  # uint64[N]
    entropy: np.ndarray       # uint32[N]

    def __len__(self) -> int:
        return int(self.headers.shape[0])

    @property
    def mtu_payload(self) -> int:
        return int(self.payload.shape[1])

    def seg_words(self) -> np.ndarray:
        """The uint32[N, 2] seg-header words (wire form of the columns)."""
        return encode_seg_headers(self.daq_id, self.seg_index, self.n_segs,
                                  self.payload_len)

    def take(self, idx) -> "PacketBatch":
        """Row gather (reorder / subset / duplicate)."""
        idx = np.asarray(idx)
        return PacketBatch(
            headers=self.headers[idx], daq_id=self.daq_id[idx],
            seg_index=self.seg_index[idx], n_segs=self.n_segs[idx],
            payload_len=self.payload_len[idx], payload=self.payload[idx],
            event_number=self.event_number[idx], entropy=self.entropy[idx],
        )

    @classmethod
    def empty(cls, mtu_payload: int = DEFAULT_MTU_PAYLOAD) -> "PacketBatch":
        return cls(
            headers=np.empty((0, 4), np.uint32),
            daq_id=np.empty((0,), np.int32),
            seg_index=np.empty((0,), np.int32),
            n_segs=np.empty((0,), np.int32),
            payload_len=np.empty((0,), np.int32),
            payload=np.empty((0, mtu_payload), np.uint8),
            event_number=np.empty((0,), np.uint64),
            entropy=np.empty((0,), np.uint32),
        )

    @classmethod
    def concat(cls, batches: Sequence["PacketBatch"]) -> "PacketBatch":
        batches = [b for b in batches if len(b) > 0]
        if not batches:
            return cls.empty()
        if len(batches) == 1:
            return batches[0]  # shared arrays; PacketBatch ops never mutate
        widths = {b.mtu_payload for b in batches}
        if len(widths) > 1:
            raise ValueError(f"mixed mtu payload widths: {sorted(widths)}")
        return cls(**{
            f.name: np.concatenate([getattr(b, f.name) for b in batches])
            for f in dataclasses.fields(cls)
        })


def group_rows(keys: np.ndarray):
    """Partition row positions by key in ONE stable pass (unique + stable
    argsort of the inverse + cumsum bounds) — no per-group window rescan.

    ``keys`` is ``[N]`` or ``[N, K]`` (composite keys as columns). Returns
    ``(unique_keys, groups)`` where ``groups[i]`` holds the positions of
    ``unique_keys[i]`` in arrival order (the stable sort preserves it, which
    the reassembler's duplicate-first-copy tie-break relies on).
    """
    if keys.ndim == 1:
        uniq, inverse, counts = np.unique(
            keys, return_inverse=True, return_counts=True)
    else:
        uniq, inverse, counts = np.unique(
            keys, axis=0, return_inverse=True, return_counts=True)
    order = np.argsort(inverse.reshape(-1), kind="stable")
    bounds = np.concatenate([[0], np.cumsum(counts)])
    groups = [order[bounds[k] : bounds[k + 1]] for k in range(len(uniq))]
    return uniq, groups


def segment_bundles(bundles: Sequence[EventBundle],
                    mtu_payload: int = DEFAULT_MTU_PAYLOAD) -> PacketBatch:
    """Segment a batch of Event Data Bundles in one vectorized pass.

    Emits the whole window's packets at once: stacked LB header words plus
    seg-header columns. The payload matrix IS the (row-padded) byte stream —
    one C-level concatenate of each bundle's bytes plus its tail padding
    lands every bundle on consecutive mtu-wide rows; all per-*segment* work
    is array arithmetic.
    """
    if not bundles:
        return PacketBatch.empty(mtu_payload)
    lens = np.asarray([len(b.payload) for b in bundles], np.int64)
    evs = np.asarray([b.event_number for b in bundles], np.uint64)
    ents = np.asarray([b.entropy for b in bundles], np.uint32)
    daqs = np.asarray([b.daq_id for b in bundles], np.int32)
    n_segs = np.maximum(1, -(-lens // mtu_payload)).astype(np.int64)

    n = int(n_segs.sum())
    bid = np.repeat(np.arange(len(bundles)), n_segs)           # bundle of row
    first = np.repeat(np.cumsum(n_segs) - n_segs, n_segs)      # first row of bundle
    seg_index = (np.arange(n) - first).astype(np.int64)
    offset = seg_index * mtu_payload
    seg_len = np.minimum(mtu_payload, lens[bid] - offset)
    seg_len = np.maximum(seg_len, 0)

    # One C-level concatenate builds the whole byte stream: each bundle's
    # payload followed by its (usually tiny) tail padding to the row grid.
    zpad = np.zeros((mtu_payload,), np.uint8)
    tail = n_segs * mtu_payload - lens
    pieces = []
    for i, b in enumerate(bundles):
        pieces.append(b.payload)
        if tail[i]:
            pieces.append(zpad[: tail[i]])
    payload = np.concatenate(pieces).reshape(n, mtu_payload)

    return PacketBatch(
        headers=encode_headers(evs[bid], ents[bid]),
        daq_id=daqs[bid].astype(np.int32),
        seg_index=seg_index.astype(np.int32),
        n_segs=n_segs[bid].astype(np.int32),
        payload_len=seg_len.astype(np.int32),
        payload=payload,
        event_number=evs[bid],
        entropy=ents[bid].astype(np.uint32),
    )


def batch_from_segments(segments: Sequence["Segment"],
                        mtu_payload: int = DEFAULT_MTU_PAYLOAD) -> PacketBatch:
    """Pack per-packet ``Segment`` objects into a ``PacketBatch`` (test shim)."""
    if not segments:
        return PacketBatch.empty(mtu_payload)
    n = len(segments)
    payload = np.zeros((n, mtu_payload), np.uint8)
    plen = np.empty((n,), np.int32)
    for i, s in enumerate(segments):
        plen[i] = len(s.payload)
        payload[i, : plen[i]] = s.payload
    return PacketBatch(
        headers=np.stack([s.lb_words for s in segments]).astype(np.uint32),
        daq_id=np.asarray([s.daq_id for s in segments], np.int32),
        seg_index=np.asarray([s.seg_index for s in segments], np.int32),
        n_segs=np.asarray([s.n_segs for s in segments], np.int32),
        payload_len=plen,
        payload=payload,
        event_number=np.asarray([s.event_number for s in segments], np.uint64),
        entropy=np.asarray([s.entropy for s in segments], np.uint32),
    )


# ---------------------------------------------------------------------------
# Per-packet reference path (round-trip oracle + bench baseline).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Segment:
    """One wire packet: LB header words + segmentation header + payload."""

    lb_words: np.ndarray  # uint32[4]
    daq_id: int
    seg_index: int
    n_segs: int
    payload: np.ndarray   # uint8
    event_number: int     # host-side convenience (also in lb_words)
    entropy: int


def segment_bundle(bundle: EventBundle,
                   mtu_payload: int = DEFAULT_MTU_PAYLOAD) -> list[Segment]:
    """Split one Event Data Bundle into <=9KB segments, all sharing the
    bundle's (Event Number, Entropy). Per-packet reference; the batched path
    is ``segment_bundles``."""
    data = bundle.payload
    n_segs = max(1, -(-len(data) // mtu_payload))
    words = encode_headers(
        np.asarray([bundle.event_number], np.uint64),
        np.asarray([bundle.entropy], np.uint32),
    )[0]
    return [
        Segment(
            lb_words=words, daq_id=bundle.daq_id, seg_index=i, n_segs=n_segs,
            payload=data[i * mtu_payload : (i + 1) * mtu_payload],
            event_number=bundle.event_number, entropy=bundle.entropy,
        )
        for i in range(n_segs)
    ]


class Reassembler:
    """CN-side per-packet reference reassembler, one instance per receive
    lane (entropy/RSS lane: the paper's fix for the single-core reassembly
    bottleneck). The batched production path is
    ``repro.data.reassembly.BatchReassembler``."""

    def __init__(self):
        self.buffers: dict[tuple[int, int], dict] = {}
        self.completed: list[tuple[tuple[int, int], np.ndarray]] = []
        self.n_duplicate = 0

    def push(self, seg: Segment) -> Optional[np.ndarray]:
        key = (seg.event_number, seg.daq_id)
        buf = self.buffers.get(key)
        if buf is None:
            buf = {"parts": {}, "n_segs": seg.n_segs}
            self.buffers[key] = buf
        if seg.seg_index in buf["parts"]:
            self.n_duplicate += 1
            return None
        buf["parts"][seg.seg_index] = seg.payload
        if len(buf["parts"]) == buf["n_segs"]:
            data = np.concatenate([buf["parts"][i] for i in range(buf["n_segs"])])
            del self.buffers[key]
            self.completed.append((key, data))
            return data
        return None

    @property
    def n_incomplete(self) -> int:
        return len(self.buffers)

    def drain_completed(self):
        out, self.completed = self.completed, []
        return out
