"""Virtual-time link model: FIFO serialization, propagation, jitter, loss.

A link is a token-bucket-rate FIFO: packet *i* leaves the head-end at

    dep_i = max(t_ready_i, dep_{i-1}) + bytes_i / rate

and arrives ``prop_delay + jitter`` later. The recurrence is vectorized with
the cumsum/cummax identity

    dep_i = c_i + max_{j<=i}(t_j - c_{j-1}),   c = cumsum(bytes / rate)

(one ``cumsum`` + one running max per window; seeded with the carried
``busy_until`` so serialization state flows across windows). The per-link
form (``fifo_departures_multi``) sorts rows by ``(link, t_ready)`` once and
runs the same identity segment-wise — the PR-1/PR-2 sort-based idiom, no
per-packet Python loop.

Loss / duplication / jitter draw from the shared per-window stream in
``repro.data.transport.draw_window``, which makes today's positional
``WANTransport`` the *degenerate* case of this model: zero-rate link, zero
propagation, unit-spaced emissions — arrival keys reduce to
``index + jitter``, the exact keys ``WANTransport`` sorts by
(property-tested in tests/test_simnet.py).

Correlated loss (link_flap's ugly cousin) is a Gilbert-Elliott two-state
chain; sojourns are geometric (memoryless), so the chain is generated
vectorized as alternating geometric run lengths and only the current state
carries across windows.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.transport import delivery_order, draw_window


def fifo_departures(t_ready: np.ndarray, tx_s: np.ndarray,
                    busy_until: float = -np.inf) -> tuple[np.ndarray, float]:
    """Head-end departure times for one FIFO link, rows in service order.

    ``tx_s`` is each packet's transmit (serialization) time; zeros model an
    infinite-rate link. Returns ``(departures, new_busy_until)``.
    """
    n = len(t_ready)
    if n == 0:
        return np.empty((0,), np.float64), busy_until
    c = np.cumsum(tx_s, dtype=np.float64)
    a = np.asarray(t_ready, np.float64) - (c - tx_s)
    a[0] = max(a[0], busy_until)
    dep = c + np.maximum.accumulate(a)
    return dep, float(dep[-1])


def fifo_departures_multi(link: np.ndarray, t_ready: np.ndarray,
                          tx_s: np.ndarray,
                          busy_until: np.ndarray) -> np.ndarray:
    """Per-link FIFO serialization in one segmented pass.

    Sorts rows by ``(link, t_ready)``, applies the cumsum/cummax identity
    within each link's segment (running max segmented by the offset trick),
    seeds each segment with that link's carried ``busy_until`` and updates it
    in place. Returns per-row departures in the caller's row order.
    """
    n = len(link)
    if n == 0:
        return np.empty((0,), np.float64)
    order = np.lexsort((t_ready, link))
    lk = link[order]
    t = np.asarray(t_ready, np.float64)[order]
    s = np.asarray(tx_s, np.float64)[order]
    new = np.ones((n,), bool)
    new[1:] = lk[1:] != lk[:-1]
    gid = np.cumsum(new) - 1
    cs = np.cumsum(s)
    seg_base = cs[new] - s[new]                  # exclusive cumsum at starts
    c = cs - seg_base[gid]                       # segmented inclusive cumsum
    a = t - (c - s)
    a[new] = np.maximum(a[new], busy_until[lk[new]])
    # Segmented running max: add a per-segment offset larger than the value
    # span so earlier segments can never dominate, accumulate, subtract.
    span = float(a.max() - a.min()) + 1.0
    off = gid * span
    dep_sorted = c + (np.maximum.accumulate(a + off) - off)
    last = np.flatnonzero(np.concatenate([new[1:], [True]]))
    busy_until[lk[last]] = np.maximum(busy_until[lk[last]], dep_sorted[last])
    dep = np.empty((n,), np.float64)
    dep[order] = dep_sorted
    return dep


def gilbert_elliott_states(seed: int, window: int, n: int, *, p_gb: float,
                           p_bg: float, start_bad: bool) -> tuple[np.ndarray, bool]:
    """Per-packet bad-state mask from a two-state Markov chain, vectorized.

    Sojourn lengths are geometric, so the whole window's states are built as
    alternating geometric run lengths (inverse-CDF over one uniform draw per
    potential run; n+1 runs always cover n packets). Memorylessness means
    only the final state needs to carry across windows.
    """
    import jax

    if n == 0:
        return np.zeros((0,), bool), start_bad
    from repro.data.segmentation import next_pow2

    key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x6E5), window)
    u = np.asarray(jax.random.uniform(
        key, (next_pow2(n + 1),), minval=1e-12, maxval=1.0),
        np.float64)[: n + 1]
    k = np.arange(n + 1)
    bad = (k % 2 == 1) if not start_bad else (k % 2 == 0)
    p_exit = np.where(bad, p_bg, p_gb)
    with np.errstate(divide="ignore"):
        lengths = np.where(
            p_exit <= 0.0, n,  # absorbing: one run covers the window
            1 + np.floor(np.log(u) / np.log1p(-np.clip(p_exit, 1e-12, 1.0))))
    bounds = np.cumsum(lengths)
    run_of_packet = np.searchsorted(bounds, np.arange(n), side="right")
    run_of_packet = np.minimum(run_of_packet, n)
    states = bad[run_of_packet]
    # Sojourns are memoryless, so the state of the last packet is all the
    # next window needs to carry.
    return states, bool(states[-1])


@dataclasses.dataclass
class LinkConfig:
    """One link's fixed parameters (scenario hooks may mutate them mid-run)."""

    rate_Bps: float = 0.0          # serialization rate; 0 = infinite (no FIFO wait)
    prop_delay_s: float = 0.0
    jitter_s: float = 0.0          # uniform extra path delay in [0, jitter_s)
    loss_prob: float = 0.0
    duplicate_prob: float = 0.0
    # Gilbert-Elliott correlated loss: active when bad_loss_prob > 0.
    p_good_to_bad: float = 0.0
    p_bad_to_good: float = 0.1
    bad_loss_prob: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class LinkDelivery:
    """One window's deliveries in arrival order (struct-of-arrays)."""

    src: np.ndarray        # int64[K] input row of each delivered packet
    is_dup: np.ndarray     # bool[K]
    t_arrive: np.ndarray   # float64[K]
    n_lost: int


class Link:
    """A stateful point-to-point link (DAQ uplinks aggregate, the WAN hop).

    ``transit`` serializes the window in emission order, applies loss
    (optionally Gilbert-Elliott correlated), duplication and jitter from the
    shared per-window stream, and returns deliveries sorted by arrival time
    (duplicates tie-broken after their original — same rule as
    ``WANTransport``).
    """

    def __init__(self, cfg: LinkConfig):
        self.cfg = cfg
        self.busy_until = -np.inf
        self.n_lost = 0
        self.n_dup = 0
        self._window = 0
        self._ge_bad = False

    def transit(self, t_emit: np.ndarray, nbytes: np.ndarray) -> LinkDelivery:
        cfg = self.cfg
        n = len(t_emit)
        window = self._window
        self._window += 1
        if n == 0:
            return LinkDelivery(np.empty((0,), np.int64),
                                np.zeros((0,), bool),
                                np.empty((0,), np.float64), 0)
        # emission order; only needed for serialization and the loss chain
        order = (np.argsort(t_emit, kind="stable")
                 if cfg.rate_Bps > 0 or cfg.bad_loss_prob > 0 else None)
        if cfg.rate_Bps > 0:
            tx = np.asarray(nbytes, np.float64) / cfg.rate_Bps
            dep_sorted, self.busy_until = fifo_departures(
                np.asarray(t_emit, np.float64)[order], tx[order],
                self.busy_until)
            dep = np.empty((n,), np.float64)
            dep[order] = dep_sorted
        else:
            # infinite rate: no serialization queue, no cross-window FIFO
            # coupling — exactly the WANTransport degenerate case
            dep = np.asarray(t_emit, np.float64)

        loss_p: float | np.ndarray = cfg.loss_prob
        if cfg.bad_loss_prob > 0:
            bad, self._ge_bad = gilbert_elliott_states(
                cfg.seed, window, n, p_gb=cfg.p_good_to_bad,
                p_bg=cfg.p_bad_to_good, start_bad=self._ge_bad)
            # chain runs in emission order; map state back to row order
            bad_rows = np.empty((n,), bool)
            bad_rows[order] = bad
            loss_p = np.where(bad_rows, cfg.bad_loss_prob, cfg.loss_prob)
        keep, dup, jitter, extra = draw_window(
            cfg.seed, window, n, loss_prob=loss_p,
            duplicate_prob=cfg.duplicate_prob, jitter_scale=cfg.jitter_s)

        arrive = dep + cfg.prop_delay_s + jitter
        self.n_lost += int((~keep).sum())
        self.n_dup += int(dup.sum())
        src, is_dup, t_arr = delivery_order(keep, dup, arrive, arrive + extra)
        return LinkDelivery(src, is_dup, t_arr, int((~keep).sum()))


class LinkSet:
    """A bank of per-destination links (LB -> CN downlinks), vectorized.

    One segmented serialization pass over all links per window; per-link
    rate/loss live in arrays so scenario hooks can flap a single member's
    link mid-run. Downlinks do not duplicate (the LB emits each packet
    once); loss models a dirty edge link.
    """

    def __init__(self, cfgs: list[LinkConfig]):
        self.n_links = len(cfgs)
        self.rate_Bps = np.asarray([c.rate_Bps for c in cfgs], np.float64)
        self.prop_delay_s = np.asarray([c.prop_delay_s for c in cfgs], np.float64)
        self.jitter_s = np.asarray([c.jitter_s for c in cfgs], np.float64)
        self.loss_prob = np.asarray([c.loss_prob for c in cfgs], np.float64)
        if any(c.duplicate_prob for c in cfgs):
            raise ValueError("downlinks do not duplicate")
        if any(c.bad_loss_prob for c in cfgs):
            raise ValueError("LinkSet does not model correlated "
                             "(Gilbert-Elliott) loss; use a Link per "
                             "destination if a downlink needs it")
        self.seed = cfgs[0].seed if cfgs else 0
        self.busy_until = np.full((self.n_links,), -np.inf)
        self.n_lost = 0
        self._window = 0

    def transit(self, link: np.ndarray, t_ready: np.ndarray,
                nbytes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Returns ``(t_arrive, keep)`` aligned with the input rows (lost
        rows have ``keep=False``; their arrival time is meaningless)."""
        n = len(link)
        window = self._window
        self._window += 1
        if n == 0:
            return np.empty((0,), np.float64), np.zeros((0,), bool)
        if (self.rate_Bps > 0).all():
            tx = np.asarray(nbytes, np.float64) / self.rate_Bps[link]
            dep = fifo_departures_multi(link, t_ready, tx, self.busy_until)
        else:
            rate = self.rate_Bps[link]
            tx = np.where(rate > 0,
                          np.asarray(nbytes, np.float64)
                          / np.where(rate > 0, rate, 1.0), 0.0)
            dep = fifo_departures_multi(link, t_ready, tx, self.busy_until)
            # zero-rate links serialize nothing: no wait, no carried state
            free = self.rate_Bps[link] <= 0
            dep = np.where(free, np.asarray(t_ready, np.float64), dep)
            self.busy_until[self.rate_Bps <= 0] = -np.inf
        keep, _dup, jitter, _extra = draw_window(
            self.seed, window, n, loss_prob=self.loss_prob[link],
            duplicate_prob=0.0, jitter_scale=1.0)
        t_arr = dep + self.prop_delay_s[link] + jitter * self.jitter_s[link]
        self.n_lost += int((~keep).sum())
        return t_arr, keep
