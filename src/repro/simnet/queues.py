"""Bounded per-CN receive queues with a per-member service-rate model.

Each member is a single-server FIFO (the paper's CN NIC + reassembly daemon).
Service time of a segment on member *m* is

    s = per_packet_s[m] + bytes * per_byte_s[m]

and the queue state is the Lindley backlog ``W`` (seconds of unfinished
work). At an arrival at time *t*:

    W <- max(W - (t - t_last), 0)                # server drains in real time
    drop-tail:  W + s > capacity_s  -> dropped (accounted, never silent)
    accept:     depart = t + W + s;  W <- W + s

Bounding the queue in *work-seconds* (equivalently: mean-size packet slots)
is what keeps the recurrence exactly vectorizable: the whole farm advances in
one scan over the window's time axis with all members as vector lanes —
rows are sorted by ``(member, arrival)`` once, scattered to a dense
``[n_members, T]`` matrix, and the scan runs T steps of [M]-wide arithmetic
(T = the *deepest* member's packet count, not the window size). Engines:
``np`` (host default) and ``jnp`` (one jitted ``lax.scan``, shapes padded to
a power of two) — property-tested equal in tests/test_simnet.py.

Occupancy is *measured*, not synthetic: ``fill() = W / capacity_s`` is what
feeds ``TelemetryHub`` — the control plane reacts to the same queue state
that determines latency.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np

from repro.data.segmentation import next_pow2


@dataclasses.dataclass
class FarmConfig:
    """Per-member service model. Arrays are length ``n_members``."""

    n_members: int
    per_packet_s: np.ndarray   # fixed per-segment cost [M]
    per_byte_s: np.ndarray     # byte-proportional cost [M]
    capacity_s: np.ndarray     # drop-tail bound on backlog (work-seconds) [M]

    @classmethod
    def uniform(cls, n_members: int, per_packet_s: float = 2e-5,
                per_byte_s: float = 1.25e-7, capacity_s: float = 0.05,
                scale: np.ndarray | None = None) -> "FarmConfig":
        """Homogeneous farm; ``scale[m] > 1`` makes member m slower (its
        service times stretch — a straggler or a weak node)."""
        s = np.ones((n_members,)) if scale is None else np.asarray(scale, np.float64)
        return cls(
            n_members=n_members,
            per_packet_s=np.full((n_members,), per_packet_s) * s,
            per_byte_s=np.full((n_members,), per_byte_s) * s,
            capacity_s=np.full((n_members,), float(capacity_s)),
        )


@dataclasses.dataclass
class ServeResult:
    """Per-row outcomes plus per-member aggregates for one window."""

    depart: np.ndarray     # float64[N] service-completion time (inf if dropped)
    dropped: np.ndarray    # bool[N]
    busy_s: np.ndarray     # float64[M] work accepted this window
    accepted: np.ndarray   # int64[M]
    w_end: np.ndarray      # float64[M] backlog at each member's last arrival
    w_max: np.ndarray      # float64[M] peak backlog seen this window


def _serve_np(tm, sm, valid, w0, t0, cap_s):
    """The scan, numpy engine: T steps of [M]-wide arithmetic."""
    n_members, t_cols = tm.shape
    w, t_last, w_max = w0.copy(), t0.copy(), w0.copy()
    dep = np.full((n_members, t_cols), np.inf)
    drop = np.zeros((n_members, t_cols), bool)
    for j in range(t_cols):
        v = valid[:, j]
        # server time never rewinds: a next-window arrival that jitter pushed
        # before the previous window's last arrival queues at t_last instead
        # of manufacturing phantom backlog decay/growth
        t = np.where(v, np.maximum(tm[:, j], t_last), t_last)
        w = np.maximum(w - (t - t_last), 0.0)
        s = sm[:, j]
        d = v & (w + s > cap_s)
        acc = v & ~d
        dep[:, j] = np.where(acc, t + w + s, np.inf)
        w = np.where(acc, w + s, w)
        w_max = np.maximum(w_max, w)
        t_last = t
        drop[:, j] = d
    return dep, drop, w, t_last, w_max


@functools.partial(jax.jit)
def _serve_jnp(tm, sm, valid, w0, t0, cap_s):
    """Identical scan as one jitted ``lax.scan`` over the time axis."""
    import jax.numpy as jnp

    def step(carry, x):
        w, t_last, w_max = carry
        t_col, s_col, v = x
        t = jnp.where(v, jnp.maximum(t_col, t_last), t_last)  # no time rewind
        w = jnp.maximum(w - (t - t_last), 0.0)
        d = v & (w + s_col > cap_s)
        acc = v & ~d
        dep = jnp.where(acc, t + w + s_col, jnp.inf)
        w = jnp.where(acc, w + s_col, w)
        w_max = jnp.maximum(w_max, w)
        return (w, t, w_max), (dep, d)

    (w, t_last, w_max), (dep, drop) = jax.lax.scan(
        step, (w0, t0, w0), (tm.T, sm.T, valid.T))
    return dep.T, drop.T, w, t_last, w_max


class FarmQueues:
    """Stateful farm of bounded FIFO queues; backlog carries across windows."""

    def __init__(self, cfg: FarmConfig, backend: str = "np"):
        if backend not in ("np", "jnp"):
            raise ValueError(f"unknown queue engine {backend!r}")
        self.cfg = cfg
        self.backend = backend
        m = cfg.n_members
        self.w = np.zeros((m,), np.float64)        # backlog at t_last
        self.t_last = np.zeros((m,), np.float64)
        self.n_dropped = 0
        self.n_served = 0

    def service_time(self, member: np.ndarray, nbytes: np.ndarray) -> np.ndarray:
        return (self.cfg.per_packet_s[member]
                + np.asarray(nbytes, np.float64) * self.cfg.per_byte_s[member])

    def fill(self, now: float | None = None) -> np.ndarray:
        """Measured queue-fill fraction per member (backlog / capacity),
        decayed to ``now`` if given — this is what telemetry reports."""
        w = self.w
        if now is not None:
            w = np.maximum(w - np.maximum(now - self.t_last, 0.0), 0.0)
        return w / self.cfg.capacity_s

    def serve(self, member: np.ndarray, t_arrive: np.ndarray,
              nbytes: np.ndarray) -> ServeResult:
        """Run one window through every member's queue."""
        m_count = self.cfg.n_members
        n = len(member)
        if n == 0:
            z = np.zeros((m_count,))
            return ServeResult(np.empty((0,)), np.zeros((0,), bool), z,
                               z.astype(np.int64), self.w.copy(), self.w.copy())
        svc = self.service_time(member, nbytes)
        order = np.lexsort((t_arrive, member))
        m_s, t_s, s_s = member[order], t_arrive[order], svc[order]
        counts = np.bincount(m_s, minlength=m_count)
        t_cols = int(counts.max())
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        col = np.arange(n) - starts[m_s]

        if self.backend == "jnp":
            t_cols = next_pow2(t_cols, lo=8)  # bound the jit cache
        tm = np.zeros((m_count, t_cols))
        sm = np.zeros((m_count, t_cols))
        valid = np.zeros((m_count, t_cols), bool)
        tm[m_s, col] = t_s
        sm[m_s, col] = s_s
        valid[m_s, col] = True

        engine = _serve_np if self.backend == "np" else _serve_jnp
        dep_m, drop_m, w, t_last, w_max = engine(
            tm, sm, valid, self.w, self.t_last, self.cfg.capacity_s)
        dep_m, drop_m = np.asarray(dep_m), np.asarray(drop_m)
        self.w, self.t_last = np.asarray(w).copy(), np.asarray(t_last).copy()

        dep = np.empty((n,), np.float64)
        drop = np.empty((n,), bool)
        dep[order] = dep_m[m_s, col]
        drop[order] = drop_m[m_s, col]
        acc_rows = ~drop
        busy = np.bincount(member[acc_rows], weights=svc[acc_rows],
                           minlength=m_count)
        accepted = np.bincount(member[acc_rows], minlength=m_count)
        self.n_dropped += int(drop.sum())
        self.n_served += int(acc_rows.sum())
        return ServeResult(dep, drop, busy, accepted.astype(np.int64),
                           self.w.copy(), np.asarray(w_max).copy())
