"""repro.simnet — discrete virtual-time network & farm simulator.

Latency is the paper's keystone claim (a *low fixed-latency* LB data plane);
this package gives the repro a notion of time so end-to-end latency, queue
occupancy and control-plane reaction are measured, not assumed. Everything is
vectorized struct-of-arrays — per-window array programs, never per-packet
Python loops (DESIGN.md §SimNet).
"""
from repro.simnet.clock import VirtualClock
from repro.simnet.links import Link, LinkConfig
from repro.simnet.queues import FarmConfig, FarmQueues
from repro.simnet.scenarios import SCENARIOS, get_scenario
from repro.simnet.sim import SimConfig, SimReport, Simulator

__all__ = [
    "VirtualClock", "Link", "LinkConfig", "FarmConfig", "FarmQueues",
    "SCENARIOS", "get_scenario", "SimConfig", "SimReport", "Simulator",
]
