"""Virtual time: a monotonic clock the simulator advances explicitly.

``now`` is a plain callable so it can be injected anywhere wall time is
consumed today (``TelemetryHub(clock=clock.now)``) — the hub, the control
plane and the scenario hooks all observe the *same* simulated instant.
"""
from __future__ import annotations


class VirtualClock:
    """Monotonic simulated time in seconds."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"virtual time cannot go backwards (dt={dt})")
        self._t += float(dt)
        return self._t

    def advance_to(self, t: float) -> float:
        """Advance to an absolute instant (no-op if already past it —
        pipeline stages may finish 'early' relative to the window edge)."""
        self._t = max(self._t, float(t))
        return self._t
