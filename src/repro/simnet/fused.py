"""Device-resident closed loop: the per-window simnet step as ONE program.

The host engine (``sim.Simulator.step``) ping-pongs Python between seven
already-vectorized array programs every window — route (device), downlink
FIFO (numpy), farm Lindley scan, reassembly sort, telemetry dicts, policy,
calendar rebuild — so the composed system measures ~22k pkt/s while the
routing core alone sustains ~760k. This module is the paper's actual shape:
the steady-state loop is a single compiled artifact (the FPGA forwards at
line rate; the control plane only intervenes at epoch boundaries), and host
code runs only at *reconfiguration* boundaries.

Split of labor (DESIGN.md §Fused closed loop):

* **Host precompute (the plant).** Everything control-INDEPENDENT is
  precomputed per run with the simulator's real stateful objects — DAQ
  emission (``DAQFleet``), segmentation, uplink + WAN serialization/loss
  (``LinkSet``/``Link``), and the per-window downlink randomness
  (``draw_window`` with the member links' own seed/window counter). Within
  the fused scope every packet routes valid, so the downlink draw count per
  window is known before routing — the one fact that makes the plant
  separable from the control loop.
* **Device scan (the closed loop).** Routing against an epoch *ring*,
  per-member downlink FIFO serialization, the bounded Lindley farm queues,
  sort-based completion/duplicate detection, reassembly-timeout buckets,
  measured-occupancy telemetry, the proportional-PI policy and the full
  512-slot calendar rebuild (largest-remainder quotas + smooth weighted
  round-robin + quota enforcement) all run inside one ``lax.scan`` over a
  K-window superblock, jitted with the carry buffer-donated. Python
  branches became masks; the epoch-switch decision is a masked in-scan
  update with the hysteresis state (scheduled weights, current epoch start)
  carried as arrays.

Numerical contract: every elementwise operation mirrors the host engine's
op-for-op (same association, same clip/round semantics, numpy's pairwise
mean replicated exactly for the lane counts the engine admits), so fused
and host runs produce identical counters and (empirically, asserted by
tests/test_fused.py) identical latencies on the supported scenarios. The
host loop stays as the parity oracle (``engine="host"``).

``FUSED_STEP_CALLS`` counts jitted superblock dispatches and
``FUSED_TRACES`` counts compiles — CI's jit-discipline check asserts one
compile total and one dispatch per superblock across heterogeneous
same-shape configs (same policy as ``controld.policy.FUSED_KERNEL_CALLS``).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.calendar import build_calendar
from repro.core.protocol import CALENDAR_SLOTS, HEADER_BYTES, split64
from repro.core.router import route as _route
from repro.core.tables import MAX_EPOCH_ROWS, DeviceTables
from repro.data.segmentation import SEG_HDR_BYTES, next_pow2, segment_bundles
from repro.data.transport import draw_window
from repro.simnet.sim import IP_UDP_BYTES

#: jitted superblock dispatches since import (one per K-window superblock)
FUSED_STEP_CALLS = 0
#: traces (compiles) of the superblock program since import — heterogeneous
#: same-shape configs must share one trace (params travel as traced arrays)
FUSED_TRACES = 0

DEFAULT_SUPERBLOCK = 8
_RING = MAX_EPOCH_ROWS  # resident calendars in the scan-carried epoch ring

# numpy's small-array quicksort is insertion sort (stable) up to this many
# elements — above it np.argsort(-rem) tie order in the calendar quota step
# is not reproducible with jnp's stable argsort, so the fused engine demurs
_STABLE_ARGSORT_MAX = 16


def unsupported_reason(cfg, scenario=None) -> Optional[str]:
    """Why this (config, scenario) must run on the host engine, or None.

    The fused program covers the embedded-CP single-instance loop with
    hook-free scenarios; anything that mutates the plant mid-run (traffic
    shaping, link flaps, controld lease churn) re-introduces host control
    flow between windows and stays on the oracle path.
    """
    if cfg.controld:
        return "controld sessions are host-side daemons"
    if cfg.n_instances != 1:
        return "multi-instance partitions the farm host-side"
    if scenario is not None:
        if scenario.traffic is not None:
            return "scenario shapes traffic per step"
        if scenario.trigger_boost is not None:
            return "scenario boosts trigger sizes per step"
        if scenario.on_step is not None:
            return "scenario mutates the plant per step"
    if cfg.stale_after_s is not None:
        return "staleness tracking needs host telemetry timestamps"
    if cfg.n_members > _STABLE_ARGSORT_MAX:
        return "calendar quota tie-break only reproducible for <=16 members"
    if not cfg.timeout_windows or cfg.timeout_windows < 1:
        return "reassembly timeout buckets need timeout_windows >= 1"
    # completion keys pack (event_lo, daq, seg) into one u64 lane
    ev_bound = (1 << 20) + 7 * cfg.steps * cfg.triggers_per_step
    if ev_bound >= (1 << 31):
        return "event numbers would overflow the packed completion key"
    if cfg.n_daqs >= (1 << 16):
        return "daq ids must fit the packed completion key"
    return None


def fused_supported(cfg, scenario=None) -> bool:
    return unsupported_reason(cfg, scenario) is None


# ---------------------------------------------------------------------------
# exact numpy arithmetic on device
# ---------------------------------------------------------------------------

def _np_sum(x, m: int):
    """Bitwise replication of numpy's pairwise ``add.reduce`` over ``m``
    lanes (m <= 128): sequential below 8, the 8-way unrolled accumulator
    with the fixed combine tree above. ``np.mean`` (the policy finalize) and
    ``w.sum()`` (calendar quotas) both reduce through this path on the host,
    so the device must associate identically or weight hysteresis / quota
    floors could flip on a ULP."""
    if m < 8:
        s = x[0]
        for i in range(1, m):
            s = s + x[i]
        return s
    r = [x[j] for j in range(8)]
    i = 8
    while i < m - (m % 8):
        for j in range(8):
            r[j] = r[j] + x[i + j]
        i += 8
    s = ((r[0] + r[1]) + (r[2] + r[3])) + ((r[4] + r[5]) + (r[6] + r[7]))
    for k in range(i, m):
        s = s + x[k]
    return s


def _device_calendar(w, n_slots: int):
    """``core.calendar.build_calendar`` as a traced program: largest-
    remainder quotas (surplus/deficit fixups as masked bounded loops — the
    host's data-dependent ``while`` moves at most M slots), the 512-step
    smooth-weighted-round-robin scan, then the exact-quota corrective walk.
    Op-for-op with the numpy implementation; all members live (w > 0)."""
    m = w.shape[0]
    total = _np_sum(w, m)
    ideal = w / total * n_slots
    counts = jnp.floor(ideal).astype(jnp.int64)
    counts = jnp.where(counts == 0, 1, counts)  # every live member reachable
    rem = ideal - jnp.floor(ideal)

    def surplus(_, cnts):
        over = jnp.where(cnts > 1, cnts.astype(jnp.float64) - ideal, -jnp.inf)
        pick = jnp.argmax(over)  # first-max, same as np.argmax
        dec = (jnp.sum(cnts) > n_slots).astype(cnts.dtype)
        return cnts.at[pick].add(-dec)

    counts = jax.lax.fori_loop(0, m, surplus, counts)
    order = jnp.argsort(-rem)  # stable; np quicksort is stable for m <= 16

    def deficit(i, cnts):
        inc = (jnp.sum(cnts) < n_slots).astype(cnts.dtype)
        return cnts.at[order[i]].add(inc)

    counts = jax.lax.fori_loop(0, m, deficit, counts)

    remaining = counts.astype(jnp.float64)

    def swrr(credit, _):
        credit = credit + remaining
        pick = jnp.argmax(credit)
        credit = credit.at[pick].add(-float(n_slots))
        return credit, pick.astype(jnp.int32)

    _, cal = jax.lax.scan(swrr, jnp.zeros((m,), jnp.float64), None,
                          length=n_slots)

    have = jnp.zeros((m,), jnp.int64).at[cal].add(1)
    deficit_m = have < counts
    len_def = jnp.sum(deficit_m.astype(jnp.int32))
    def_ids = jnp.sort(jnp.where(deficit_m, jnp.arange(m, dtype=jnp.int32),
                                 m))
    need = jnp.where(deficit_m, counts - have, 0)

    def enforce(c3, cal_s):
        have, need, di = c3
        d = jnp.clip(def_ids[jnp.clip(di, 0, m - 1)], 0, m - 1)
        cond = (have[cal_s] > counts[cal_s]) & (di < len_def)
        c1 = cond.astype(jnp.int64)
        out = jnp.where(cond, d, cal_s)
        have = have.at[cal_s].add(-c1).at[d].add(c1)
        need = need.at[d].add(-c1)
        di = di + (cond & (need[d] == 0)).astype(jnp.int32)
        return (have, need, di), out.astype(jnp.int32)

    _, cal = jax.lax.scan(enforce, (have, need, jnp.int32(0)), cal)
    return cal


# ---------------------------------------------------------------------------
# the fused per-window step + superblock scan
# ---------------------------------------------------------------------------

def _window_step(carry, x, params):
    """One window: route -> downlink FIFO -> farm -> completion ->
    timeout buckets -> telemetry -> policy -> (masked) epoch switch.
    Every branch of the host step is a mask; padding windows/rows are exact
    carry no-ops."""
    i32, f64 = jnp.int32, jnp.float64
    valid = x["valid"]
    n = valid.shape[0]
    m_count = carry["weights"].shape[0]
    g_count = x["nseg_b"].shape[0]
    idx = jnp.arange(n, dtype=i32)

    # -- 1) route against the scan-carried epoch ring ----------------------
    tables = DeviceTables(
        seg_start_hi=carry["ring_hi"], seg_start_lo=carry["ring_lo"],
        seg_row=jnp.arange(_RING, dtype=i32), calendars=carry["ring_cal"],
        member_node=jnp.arange(m_count, dtype=i32),
        member_base_lane=jnp.zeros((m_count,), i32),
        member_lane_mask=jnp.zeros((m_count,), i32),
        member_valid=jnp.ones((m_count,), i32))
    r = _route(tables, x["ev_hi"], x["ev_lo"], jnp.zeros((n,), i32))
    memb = r.member
    invalid = jnp.sum(valid & ~r.valid)  # expected 0 in fused scope
    mc = jnp.clip(memb, 0, m_count - 1)

    # -- 2) downlink: segmented FIFO (links.fifo_departures_multi) ---------
    lk = jnp.where(valid, memb, m_count).astype(i32)
    tx = jnp.where(valid, x["bytes"] / params["link_rate"], 0.0)
    t_rdy = jnp.where(valid, x["t_out"], 0.0)
    s_lk, s_t, s_idx, s_tx = jax.lax.sort((lk, t_rdy, idx, tx), num_keys=3)
    new = jnp.concatenate([jnp.ones((1,), bool), s_lk[1:] != s_lk[:-1]])
    svalid = s_lk < m_count
    gid = jnp.cumsum(new.astype(i32)) - 1
    cs = jnp.cumsum(s_tx)
    seg_base = jax.lax.cummax(jnp.where(new, cs - s_tx, -jnp.inf))
    c = cs - seg_base
    a = s_t - (c - s_tx)
    busy_ext = jnp.concatenate([carry["dl_busy"], jnp.full((1,), -jnp.inf)])
    a = jnp.where(new, jnp.maximum(a, busy_ext[s_lk]), a)
    amax = jnp.max(jnp.where(svalid, a, -jnp.inf))
    amin = jnp.min(jnp.where(svalid, a, jnp.inf))
    span = jnp.where(jnp.isfinite(amax), (amax - amin) + 1.0, 0.0)
    off = gid.astype(f64) * span
    run = jax.lax.cummax(jnp.where(svalid, a + off, -jnp.inf))
    dep_s = c + (run - off)
    last = jnp.concatenate([new[1:], jnp.ones((1,), bool)])
    dl_busy = carry["dl_busy"].at[
        jnp.where(last & svalid, s_lk, m_count)].max(dep_s, mode="drop")
    dep_row = jnp.zeros((n,), f64).at[s_idx].set(dep_s)
    # host: arrive = dep + prop_delay + jitter * jitter_s (same association)
    t_cn = (dep_row + params["dl_prop"]) + x["jadd"]

    # -- 3) farm: bounded Lindley queues (queues._serve_np) ----------------
    fvalid = valid & x["keep"]
    fm = jnp.where(fvalid, memb, m_count).astype(i32)
    ft = jnp.where(fvalid, t_cn, 0.0)
    svc = jnp.where(fvalid,
                    params["per_pkt"][mc] + x["bytes"] * params["per_byte"][mc],
                    0.0)
    s_fm, s_ft, s_fi, s_sv = jax.lax.sort((fm, ft, idx, svc), num_keys=3)
    fnew = jnp.concatenate([jnp.ones((1,), bool), s_fm[1:] != s_fm[:-1]])
    col = idx - jax.lax.cummax(jnp.where(fnew, idx, 0))
    tm = jnp.zeros((m_count, n), f64).at[s_fm, col].set(s_ft, mode="drop")
    sm = jnp.zeros((m_count, n), f64).at[s_fm, col].set(s_sv, mode="drop")
    vm = jnp.zeros((m_count, n), bool).at[s_fm, col].set(
        jnp.ones((n,), bool), mode="drop")

    def serve(c2, xc):
        w, t_last = c2
        t_col, s_col, v = xc
        t = jnp.where(v, jnp.maximum(t_col, t_last), t_last)
        w = jnp.maximum(w - (t - t_last), 0.0)
        d = v & (w + s_col > params["cap_s"])
        acc = v & ~d
        dep = jnp.where(acc, t + w + s_col, jnp.inf)
        w = jnp.where(acc, w + s_col, w)
        return (w, t), (dep, d)

    (farm_w, farm_t), (dep_c, drop_c) = jax.lax.scan(
        serve, (carry["farm_w"], carry["farm_t"]), (tm.T, sm.T, vm.T))
    fmc = jnp.clip(s_fm, 0, m_count - 1)
    dep_sorted = jnp.where(svalid_f := (s_fm < m_count),
                           dep_c.T[fmc, col], jnp.inf)
    drop_sorted = svalid_f & drop_c.T[fmc, col]
    farm_dep = jnp.full((n,), jnp.inf).at[s_fi].set(dep_sorted)
    farm_drop = jnp.zeros((n,), bool).at[s_fi].set(drop_sorted)
    qdrop = jnp.sum(farm_drop)
    acc = fvalid & ~farm_drop
    acc_m = jnp.zeros((m_count,), jnp.int64).at[
        jnp.where(acc, memb, m_count)].add(1, mode="drop")
    recv = acc_m > 0

    # -- 4) completion: sort-based dedup + per-bundle counts ---------------
    key = ((x["ev_lo"].astype(jnp.uint64) << 32)
           | (x["daq"].astype(jnp.uint64) << 16)
           | x["seg"].astype(jnp.uint64))
    nacc = (~acc).astype(jnp.uint32)
    s_na, s_key, s_dep, s_lidx = jax.lax.sort(
        (nacc, key, farm_dep, x["lidx"]), num_keys=2)
    s_acc = s_na == 0
    same = jnp.concatenate([jnp.zeros((1,), bool),
                            (s_key[1:] == s_key[:-1])
                            & s_acc[1:] & s_acc[:-1]])
    uniq = s_acc & ~same
    tri = jnp.cumsum(uniq.astype(i32)) - 1
    # first-served copy of a segment = the copy with the minimal departure
    # (FIFO per member: service completions are nondecreasing in arrival
    # order) — exactly the host's dedup-in-service-order rule
    tri_min = jnp.full((n,), jnp.inf).at[
        jnp.where(s_acc, tri, n)].min(s_dep, mode="drop")
    val = tri_min[jnp.clip(tri, 0, n - 1)]
    cnt_b = jnp.zeros((g_count,), i32).at[
        jnp.where(uniq, s_lidx, g_count)].add(1, mode="drop")
    tdone_raw = jnp.full((g_count,), -jnp.inf).at[
        jnp.where(uniq, s_lidx, g_count)].max(val, mode="drop")
    dups = jnp.sum(s_acc.astype(jnp.int64)) - jnp.sum(uniq.astype(jnp.int64))
    done_b = (cnt_b == x["nseg_b"]) & (cnt_b > 0)
    any_b = cnt_b > 0
    t_done_b = jnp.where(done_b, tdone_raw, 0.0)
    mem_b = jnp.full((g_count,), -1, i32).at[
        jnp.where(valid, x["lidx"], g_count)].max(memb, mode="drop")
    new_pend = jnp.zeros((m_count,), i32).at[
        jnp.where(any_b & ~done_b, jnp.clip(mem_b, 0, m_count - 1),
                  m_count)].add(1, mode="drop")

    # -- 5) reassembly-timeout buckets (BatchReassembler aging) ------------
    # buckets[m, j] = pending groups that have survived j member-pushes; a
    # push shifts, expires slot A-1 and admits this window's new groups
    buckets = carry["buckets"]
    timed = jnp.sum(jnp.where(recv, buckets[:, -1], 0).astype(jnp.int64))
    shifted = jnp.concatenate([new_pend[:, None], buckets[:, :-1]], axis=1)
    buckets = jnp.where(recv[:, None], shifted, buckets)
    pend_m = jnp.sum(buckets, axis=1)

    # -- 6) measured telemetry at the window boundary ----------------------
    w_dec = jnp.maximum(farm_w - jnp.maximum(x["wend"] - farm_t, 0.0), 0.0)
    fill_farm = w_dec / params["cap_s"]
    backlog_q = jnp.rint(fill_farm * params["cap_pkts"])  # host round() is
    backlog = jnp.maximum(backlog_q, pend_m.astype(f64))  # banker's too
    fill_t = jnp.minimum(1.0, backlog / params["cap_div"])

    # -- 7) proportional-PI policy + finalize (policy._prop update) --------
    err = params["target"] - fill_t
    integ_new = jnp.clip(carry["integral"] + params["ki"] * err, -1.0, 1.0)
    factor = 1.0 + params["kp"] * err + integ_new
    grow = carry["weights"] * jnp.maximum(factor, 0.1)
    mean = _np_sum(grow, m_count) / float(m_count)
    wfin = jnp.clip(grow / jnp.maximum(mean, 1e-9),
                    params["min_w"], params["max_w"])
    upd = x["reweight"] & x["win_valid"]
    integral = jnp.where(upd, integ_new, carry["integral"])
    weights = jnp.where(upd, wfin, carry["weights"])

    # -- 8) hysteresis + masked epoch switch -------------------------------
    past = x["cur_event"] >= carry["cur_start"]
    delta = jnp.any(jnp.abs(wfin - carry["sched_w"]) / carry["sched_w"]
                    > params["rw_thresh"])
    do_sw = upd & past & delta

    def switch(op):
        ring_hi, ring_lo, ring_cal, _, _ = op
        boundary = jnp.maximum(x["cur_event"] + params["horizon"],
                               carry["cur_start"] + 1)
        cal = _device_calendar(wfin, ring_cal.shape[1])
        ring_hi = jnp.concatenate(
            [ring_hi[1:], (boundary >> 32).astype(jnp.uint32)[None]])
        ring_lo = jnp.concatenate(
            [ring_lo[1:], (boundary & 0xFFFFFFFF).astype(jnp.uint32)[None]])
        ring_cal = jnp.concatenate([ring_cal[1:], cal[None]], axis=0)
        return ring_hi, ring_lo, ring_cal, boundary, wfin

    ring_hi, ring_lo, ring_cal, cur_start, sched_w = jax.lax.cond(
        do_sw, switch, lambda op: op,
        (carry["ring_hi"], carry["ring_lo"], carry["ring_cal"],
         carry["cur_start"], carry["sched_w"]))

    new_carry = dict(dl_busy=dl_busy, farm_w=farm_w, farm_t=farm_t,
                     ring_hi=ring_hi, ring_lo=ring_lo, ring_cal=ring_cal,
                     cur_start=cur_start, weights=weights, integral=integral,
                     sched_w=sched_w, buckets=buckets)
    ys = dict(done_b=done_b, t_done_b=t_done_b, any_b=any_b, mem_b=mem_b,
              acc_m=acc_m, fill=fill_farm, weights=weights,
              dups=dups, timed=timed, qdrop=qdrop.astype(jnp.int64),
              invalid=invalid.astype(jnp.int64), switched=do_sw,
              # per-row stage times, returned unconditionally so tracing
              # never changes the program (FUSED_TRACES stays 1): spans are
              # materialized on host post-hoc from these masked arrays
              t_cn=t_cn, farm_dep=jnp.where(acc, farm_dep, 0.0),
              memb=mc, acc=acc)
    return new_carry, ys


def _superblock_impl(carry, xs, params):
    global FUSED_TRACES
    FUSED_TRACES += 1
    return jax.lax.scan(lambda c, x: _window_step(c, x, params), carry, xs)


_SUPERBLOCK = jax.jit(_superblock_impl, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class FusedEngine:
    """Runs one supported scenario end to end: host plant precompute, the
    jitted superblock scan, then numpy post-processing into a ``SimReport``
    identical (counters exactly, floats within fp tolerance) to the host
    engine's. Construct from an already-built ``Simulator``."""

    def __init__(self, sim, superblock: int = DEFAULT_SUPERBLOCK):
        self.sim = sim
        self.cfg = sim.cfg
        self.superblock = max(1, int(superblock))
        self.final_carry: Optional[dict] = None
        self.n_superblocks = 0

    # -- host plant precompute (control-independent randomness) ------------
    def _precompute(self):
        cfg, sim = self.cfg, self.sim
        W = cfg.steps
        G = cfg.triggers_per_step * cfg.n_daqs
        period = cfg.window_period_s(cfg.triggers_per_step)
        ml = cfg.member_link
        dl_seed = sim.member_links.seed
        rows, meta = [], []
        t_clock, dl_ctr = 0.0, 0
        packets_sent = packets_delivered = lost_dl = 0
        emit_all = np.zeros((W, G))
        nseg_all = np.zeros((W, G), np.int32)
        ev_all = np.zeros((W, G), np.uint64)
        daq_all = np.zeros((W, G), np.int32)
        for i in range(W):
            t0 = t_clock
            window_end = t0 + period
            t_clock = window_end
            bundles = sim.fleet.bundle_window(cfg.triggers_per_step)
            trigger_t = (t0 + np.arange(cfg.triggers_per_step)
                         * cfg.trigger_period_s * 1.0)
            emit_b = np.repeat(trigger_t, cfg.n_daqs)
            batch = segment_bundles(bundles, cfg.mtu_payload)
            packets_sent += len(batch)
            bundle_of_row = np.cumsum(batch.seg_index == 0) - 1
            wire = (batch.payload_len.astype(np.float64)
                    + HEADER_BYTES + SEG_HDR_BYTES + IP_UDP_BYTES)
            t_up, up_keep = sim.daq_uplinks.transit(
                batch.daq_id.astype(np.int64), emit_b[bundle_of_row], wire)
            rows_up = np.flatnonzero(up_keep)
            dlv = sim.wan.transit(t_up[rows_up], wire[rows_up])
            src = rows_up[dlv.src]
            n3 = len(src)
            packets_delivered += n3
            if n3:
                # the member links' own stream, advanced only on non-empty
                # windows (the host step returns before transit when nothing
                # arrived) — loss/jitter identical to LinkSet.transit
                keep, _d, jit_u, _e = draw_window(
                    dl_seed, dl_ctr, n3, loss_prob=float(ml.loss_prob),
                    duplicate_prob=0.0, jitter_scale=1.0)
                dl_ctr += 1
                jadd = jit_u * float(ml.jitter_s)
                lost_dl += int((~keep).sum())
            else:
                keep = np.zeros((0,), bool)
                jadd = np.zeros((0,))
            hi, lo = split64(batch.event_number[src])
            rows.append(dict(
                ev_hi=hi.astype(np.uint32), ev_lo=lo.astype(np.uint32),
                daq=batch.daq_id[src].astype(np.int32),
                seg=batch.seg_index[src].astype(np.int32),
                lidx=bundle_of_row[src].astype(np.int32),
                bytes=wire[src],
                t_out=dlv.t_arrive + cfg.lb_latency_s,
                keep=keep, jadd=jadd,
                # host-side stage boundaries for the trace materializer
                # (never shipped to device)
                t_emit=emit_b[bundle_of_row][src], t_up=t_up[src],
                t_lb=dlv.t_arrive, sent=len(batch)))
            nseg_b = np.zeros((G,), np.int32)
            nseg_b[bundle_of_row] = batch.n_segs
            ev_all[i][bundle_of_row] = batch.event_number
            daq_all[i][bundle_of_row] = batch.daq_id
            emit_all[i] = emit_b
            nseg_all[i] = nseg_b
            reweight = (not cfg.frozen_weights and cfg.reweight_every
                        and (i + 1) % cfg.reweight_every == 0)
            meta.append(dict(nseg_b=nseg_b, reweight=bool(reweight),
                             win_valid=True, t0=t0, wend=window_end,
                             cur_event=sim.fleet.event_number))
        npad = next_pow2(max((len(r["ev_hi"]) for r in rows), default=1))
        return dict(rows=rows, meta=meta, npad=npad, G=G, W=W,
                    packets_sent=packets_sent,
                    packets_delivered=packets_delivered, lost_dl=lost_dl,
                    sim_time=t_clock, emit=emit_all, nseg=nseg_all,
                    ev=ev_all, daq=daq_all)

    def _stack_xs(self, plant):
        """Pad rows to one global Npad and windows to a whole number of
        superblocks (padding windows are exact carry no-ops), then stack."""
        npad, K = plant["npad"], self.superblock
        W, G = plant["W"], plant["G"]
        Wp = ((W + K - 1) // K) * K
        spec = [("ev_hi", np.uint32), ("ev_lo", np.uint32),
                ("daq", np.int32), ("seg", np.int32), ("lidx", np.int32),
                ("bytes", np.float64), ("t_out", np.float64),
                ("keep", bool), ("jadd", np.float64)]
        xs = {k: np.zeros((Wp, npad), dt) for k, dt in spec}
        xs["valid"] = np.zeros((Wp, npad), bool)
        xs["nseg_b"] = np.zeros((Wp, G), np.int32)
        xs["reweight"] = np.zeros((Wp,), bool)
        xs["win_valid"] = np.zeros((Wp,), bool)
        xs["wend"] = np.zeros((Wp,))
        xs["cur_event"] = np.zeros((Wp,), np.int64)
        for i, (r, mt) in enumerate(zip(plant["rows"], plant["meta"])):
            n3 = len(r["ev_hi"])
            for k, _ in spec:
                xs[k][i, :n3] = r[k]
            xs["valid"][i, :n3] = True
            xs["nseg_b"][i] = mt["nseg_b"]
            xs["reweight"][i] = mt["reweight"]
            xs["win_valid"][i] = mt["win_valid"]
            xs["wend"][i] = mt["wend"]
            xs["cur_event"][i] = mt["cur_event"]
        return xs, Wp

    def _initial_carry(self):
        cfg = self.cfg
        M = cfg.n_members
        cal0 = build_calendar(np.arange(M, dtype=np.int32), np.ones((M,)),
                              n_slots=CALENDAR_SLOTS)
        # all ring entries start as (start 0, epoch-0 calendar): starts stay
        # sorted ascending across shift-appends, and "newest start <= event"
        # always picks the live epoch — duplicated oldest rows are harmless
        return dict(
            dl_busy=np.full((M,), -np.inf),
            farm_w=np.zeros((M,)), farm_t=np.zeros((M,)),
            ring_hi=np.zeros((_RING,), np.uint32),
            ring_lo=np.zeros((_RING,), np.uint32),
            ring_cal=np.tile(cal0.astype(np.int32), (_RING, 1)),
            cur_start=np.int64(0),
            weights=np.ones((M,)), integral=np.zeros((M,)),
            sched_w=np.ones((M,)),
            buckets=np.zeros((M, cfg.timeout_windows), np.int32))

    def _params(self):
        cfg = self.cfg
        farm = self.sim.farm.cfg
        return dict(
            per_pkt=farm.per_packet_s, per_byte=farm.per_byte_s,
            cap_s=farm.capacity_s,
            link_rate=np.float64(cfg.member_link.rate_Bps),
            dl_prop=np.float64(cfg.member_link.prop_delay_s),
            target=np.float64(0.5), kp=np.float64(0.5), ki=np.float64(0.1),
            min_w=np.float64(0.05), max_w=np.float64(8.0),
            cap_pkts=np.float64(cfg.queue_capacity_pkts),
            cap_div=np.float64(max(cfg.queue_capacity_pkts, 1)),
            horizon=np.int64(max(16, 8 * cfg.triggers_per_step)),
            rw_thresh=np.float64(0.05))

    def _run_device(self, xs, Wp):
        global FUSED_STEP_CALLS
        K = self.superblock
        with enable_x64():
            carry = {k: jnp.asarray(v) for k, v in self._initial_carry().items()}
            params = {k: jnp.asarray(v) for k, v in self._params().items()}
            chunks = []
            for s in range(0, Wp, K):
                blk = {k: jnp.asarray(v[s:s + K]) for k, v in xs.items()}
                carry, ys = _SUPERBLOCK(carry, blk, params)
                FUSED_STEP_CALLS += 1
                self.n_superblocks += 1
                chunks.append(jax.device_get(ys))
            self.final_carry = jax.device_get(carry)
        return {k: np.concatenate([c[k] for c in chunks]) for k in chunks[0]}

    def state_digest(self) -> tuple:
        """Cross-superblock carry state, hashable — the property test
        asserts K=1 and K=8 splits land on identical digests."""
        fc = self.final_carry
        assert fc is not None, "run() first"
        return tuple(
            (k, np.asarray(fc[k]).tobytes()) for k in sorted(fc))

    # -- accounting replication (host dict bookkeeping, vectorized) --------
    def _vanished(self, plant, ys):
        """Replicates ``Simulator._purge_vanished``: a bundle's emit entry
        is popped at completion, at reassembly timeout (the timeout-th push
        of its member after entry), or counted vanished at the first purge
        step past the horizon that finds it still tracked."""
        cfg = self.cfg
        W, G, M = plant["W"], plant["G"], cfg.n_members
        T = cfg.timeout_windows
        horizon = max(4 * (T or 1), 64)
        done = ys["done_b"][:W]
        anyb = ys["any_b"][:W]
        memb = ys["mem_b"][:W]
        recv = np.asarray(ys["acc_m"][:W]) > 0
        big = np.iinfo(np.int64).max
        pop = np.full((W, G), big)
        wcol = np.repeat(np.arange(W)[:, None], G, axis=1)
        pop[done] = wcol[done]
        pend = anyb & ~done
        for m in range(M):
            rw = np.flatnonzero(recv[:, m])
            if len(rw) == 0:
                continue
            pos_of = np.full((W,), -1, np.int64)
            pos_of[rw] = np.arange(len(rw))
            sel = pend & (memb == m)
            ws, gs = np.nonzero(sel)
            if len(ws) == 0:
                continue
            tgt = pos_of[ws] + T
            has = tgt < len(rw)
            pop[ws, gs] = np.where(has, rw[np.minimum(tgt, len(rw) - 1)], big)
        vanished = 0
        alive = np.ones((W, G), bool)
        for P in range(31, W, 32):
            q = alive & (wcol < P - horizon) & (pop > P)
            vanished += int(q.sum())
            alive &= ~q
        return vanished

    # -- host-side observation replay (tracing + live metrics) --------------
    def _trace_window(self, tb, w, plant, ys, sel, pid0: int) -> int:
        """Materialize one window's spans from the plant's host-side stage
        boundaries plus the device scan's returned per-row arrays — the
        identical span set the host engine records inline (parity-tested)."""
        from repro.telemetry.trace import bundle_key
        r, mt = plant["rows"][w], plant["meta"][w]
        key_b = bundle_key(plant["ev"][w], plant["daq"][w])
        tb.record_window("emit_wait", key_b, mt["t0"], plant["emit"][w])
        n3 = len(r["ev_hi"])
        if n3:
            ev_row = ((r["ev_hi"].astype(np.uint64) << np.uint64(32))
                      | r["ev_lo"].astype(np.uint64))
            key_r = bundle_key(ev_row, r["daq"])
            pid_r = np.uint64(pid0) + np.arange(n3, dtype=np.uint64)
            tb.record_window("uplink", key_r, r["t_emit"], r["t_up"],
                             pid=pid_r)
            tb.record_window("wan", key_r, r["t_up"], r["t_lb"], pid=pid_r)
            tb.record_window("lb", key_r, r["t_lb"], r["t_out"], pid=pid_r)
            memb = ys["memb"][w, :n3].astype(np.int64)
            keep = r["keep"]
            t_cn = ys["t_cn"][w, :n3]
            tb.record_window("downlink", key_r[keep], r["t_out"][keep],
                             t_cn[keep], pid=pid_r[keep], aux=memb[keep])
            acc = np.asarray(ys["acc"][w, :n3])
            dep = ys["farm_dep"][w, :n3]
            m_acc = memb[acc]
            fc = self.sim.farm.cfg
            svc = fc.per_packet_s[m_acc] + r["bytes"][acc] * fc.per_byte_s[m_acc]
            tb.record_window("farm_wait", key_r[acc], t_cn[acc],
                             dep[acc] - svc, pid=pid_r[acc], aux=m_acc)
            tb.record_window("service", key_r[acc], dep[acc] - svc, dep[acc],
                             pid=pid_r[acc], aux=m_acc)
            if len(sel):
                keys_done = bundle_key(plant["ev"][w, sel],
                                       plant["daq"][w, sel])
                rmin = np.full((plant["G"],), np.inf)
                np.minimum.at(rmin, r["lidx"][acc], dep[acc])
                t_done = ys["t_done_b"][w, sel]
                tb.record_window("reassembly", keys_done, rmin[sel], t_done)
                tb.complete_window(keys_done, plant["emit"][w, sel], t_done)
        return pid0 + n3

    def _observe(self, plant, xs, ys, sels) -> None:
        """Replay the host engine's per-window observation — trace spans
        and ``_emit_metrics`` (same registry updates, same JSONL rows, same
        virtual timestamps) — from the superblock's returned arrays."""
        sim = self.sim
        tb = sim.trace
        W = plant["W"]
        pid0 = 0
        cum_sent = cum_dlv = cum_sw = 0
        for w in range(W):
            sel = sels[w]
            if tb is not None:
                pid0 = self._trace_window(tb, w, plant, ys, sel, pid0)
                tb.end_window()
            r = plant["rows"][w]
            cum_sent += r["sent"]
            cum_dlv += len(r["ev_hi"])
            cum_sw += int(ys["switched"][w])
            if len(sel):
                new = (ys["t_done_b"][w, sel]
                       - plant["emit"][w, sel]).tolist()
                sim.latencies.extend(new)
                if tb is not None:
                    from repro.telemetry.trace import bundle_key
                    keys = bundle_key(plant["ev"][w, sel],
                                      plant["daq"][w, sel])
                    sim._lat_keys.extend(int(k) for k in keys)
            if sim.metrics is not None:
                sim.packets_sent = cum_sent
                sim.packets_delivered = cum_dlv
                sim.epoch_switches = cum_sw
                sim.bundles_sent = plant["G"] * (w + 1)
                sim.clock.advance_to(float(plant["meta"][w]["wend"]))
                sim._emit_metrics(w, np.asarray(ys["fill"][w]))
        if sim._ts_writer is not None:
            sim._ts_writer.close()

    def run(self):
        from repro.simnet.sim import SimReport

        t_wall = time.perf_counter()
        cfg, sim = self.cfg, self.sim
        plant = self._precompute()
        xs, Wp = self._stack_xs(plant)
        ys = self._run_device(xs, Wp)
        W, G, M = plant["W"], plant["G"], cfg.n_members

        # latencies in the host's append order: window, then member
        # ascending, then (event, daq) ascending within the member
        lats = []
        sels = []
        done = ys["done_b"][:W]
        for w in range(W):
            d = np.flatnonzero(done[w])
            if len(d) == 0:
                sels.append(d)
                continue
            order = np.lexsort((plant["daq"][w, d], plant["ev"][w, d],
                                ys["mem_b"][w, d]))
            sel = d[order]
            sels.append(sel)
            lats.extend((ys["t_done_b"][w, sel]
                         - plant["emit"][w, sel]).tolist())
        lat = np.asarray(lats)
        if sim.trace is not None or sim.metrics is not None:
            self._observe(plant, xs, ys, sels)
        completed = len(lats)
        pending = int(self.final_carry["buckets"].sum())
        timed_out = int(ys["timed"][:W].sum())
        dups = int(ys["dups"][:W].sum())
        qdrop = int(ys["qdrop"][:W].sum())
        discarded = int(ys["invalid"][:W].sum())
        vanished = self._vanished(plant, ys)
        bundles_sent = W * G

        acc_tot = np.asarray(ys["acc_m"][:W]).sum(axis=0)
        per_member = {int(m): int(acc_tot[m]) for m in range(M)
                      if acc_tot[m] > 0}
        trajectory = [
            (w, {m: round(float(ys["weights"][w, m]), 4) for m in range(M)})
            for w in range(W) if xs["reweight"][w]]
        fill_trace = [
            (float(xs["wend"][w]),
             [round(float(f), 4) for f in ys["fill"][w]])
            for w in range(W)]
        weights = {str(m): round(float(self.final_carry["weights"][m]), 4)
                   for m in range(M)}

        violations = []
        # split events / corrupt bundles are impossible by construction in
        # fused scope: every segment of a bundle shares its event number
        # (one member), is emitted in one window and payloads are never
        # touched after segmentation — asserted against the host oracle in
        # tests/test_fused.py
        lost_wan = sim.wan.n_lost + sim.daq_uplinks.n_lost
        lossless = (lost_wan == 0 and plant["lost_dl"] == 0
                    and qdrop == 0 and discarded == 0)
        if lossless and completed + pending + timed_out < bundles_sent:
            violations.append("bundles unaccounted with zero loss")

        wall = time.perf_counter() - t_wall
        return SimReport(
            scenario=sim.scenario.name if sim.scenario else "custom",
            steps=cfg.steps,
            sim_time_s=plant["sim_time"],
            wall_s=wall,
            packets_sent=plant["packets_sent"],
            packets_delivered=plant["packets_delivered"],
            packets_lost_wan=lost_wan,
            packets_lost_downlink=plant["lost_dl"],
            packets_dropped_queue=qdrop,
            packets_discarded_invalid=discarded,
            duplicates_absorbed=dups,
            bundles_sent=bundles_sent,
            bundles_completed=completed,
            bundles_pending=pending,
            bundles_timed_out=timed_out,
            bundles_vanished=vanished,
            latency_p50_s=float(np.percentile(lat, 50)) if completed else 0.0,
            latency_p99_s=float(np.percentile(lat, 99)) if completed else 0.0,
            latency_max_s=float(lat.max()) if completed else 0.0,
            latency_mean_s=float(lat.mean()) if completed else 0.0,
            epoch_switches=int(ys["switched"][:W].sum()),
            final_weights=weights,
            weight_trajectory=trajectory,
            queue_fill_trace=fill_trace,
            per_member_segments=per_member,
            violations=violations,
            engine="fused",
        )
