"""Scenario library: named workloads for the virtual-time simulator.

Each scenario is a ``Scenario`` preset — config overrides plus live hooks
(traffic shaping, per-trigger size boosts, mid-run link mutation). The
stress shapes follow the load-balancing literature the repro tracks:
elephant-vs-mice flows and burst arrivals (RDNA Balance, arXiv:1904.05664),
in-network steering for heterogeneous scientific farms (arXiv:2009.02457),
and the paper's own straggler / multi-instance cases (fig. 7c, §I-C).

``expect_cp_gain`` marks scenarios where the closed loop must measurably
beat a frozen-weights control run on p99 latency — run_simnet's
``--compare-frozen`` turns that into a hard check.
"""
from __future__ import annotations

import numpy as np

from repro.simnet.links import LinkConfig
from repro.simnet.sim import Scenario


def _straggler_scale(n_members: int) -> np.ndarray:
    s = np.ones((n_members,))
    s[0] = 4.0  # member 0 runs 4x slow — what the CP must detect and shed
    return s


def _hetero_scale(n_members: int) -> np.ndarray:
    # deterministic spread of relative speeds, shuffled so the slow nodes
    # aren't adjacent calendar slots
    s = np.geomspace(0.7, 2.4, n_members)
    return s[np.random.default_rng(7).permutation(n_members)]


def _elephant_scale(n_members: int) -> np.ndarray:
    s = np.geomspace(0.8, 2.2, n_members)
    return s[np.random.default_rng(3).permutation(n_members)]


def _burst_traffic(step: int, cfg) -> tuple[int, float]:
    """Every 6th window: 4x the triggers compressed into the same span —
    a 4x instantaneous arrival-rate burst, mean load unchanged elsewhere."""
    if step % 6 == 0:
        return 4 * cfg.triggers_per_step, 0.25
    return cfg.triggers_per_step, 1.0


def _elephant_boost(rng: np.random.Generator, event_number: int) -> float:
    """Heavy-tailed trigger sizes: ~5% of triggers are 10x elephants."""
    return 10.0 if rng.random() < 0.05 else 1.0


def _flap_link(sim, step: int) -> None:
    """Member 0's downlink degrades 20x for the middle third of the run."""
    lo, hi = sim.cfg.steps // 3, (2 * sim.cfg.steps) // 3
    nominal = sim.cfg.member_link.rate_Bps
    sim.member_links.rate_Bps[0] = (nominal / 20.0 if lo <= step < hi
                                    else nominal)


def _lease_churn(sim, step: int) -> None:
    """Member 1's CN daemon goes silent for the middle third: its lease
    lapses at the daemon (-> the mark_failed hit-less drain), then it comes
    back and must *re-register* to rejoin the calendar."""
    lo, hi = sim.cfg.steps // 3, (2 * sim.cfg.steps) // 3
    if step == lo:
        sim.muted.add(1)
    elif step == hi:
        sim.muted.discard(1)
        sim.reregister(1)


def _restart_daemon_mid_run(sim, step: int) -> None:
    """Kill the control daemon halfway and recover it from the journal —
    calendars must come back byte-identical (state_digest audit) and the
    plant must not notice (no accounting violations)."""
    if step == sim.cfg.steps // 2:
        sim.restart_daemon()


def _leader_failover(sim, step: int) -> None:
    """The HA chaos script: mute a CN so its lease is mid-drain (epoch
    switches in flight), then SIGKILL the controld leader two windows
    later — the warm standby must take over within ~one lease term,
    resume byte-identical, and finish the drain; the CN re-registers
    against the *successor* in the final third."""
    lo, hi = sim.cfg.steps // 3, (2 * sim.cfg.steps) // 3
    if step == lo:
        sim.muted.add(1)
    elif step == lo + 2:
        sim.kill_leader()
    elif step == hi:
        sim.muted.discard(1)
        sim.reregister(1)


SCENARIOS: dict[str, Scenario] = {
    "baseline": Scenario(
        name="baseline",
        description="clean links, homogeneous farm, steady traffic",
    ),
    "burst": Scenario(
        name="burst",
        description="periodic 4x arrival-rate bursts (mice stampedes)",
        traffic=_burst_traffic,
    ),
    "elephant": Scenario(
        name="elephant",
        description="10x elephant triggers over a heterogeneous farm: "
                    "static weights drown the slow members in elephants "
                    "(drops + timeouts); measured-occupancy feedback "
                    "re-shares and keeps the tail bounded",
        expect_cp_gain=True,
        trigger_boost=_elephant_boost,
        service_scale=_elephant_scale,
        overrides=dict(queue_capacity_s=0.5, timeout_windows=60,
                       reweight_every=3),
    ),
    "straggler": Scenario(
        name="straggler",
        description="member 0 serves 4x slow; CP must shed its weight",
        expect_cp_gain=True,
        service_scale=_straggler_scale,
        overrides=dict(timeout_windows=30, reweight_every=3),
    ),
    "hetero_farm": Scenario(
        name="hetero_farm",
        description="per-member service rates spread 0.7x-2.4x",
        service_scale=_hetero_scale,
        overrides=dict(timeout_windows=30),
    ),
    "link_flap": Scenario(
        name="link_flap",
        description="member 0 downlink degrades 20x for the middle third",
        on_step=_flap_link,
        overrides=dict(timeout_windows=30),
    ),
    "correlated_loss": Scenario(
        name="correlated_loss",
        description="Gilbert-Elliott burst loss on the WAN hop",
        overrides=dict(
            wan=LinkConfig(prop_delay_s=1e-3, jitter_s=2e-4,
                           p_good_to_bad=0.02, p_bad_to_good=0.25,
                           bad_loss_prob=0.5),
            timeout_windows=12,
        ),
    ),
    "multi_instance": Scenario(
        name="multi_instance",
        description="2 virtual LB instances partition DAQs and the farm",
        overrides=dict(n_instances=2, n_daqs=4, n_members=8),
    ),
    # -- controld scenarios: the CP is a session service (DESIGN.md §Controld)
    "lease_churn": Scenario(
        name="lease_churn",
        description="a CN daemon goes silent mid-run: its lease lapses "
                    "(hit-less drain, bundles accounted), then it "
                    "re-registers and rejoins the calendar",
        on_step=_lease_churn,
        overrides=dict(controld=True, timeout_windows=30, reweight_every=2,
                       lease_s=None),
    ),
    "cp_restart": Scenario(
        name="cp_restart",
        description="control daemon killed mid-run and recovered from the "
                    "event-sourced journal; calendars byte-identical, "
                    "traffic unaffected",
        on_step=_restart_daemon_mid_run,
        overrides=dict(controld=True, timeout_windows=30, reweight_every=3),
    ),
    "leader_failover": Scenario(
        name="leader_failover",
        description="controld leader SIGKILLed mid-run, under load, while "
                    "a CN lease is draining: the WAL-shipped warm standby "
                    "promotes within ~one lease term (client-driven, "
                    "idempotent resend), resumes byte-identical, and the "
                    "plant keeps forwarding on the programmed tables — "
                    "gated on takeover time, resume digest, and zero lost "
                    "bundles (DESIGN.md §Controld-HA)",
        on_step=_leader_failover,
        overrides=dict(controld=True, ha=True, timeout_windows=30,
                       reweight_every=2),
    ),
    "farm_1k": Scenario(
        name="farm_1k",
        description="1024-member farm across 4 virtual LB instances, every "
                    "CN a controld client: 1024 heartbeats/window travel as "
                    "4 SendStateBatch frames and each tick is one fused "
                    "policy update per reservation (control-plane scaling "
                    "smoke; 256 members/instance fits the 512-slot calendar)",
        overrides=dict(controld=True, n_members=1024, n_instances=4,
                       n_daqs=8, triggers_per_step=8, reweight_every=2,
                       timeout_windows=30, queue_capacity_s=0.5),
    ),
    "multi_tenant": Scenario(
        name="multi_tenant",
        description="2 reservations on one daemon: tenant 0 runs the "
                    "proportional policy, tenant 1 the PID fill controller",
        overrides=dict(controld=True, n_instances=2, n_daqs=4, n_members=8,
                       controld_policy=("proportional", "pid"),
                       timeout_windows=30),
    ),
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; have {sorted(SCENARIOS)}") from None
