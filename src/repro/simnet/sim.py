"""The virtual-time simulator: DAQ -> links -> LB -> farm queues -> CP loop.

Every packet carries a timestamp from DAQ emission through uplink/WAN
serialization (``simnet.links``), the LB's fixed-latency routing hop
(``DataPlane.route_window`` — the *same* routing engine as production), the
per-member downlink, and the CN's bounded receive queue (``simnet.queues``).
End-to-end latency per bundle = service completion of its last segment minus
emission — the paper's fig. 7 metric, measured instead of assumed.

The control loop runs on simulated time: ``TelemetryHub`` gets the virtual
clock injected and consumes *measured* queue occupancy
(``FarmQueues.fill``), and ``LoadBalancerControlPlane.feedback`` closes the
loop at the simulated reweight cadence. ``frozen_weights=True`` disables
feedback — the control run that quantifies what the CP buys (run_simnet's
``--compare-frozen``).

Multi-instance (paper §I-C): ``n_instances > 1`` stacks per-instance tables
(``DataPlane.from_instances``), partitions the farm and the DAQs across
instances, and runs one control plane per instance — same fused routing
pass, per-packet ``instance_id``.

Everything is struct-of-arrays; per-window work is array programs plus
O(n_members) bookkeeping. No per-packet Python loop anywhere on the hot
path (DESIGN.md §SimNet).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Callable, Optional

import numpy as np

from repro.core.control_plane import LoadBalancerControlPlane
from repro.core.dataplane import DataPlane, DataPlaneCache
from repro.core.epoch import EpochManager
from repro.core.protocol import HEADER_BYTES
from repro.core.tables import MemberSpec
from repro.data.daq import DAQConfig, DAQFleet
from repro.data.segmentation import SEG_HDR_BYTES, group_rows, segment_bundles
from repro.simnet.clock import VirtualClock
from repro.simnet.links import Link, LinkConfig, LinkSet
from repro.simnet.queues import FarmConfig, FarmQueues
from repro.telemetry.metrics import TelemetryHub

IP_UDP_BYTES = 28  # IP(20) + UDP(8), matching protocol.MAX_SEGMENT_PAYLOAD


@dataclasses.dataclass
class SimConfig:
    """One simulation's shape. Scenario presets override fields of this."""

    steps: int = 100
    n_members: int = 8
    n_daqs: int = 3
    n_instances: int = 1
    triggers_per_step: int = 4
    trigger_period_s: float = 1e-3
    mean_bundle_bytes: int = 12_000
    mtu_payload: int = 2048
    seed: int = 0

    # LB data plane (paper §IV: fixed sub-4us pipeline latency)
    backend: str = "auto"
    lb_latency_s: float = 4e-6

    # run engine: "fused" = the device-resident closed loop (simnet.fused;
    # one jitted superblock program per K windows), with a transparent
    # fallback to "host" for configs/scenarios outside its scope; "host" =
    # the per-window Python loop below (the parity oracle).
    engine: str = "fused"

    # links
    daq_uplink: LinkConfig = dataclasses.field(
        default_factory=lambda: LinkConfig(rate_Bps=100e6, jitter_s=2e-5))
    wan: LinkConfig = dataclasses.field(
        default_factory=lambda: LinkConfig(prop_delay_s=1e-3, jitter_s=2e-4))
    member_link: LinkConfig = dataclasses.field(
        default_factory=lambda: LinkConfig(rate_Bps=50e6, prop_delay_s=5e-5,
                                           jitter_s=2e-5))

    # farm service model
    service_per_packet_s: float = 2e-5
    service_per_byte_s: float = 1.25e-7      # = 8 MB/s per member
    queue_capacity_s: float = 0.05
    service_scale: Optional[np.ndarray] = None   # [M] relative slowness
    queue_engine: str = "np"

    # control loop
    reweight_every: int = 5
    frozen_weights: bool = False
    timeout_windows: int = 8
    stale_after_s: Optional[float] = None
    queue_capacity_pkts: int = 32            # telemetry backlog granularity

    # controld mode: CNs are *clients* of a session-oriented control daemon
    # (repro.controld) — register / heartbeat / lease lifecycle on the
    # virtual clock instead of the embedded per-instance feedback call.
    controld: bool = False
    controld_policy: object = "proportional"  # str, or one str per instance
    controld_policy_params: dict = dataclasses.field(default_factory=dict)
    lease_s: Optional[float] = None          # default: 10 nominal windows

    # controld HA mode (requires controld=True): the CP is an HACluster of
    # warm standbys behind a FailoverTransport whose backoff sleeps *advance
    # the virtual clock* — killing the leader (scenario hook or
    # ha_kill_every) fast-forwards sim time by ~one lease term while the
    # retrying client drives a standby's promotion (DESIGN.md §Controld-HA).
    ha: bool = False
    ha_nodes: int = 2
    ha_term_s: Optional[float] = None        # default: 6 nominal windows
    ha_kill_every: int = 0                   # soak leg: kill leader every N windows

    # observability: metrics_every > 0 enables a MetricsRegistry over the
    # run (E2E latency histogram, queue-fill gauges, window/packet totals)
    # and — when metrics_path is set — appends one JSONL time-series row
    # every that-many windows. Works on both engines: the fused engine
    # replays the identical emission from the superblock's returned arrays.
    metrics_every: int = 0
    metrics_path: Optional[str] = None

    # tracing: trace=True attaches a telemetry.trace.TraceBuffer — per-
    # bundle stage spans (head-sampled at trace_sample via mix64 on the
    # event number, plus a top-k tail reservoir of the slowest bundles).
    # Works on both engines; spans are engine-parity-tested.
    trace: bool = False
    trace_sample: float = 1.0
    trace_tail_k: int = 64

    def window_period_s(self, n_triggers: int, period_scale: float = 1.0) -> float:
        return n_triggers * self.trigger_period_s * period_scale


@dataclasses.dataclass
class SimReport:
    """What a run measured. ``to_dict`` is the JSON form run_simnet prints."""

    scenario: str
    steps: int
    sim_time_s: float
    wall_s: float
    packets_sent: int
    packets_delivered: int
    packets_lost_wan: int
    packets_lost_downlink: int
    packets_dropped_queue: int
    packets_discarded_invalid: int
    duplicates_absorbed: int
    bundles_sent: int
    bundles_completed: int
    bundles_pending: int
    bundles_timed_out: int
    bundles_vanished: int          # every segment lost before reassembly
    latency_p50_s: float
    latency_p99_s: float
    latency_max_s: float
    latency_mean_s: float
    epoch_switches: int
    final_weights: dict
    weight_trajectory: list        # [(step, {member: weight})]
    queue_fill_trace: list         # [(t, [fill per member])]
    per_member_segments: dict
    violations: list
    # controld-mode lifecycle accounting (zero in embedded-CP mode)
    daemon_restarts: int = 0
    leases_expired: int = 0
    heartbeats_rejected: int = 0
    engine: str = "host"           # which engine produced this report
    # HA-mode failover accounting (zero outside cfg.ha)
    ha_failovers: int = 0
    ha_revivals: int = 0
    ha_failover_durations: list = dataclasses.field(default_factory=list)

    @property
    def packets_per_sec(self) -> float:
        return self.packets_sent / self.wall_s if self.wall_s > 0 else 0.0

    def to_dict(self, with_traces: bool = False) -> dict:
        d = dataclasses.asdict(self)
        if not with_traces:
            d.pop("queue_fill_trace")
            d["weight_trajectory"] = d["weight_trajectory"][-3:]
        d["packets_per_sec"] = round(self.packets_per_sec, 1)
        for k, v in list(d.items()):
            if isinstance(v, float):
                d[k] = round(v, 9)
        return d


@dataclasses.dataclass
class Scenario:
    """A named preset: config overrides + live hooks (see scenarios.py)."""

    name: str
    description: str
    expect_cp_gain: bool = False
    overrides: dict = dataclasses.field(default_factory=dict)
    service_scale: Optional[Callable[[int], np.ndarray]] = None
    traffic: Optional[Callable[[int, "SimConfig"], tuple[int, float]]] = None
    # (rng, event_number) -> size multiplier for that trigger's bundles
    trigger_boost: Optional[Callable[[np.random.Generator, int], float]] = None
    on_step: Optional[Callable[["Simulator", int], None]] = None

    def build_config(self, **extra) -> SimConfig:
        cfg = SimConfig(**{**self.overrides, **extra})
        if self.service_scale is not None:
            cfg.service_scale = self.service_scale(cfg.n_members)
        return cfg


def _rss_bytes() -> float:
    """Current resident set size (Linux /proc; peak-RSS fallback)."""
    try:
        with open("/proc/self/statm") as f:
            import os
            return float(int(f.read().split()[1]) * os.sysconf("SC_PAGE_SIZE"))
    except (OSError, ValueError, IndexError):
        import resource
        return float(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
                     * 1024)


class Simulator:
    """Drives one scenario end to end on virtual time."""

    def __init__(self, cfg: SimConfig, scenario: Optional[Scenario] = None):
        if cfg.n_members % cfg.n_instances:
            raise ValueError("n_members must divide evenly across instances")
        if cfg.n_instances > 1 and cfg.n_daqs < cfg.n_instances:
            raise ValueError("need at least one DAQ per instance")
        self.cfg = cfg
        self.scenario = scenario
        self.clock = VirtualClock()
        self.rng = np.random.default_rng(cfg.seed)

        # -- per-bundle tracing (cfg.trace) — created before the control
        # plane so the daemon can record per-message spans into it
        self.trace = None
        self._trace_pid0 = 0           # delivered-row counter = packet pid
        self._lat_keys: list[int] = []  # bundle key per self.latencies entry
        if cfg.trace:
            from repro.telemetry.trace import TraceBuffer, TraceConfig
            self.trace = TraceBuffer(TraceConfig(
                head_rate=cfg.trace_sample, tail_k=cfg.trace_tail_k,
                seed=cfg.seed))

        # -- control planes (one per LB instance, paper §I-C) -----------------
        per_inst = cfg.n_members // cfg.n_instances
        self.instance_members: list[list[int]] = [
            list(range(i * per_inst, (i + 1) * per_inst))
            for i in range(cfg.n_instances)]
        self.daemon = None
        self.client = None
        self.tokens: list[str] = []
        self.muted: set[int] = set()          # members whose heartbeats stop
        self.daemon_restarts = 0
        self.restart_digest_mismatches = 0
        self.heartbeats_rejected = 0
        # HA-mode state (cfg.ha): the cluster, kill/promotion bookkeeping
        self.cluster = None
        self.ha_failovers = 0
        self.ha_revivals = 0
        self.ha_digest_mismatches = 0
        self.ha_failover_durations: list[float] = []
        self._ha_last_failover_s = 0.0
        self._ha_kill_t: Optional[float] = None
        self._ha_pre_kill_digest: Optional[str] = None
        if cfg.controld:
            self._start_controld()
        else:
            self.managers: list[EpochManager] = []
            self.cps: list[LoadBalancerControlPlane] = []
            for ids in self.instance_members:
                em = EpochManager(max_members=max(64, 4 * cfg.n_members))
                cp = LoadBalancerControlPlane(em)
                cp.policy.epoch_horizon = max(16, 8 * cfg.triggers_per_step)
                cp.start({m: MemberSpec(node_id=m, lane_bits=1) for m in ids})
                self.managers.append(em)
                self.cps.append(cp)
        self._dp_cache = DataPlaneCache(self.managers, backend=cfg.backend)

        # -- plant: DAQs, links, farm ----------------------------------------
        self.fleet = DAQFleet(DAQConfig(
            n_daqs=cfg.n_daqs, seq_len=32,
            mean_bundle_bytes=cfg.mean_bundle_bytes, seed=cfg.seed,
            token_payload=False))
        self.daq_uplinks = LinkSet([
            dataclasses.replace(cfg.daq_uplink, seed=cfg.seed + 101)
            for _ in range(cfg.n_daqs)])
        self.wan = Link(dataclasses.replace(cfg.wan, seed=cfg.seed + 211))
        self.member_links = LinkSet([
            dataclasses.replace(cfg.member_link, seed=cfg.seed + 307)
            for _ in range(cfg.n_members)])
        self.farm = FarmQueues(
            FarmConfig.uniform(cfg.n_members,
                               per_packet_s=cfg.service_per_packet_s,
                               per_byte_s=cfg.service_per_byte_s,
                               capacity_s=cfg.queue_capacity_s,
                               scale=cfg.service_scale),
            backend=cfg.queue_engine)

        # -- telemetry on the virtual clock ----------------------------------
        self.hub = TelemetryHub(queue_capacity=cfg.queue_capacity_pkts,
                                clock=self.clock.now,
                                stale_after=cfg.stale_after_s,
                                fill_mode="occupancy")
        self.reassemblers: dict[int, object] = {}
        self._reported_timeouts: dict[int, int] = defaultdict(int)

        # -- accounting --------------------------------------------------------
        self.emit_time: dict[tuple[int, int], float] = {}
        self.emit_step: dict[tuple[int, int], int] = {}
        self.bundles_vanished = 0
        self.latencies: list[float] = []
        self.event_members: dict[tuple[int, int], set[int]] = defaultdict(set)
        self.corrupt = 0
        self.discarded = 0
        self.packets_sent = 0
        self.packets_delivered = 0
        self.bundles_sent = 0
        self.epoch_switches = 0
        self.weight_trajectory: list[tuple[int, dict]] = []
        self.queue_fill_trace: list[tuple[float, list[float]]] = []
        self.per_member_segments: dict[int, int] = defaultdict(int)
        self._expected: dict[tuple[int, int], np.ndarray] = {}

        # -- live metrics (cfg.metrics_every > 0) -----------------------------
        self.metrics = None
        self._ts_writer = None
        self._lat_emitted = 0
        if cfg.metrics_every > 0:
            self._init_metrics()

    def _init_metrics(self) -> None:
        from repro.telemetry.export import TimeSeriesWriter
        from repro.telemetry.registry import MetricsRegistry
        reg = self.metrics = MetricsRegistry()
        self._lat_hist = reg.histogram(
            "simnet_e2e_latency_seconds",
            "Bundle end-to-end latency (emission -> last-segment service).")
        self._fill_mean = reg.gauge(
            "simnet_queue_fill_mean", "Mean farm queue fill this window.")
        self._fill_max = reg.gauge(
            "simnet_queue_fill_max", "Max farm queue fill this window.")
        self._windows = reg.counter(
            "simnet_windows_total", "Simulated windows completed.")
        # cumulative totals read straight off the simulator at scrape time
        reg.gauge("simnet_packets_sent",
                  "Segments emitted by the DAQ fleet."
                  ).set_function(lambda: self.packets_sent)
        reg.gauge("simnet_packets_delivered",
                  "Segments that survived uplink + WAN."
                  ).set_function(lambda: self.packets_delivered)
        reg.gauge("simnet_bundles_completed",
                  "Bundles fully reassembled."
                  ).set_function(lambda: len(self.latencies))
        reg.gauge("simnet_epoch_switches",
                  "Hit-less epoch switches scheduled by the control loop."
                  ).set_function(lambda: self.epoch_switches)
        # soak-trend gauges (scripts/analyze_soak.py slope-gates these):
        # pending state must stay bounded over a long run, RSS must not creep
        reg.gauge("simnet_bundles_pending",
                  "Bundles emitted but not yet reassembled or timed out "
                  "(in flight + awaiting segments)."
                  ).set_function(
                      lambda: self.bundles_sent - len(self.latencies)
                      - sum(ra.stats.n_timed_out_groups
                            for ra in self.reassemblers.values()))
        reg.gauge("process_rss_bytes",
                  "Resident set size at scrape time (soak growth gate; "
                  "machine state, excluded from engine-parity checks)."
                  ).set_function(_rss_bytes)
        if self.cluster is not None:
            # soak failover leg: analyze_soak gates bounded failover
            # duration and no post-failover RSS/pending slope change
            reg.gauge("controld_ha_failovers",
                      "Leader failovers completed so far."
                      ).set_function(lambda: float(self.ha_failovers))
            reg.gauge("controld_ha_last_failover_s",
                      "Duration of the most recent leader failover in sim "
                      "seconds (0 before the first)."
                      ).set_function(lambda: self._ha_last_failover_s)
        if self.cfg.metrics_path:
            self._ts_writer = TimeSeriesWriter(self.cfg.metrics_path, reg)

    def _emit_metrics(self, step_idx: int, fill) -> None:
        if self.metrics is None:
            return
        new = self.latencies[self._lat_emitted:]
        if new:
            self._lat_hist.observe_many(new)
            if self.trace is not None and self._lat_keys:
                from repro.telemetry.trace import trace_id
                keys = self._lat_keys[self._lat_emitted:]
                self._lat_hist.put_exemplars(
                    new, [trace_id(k) for k in keys])
            self._lat_emitted = len(self.latencies)
        self._windows.inc()
        self._fill_mean.set(float(np.mean(fill)))
        self._fill_max.set(float(np.max(fill)))
        if (self._ts_writer is not None
                and (step_idx + 1) % self.cfg.metrics_every == 0):
            self._ts_writer.write(step=step_idx,
                                  t_sim=round(self.clock.now(), 9))

    # -- controld mode: the CP is a *service* the CNs talk to ------------------
    def _lease_s(self) -> float:
        cfg = self.cfg
        if cfg.lease_s is not None:
            return cfg.lease_s
        base = 10.0 * cfg.window_period_s(cfg.triggers_per_step)
        if cfg.ha:
            # a CN lease must comfortably outlive a leader failover
            # (~1.25x the leadership term): the outage advances virtual
            # time, and a shorter CN lease would lapse farm-wide on
            # every takeover
            base = max(base, 2.5 * self._ha_term_s())
        return base

    def _ha_term_s(self) -> float:
        cfg = self.cfg
        return (cfg.ha_term_s if cfg.ha_term_s is not None
                else 6.0 * cfg.window_period_s(cfg.triggers_per_step))

    def _start_controld(self) -> None:
        """Stand up a ControlDaemon on the virtual clock; every CN registers
        as a client of its instance's reservation (one tenant per virtual LB
        instance) and will heartbeat at window boundaries. HA mode swaps the
        single daemon for an HACluster behind a FailoverTransport whose
        retry sleeps advance the virtual clock — a retrying heartbeat alone
        drives a standby's lease claim and promotion."""
        from repro.controld import (ControlDaemon, ControldClient,
                                    FailoverTransport, HACluster,
                                    InProcTransport, Journal, RetryPolicy)
        cfg = self.cfg
        if cfg.ha:
            term = self._ha_term_s()
            self.cluster = HACluster(
                n_nodes=cfg.ha_nodes, clock=self.clock.now, term_s=term,
                daemon_kwargs=dict(
                    n_instances=cfg.n_instances, lease_s=self._lease_s(),
                    epoch_horizon=max(16, 8 * cfg.triggers_per_step),
                    max_members=max(64, 4 * cfg.n_members)))
            # backoff well under the lease term so promotion overshoot is
            # a fraction of the 1.25x-term failover gate; sleeps advance
            # virtual time (the outage costs sim seconds, not wall time)
            retry = RetryPolicy(base_s=term / 16.0, cap_s=term / 8.0,
                                max_elapsed_s=60.0 * term, seed=cfg.seed)
            transport = FailoverTransport(
                self.cluster.client_endpoints(), retry=retry,
                sleep=self.clock.advance, clock=self.clock.now)
            client = ControldClient(transport, client_id=f"sim{cfg.seed}")
            daemon = self.cluster.leader().daemon
        else:
            daemon = ControlDaemon(
                n_instances=cfg.n_instances, clock=self.clock.now,
                lease_s=self._lease_s(),
                epoch_horizon=max(16, 8 * cfg.triggers_per_step),
                max_members=max(64, 4 * cfg.n_members),
                journal=Journal(), trace=self.trace)
            client = ControldClient(InProcTransport(daemon))
        policies = cfg.controld_policy
        if isinstance(policies, str):
            policies = [policies] * cfg.n_instances
        self.tokens = []
        for inst, ids in enumerate(self.instance_members):
            r = client.reserve(policy=policies[inst], instance_hint=inst,
                               policy_params=cfg.controld_policy_params)
            self.tokens.append(r["token"])
            # whole instance membership in one frame / one journal entry
            reg = client.register_batch(r["token"], ids, lane_bits=1)
            assert not reg["rejected"], reg["rejected"]
        client.tick(current_event=0)  # starts every session (epoch 0)
        self._bind_daemon(daemon, client)

    def _bind_daemon(self, daemon, client) -> None:
        self.daemon = daemon
        self.client = client
        sessions = [daemon.sessions[t] for t in self.tokens]
        self.managers = [s.manager for s in sessions]
        self.cps = [s.cp for s in sessions]

    def _instance_of(self, member: int) -> int:
        return member // (self.cfg.n_members // self.cfg.n_instances)

    def reregister(self, member: int) -> None:
        """A CN whose lease lapsed rejoins its reservation (scenario hook)."""
        self.client.register(self.tokens[self._instance_of(member)],
                             member_id=member, node_id=member, lane_bits=1)

    def restart_daemon(self) -> None:
        """Kill the daemon and recover a fresh one from its journal — the
        hit-less restart scenario. Reservation tokens survive (they are
        deterministic journal state); calendars must come back byte-identical
        (audited via state_digest -> a violation on mismatch)."""
        from repro.controld import ControlDaemon, ControldClient, InProcTransport
        assert self.daemon is not None, "restart_daemon needs controld mode"
        cfg = self.cfg
        digest = self.daemon.state_digest()
        recovered = ControlDaemon.recover(
            self.daemon.journal,
            n_instances=cfg.n_instances, clock=self.clock.now,
            lease_s=self._lease_s(),
            epoch_horizon=max(16, 8 * cfg.triggers_per_step),
            max_members=max(64, 4 * cfg.n_members), trace=self.trace)
        self.daemon_restarts += 1
        if recovered.state_digest() != digest:
            self.restart_digest_mismatches += 1
        self._bind_daemon(recovered, ControldClient(InProcTransport(recovered)))
        # recompile the routing tables from the recovered managers
        self._dp_cache = DataPlaneCache(self.managers, backend=cfg.backend)

    def kill_leader(self) -> None:
        """SIGKILL the HA leader (scenario hook / soak leg). Promotion is
        client-driven: this window's heartbeats retry against the standbys
        until the lease lapses and one claims it — ``_ha_after_window``
        then audits the takeover and rebinds the sim to the successor."""
        assert self.cluster is not None, "kill_leader needs controld HA mode"
        leader = self.cluster.leader()
        if leader is None:
            return  # previous kill still failing over
        self._ha_pre_kill_digest = leader.daemon.state_digest()
        self._ha_kill_t = self.clock.now()
        leader.kill()

    def _ha_after_window(self) -> None:
        """Detect a promotion that this window's client traffic drove:
        audit the successor's resume digest against the dead leader's last
        digest (byte-identical or a violation), record the failover
        duration, rebind managers/CPs/routing to the promoted daemon, and
        revive the corpse as a fresh standby (full-backlog catch-up)."""
        lead = self.cluster.leader()
        if lead is None or lead.daemon is self.daemon:
            return
        self.ha_failovers += 1
        dur = 0.0
        if self._ha_kill_t is not None and lead.promoted_at is not None:
            dur = lead.promoted_at - self._ha_kill_t
        self.ha_failover_durations.append(dur)
        self._ha_last_failover_s = dur
        lead.record_failover(dur)
        if (self._ha_pre_kill_digest is not None
                and lead.promoted_digest != self._ha_pre_kill_digest):
            self.ha_digest_mismatches += 1
        self._ha_kill_t = None
        self._ha_pre_kill_digest = None
        self._bind_daemon(lead.daemon, self.client)
        self._dp_cache = DataPlaneCache(self.managers,
                                        backend=self.cfg.backend)
        for node in self.cluster.nodes:
            if not node.alive:
                self.cluster.revive(node)
                self.ha_revivals += 1

    # -- data plane cache (rebuild only after an epoch-state change) ----------
    def dataplane(self) -> DataPlane:
        return self._dp_cache.get()

    def _reassembler(self, member: int):
        if member not in self.reassemblers:
            self.reassemblers[member] = self.dataplane().make_reassembler(
                mtu_payload=self.cfg.mtu_payload,
                timeout_windows=self.cfg.timeout_windows)
        return self.reassemblers[member]

    # -- one window ------------------------------------------------------------
    def step(self, step_idx: int) -> None:
        cfg = self.cfg
        if self.scenario is not None and self.scenario.on_step is not None:
            self.scenario.on_step(self, step_idx)

        n_triggers, period_scale = cfg.triggers_per_step, 1.0
        if self.scenario is not None and self.scenario.traffic is not None:
            n_triggers, period_scale = self.scenario.traffic(step_idx, cfg)
        t0 = self.clock.now()
        window_end = t0 + cfg.window_period_s(n_triggers, period_scale)

        # -- DAQ emission (per-trigger timestamps) ----------------------------
        bundles = self.fleet.bundle_window(n_triggers)
        if self.scenario is not None and self.scenario.trigger_boost is not None:
            boosts = [self.scenario.trigger_boost(
                self.rng, bundles[k * cfg.n_daqs].event_number)
                for k in range(n_triggers)]
            for i, b in enumerate(bundles):
                f = boosts[i // cfg.n_daqs]
                if f > 1.0:
                    b.payload = np.resize(b.payload, int(len(b.payload) * f))
        self.bundles_sent += len(bundles)
        trigger_t = t0 + np.arange(n_triggers) * cfg.trigger_period_s * period_scale
        emit_b = np.repeat(trigger_t, cfg.n_daqs)
        for b, t in zip(bundles, emit_b):
            self.emit_time[(b.event_number, b.daq_id)] = float(t)
            self.emit_step[(b.event_number, b.daq_id)] = step_idx
            self._expected[(b.event_number, b.daq_id)] = b.payload
        tb = self.trace
        if tb is not None:
            from repro.telemetry.trace import bundle_key
            key_b = bundle_key([b.event_number for b in bundles],
                               [b.daq_id for b in bundles])
            tb.record_window("emit_wait", key_b, t0, emit_b)

        # -- segmentation (timestamps ride as a side column) ------------------
        batch = segment_bundles(bundles, cfg.mtu_payload)
        n = len(batch)
        self.packets_sent += n
        bundle_of_row = np.cumsum(batch.seg_index == 0) - 1
        t_emit = emit_b[bundle_of_row]
        wire_bytes = (batch.payload_len.astype(np.float64)
                      + HEADER_BYTES + SEG_HDR_BYTES + IP_UDP_BYTES)

        # -- DAQ uplink serialization + WAN hop -------------------------------
        daq_link = batch.daq_id.astype(np.int64)
        t_up, up_keep = self.daq_uplinks.transit(daq_link, t_emit, wire_bytes)
        rows_up = np.flatnonzero(up_keep)
        delivery = self.wan.transit(t_up[rows_up], wire_bytes[rows_up])
        src = rows_up[delivery.src]
        arrived = batch.take(src)
        t_lb = delivery.t_arrive
        self.packets_delivered += len(arrived)
        key_r = pid_r = None
        if tb is not None:
            from repro.telemetry.trace import bundle_key
            key_r = bundle_key(arrived.event_number, arrived.daq_id)
            pid_r = (np.uint64(self._trace_pid0)
                     + np.arange(len(src), dtype=np.uint64))
            self._trace_pid0 += len(src)
            tb.record_window("uplink", key_r, t_emit[src], t_up[src],
                             pid=pid_r)
            tb.record_window("wan", key_r, t_up[src], t_lb, pid=pid_r)
        if len(arrived) == 0:
            self._post_window(step_idx, window_end, {})
            return

        # -- LB routing: the production engine, fixed pipeline latency --------
        # one DAQ -> instance assignment, used by both routing and the audit
        iid_np = (arrived.daq_id % cfg.n_instances).astype(np.uint64)
        member, _node, _lane, valid = self.dataplane().route_window(
            arrived, instance_id=iid_np if cfg.n_instances > 1 else None)
        self.discarded += int((~valid).sum())
        t_out = t_lb + cfg.lb_latency_s
        arrived_bytes = wire_bytes[src]
        # atomicity audit on unique (instance, event, member) triples — one
        # np.unique pass, O(#bundles) not O(#packets) host work
        rows_v = np.flatnonzero(valid)
        triples = np.unique(np.stack(
            [iid_np[rows_v], arrived.event_number[rows_v].astype(np.uint64),
             member[rows_v].astype(np.uint64)], axis=1), axis=0)
        for i, e, m in triples.tolist():
            self.event_members[(int(i), int(e))].add(int(m))

        # -- LB -> CN downlink + bounded receive queue ------------------------
        rows_ok = np.flatnonzero(valid)
        m_ok = member[rows_ok].astype(np.int64)
        t_cn, dl_keep = self.member_links.transit(
            m_ok, t_out[rows_ok], arrived_bytes[rows_ok])
        rows_cn = rows_ok[dl_keep]
        served = self.farm.serve(m_ok[dl_keep], t_cn[dl_keep],
                                 arrived_bytes[rows_ok][dl_keep])
        rows_acc = rows_cn[~served.dropped]
        dep_acc = served.depart[~served.dropped]
        if tb is not None:
            tb.record_window("lb", key_r, t_lb, t_out, pid=pid_r)
            tb.record_window("downlink", key_r[rows_cn], t_out[rows_cn],
                             t_cn[dl_keep], pid=pid_r[rows_cn],
                             aux=m_ok[dl_keep])
            m_acc = m_ok[dl_keep][~served.dropped]
            svc = self.farm.service_time(
                m_acc, arrived_bytes[rows_ok][dl_keep][~served.dropped])
            tb.record_window("farm_wait", key_r[rows_acc],
                             t_cn[dl_keep][~served.dropped], dep_acc - svc,
                             pid=pid_r[rows_acc], aux=m_acc)
            tb.record_window("service", key_r[rows_acc], dep_acc - svc,
                             dep_acc, pid=pid_r[rows_acc], aux=m_acc)

        # -- per-member reassembly at service-completion order ----------------
        done_by_member: dict[int, int] = {}
        tr_keys: list[int] = []
        tr_t0: list[float] = []
        tr_t1: list[float] = []
        tr_emit: list[float] = []
        if len(rows_acc):
            mem_acc = member[rows_acc]
            mem_ids, groups = group_rows(mem_acc)
            for m, grp in zip(mem_ids.tolist(), groups):
                sel = rows_acc[grp]
                dep_sel = dep_acc[grp]
                order = np.argsort(dep_sel, kind="stable")
                ra = self._reassembler(m)
                ra.push_batch(arrived.take(sel[order]))
                self.per_member_segments[m] += len(sel)
                # timed-out bundles will never complete: purge their emit
                # state so lossy soak runs don't grow (and a late duplicate
                # can't resurrect them into a second "completion")
                for key in ra.last_timed_out_keys:
                    self.emit_time.pop(key, None)
                    self.emit_step.pop(key, None)
                    self._expected.pop(key, None)
                completed = ra.drain_completed()
                done_by_member[m] = len(completed)
                if completed:
                    # completion time of a group = max service completion
                    # over the FIRST-served copy of each of its segments
                    # (FIFO => that is the closing row; a duplicate copy
                    # served later must not inflate the measured latency).
                    # Dedup by (event, daq, seg) keeping service order, then
                    # one sort + reduceat over (event, daq) — O(#bundles)
                    # python, never O(#packets).
                    sel_o, dep_o = sel[order], dep_sel[order]
                    seg3 = ((arrived.event_number[sel_o].astype(np.uint64)
                             << np.uint64(32))
                            | (arrived.daq_id[sel_o].astype(np.uint64)
                               << np.uint64(16))
                            | arrived.seg_index[sel_o].astype(np.uint64))
                    sorder = np.argsort(seg3, kind="stable")  # keeps dep order
                    firsts = sorder[np.concatenate(
                        [[True], seg3[sorder][1:] != seg3[sorder][:-1]])]
                    enc = ((arrived.event_number[sel_o[firsts]].astype(np.uint64)
                            << np.uint64(16))
                           | arrived.daq_id[sel_o[firsts]].astype(np.uint64))
                    dep_u = dep_o[firsts]
                    korder = np.argsort(enc, kind="stable")
                    enc_s, dep_s = enc[korder], dep_u[korder]
                    starts = np.flatnonzero(np.concatenate(
                        [[True], enc_s[1:] != enc_s[:-1]]))
                    gmax = np.maximum.reduceat(dep_s, starts)
                    gmin = np.minimum.reduceat(dep_s, starts)
                    uk_enc = enc_s[starts]
                    for key, payload in completed:
                        emit = self.emit_time.pop(key, None)
                        if emit is None:
                            continue  # resurrected duplicate group
                        self.emit_step.pop(key, None)
                        want = self._expected.pop(key, None)
                        if want is not None and not np.array_equal(payload, want):
                            self.corrupt += 1
                        kenc = (int(key[0]) << 16) | int(key[1])
                        pos = np.searchsorted(uk_enc, kenc)
                        t_done = float(gmax[pos])
                        self.latencies.append(t_done - emit)
                        if tb is not None:
                            self._lat_keys.append(kenc)
                            tr_keys.append(kenc)
                            tr_t0.append(float(gmin[pos]))
                            tr_t1.append(t_done)
                            tr_emit.append(emit)
        if tb is not None and tr_keys:
            rk = np.asarray(tr_keys, np.uint64)
            tb.record_window("reassembly", rk, np.asarray(tr_t0),
                             np.asarray(tr_t1))
            tb.complete_window(rk, np.asarray(tr_emit), np.asarray(tr_t1))
        self._post_window(step_idx, window_end, done_by_member,
                          busy_s=served.busy_s, accepted=served.accepted)

    # -- telemetry + control loop at the window boundary -----------------------
    def _post_window(self, step_idx: int, window_end: float,
                     done_by_member: dict[int, int],
                     busy_s: Optional[np.ndarray] = None,
                     accepted: Optional[np.ndarray] = None) -> None:
        """All telemetry is *measured* plant state: queue fill from the
        Lindley backlog, step time from accepted work seconds per segment,
        ingest backlog from the reassemblers — on the virtual clock."""
        cfg = self.cfg
        self.clock.advance_to(window_end)
        if self.trace is not None:
            self.trace.end_window()
        fill = self.farm.fill(now=self.clock.now())
        for m in range(cfg.n_members):
            backlog = int(round(fill[m] * cfg.queue_capacity_pkts))
            if (busy_s is not None and accepted is not None
                    and accepted[m] > 0):
                self.hub.report_step(
                    m, step_time=float(busy_s[m] / accepted[m]),
                    backlog=backlog, processed=done_by_member.get(m, 0))
            else:
                self.hub.report_queue(m, backlog)
            ra = self.reassemblers.get(m)
            if ra is not None:
                new_t = ra.stats.n_timed_out_groups - self._reported_timeouts[m]
                self._reported_timeouts[m] = ra.stats.n_timed_out_groups
                self.hub.report_ingest(m, pending=ra.n_incomplete,
                                       completed=done_by_member.get(m, 0),
                                       timed_out=new_t)

        if cfg.controld:
            if (self.cluster is not None and cfg.ha_kill_every
                    and (step_idx + 1) % cfg.ha_kill_every == 0
                    and step_idx + 1 < cfg.steps):
                self.kill_leader()
            self._controld_window(step_idx, fill, busy_s, accepted)
            if self.cluster is not None:
                self._ha_after_window()
            self.queue_fill_trace.append(
                (self.clock.now(), [round(float(f), 4) for f in fill]))
            self._purge_vanished(step_idx)
            self._emit_metrics(step_idx, fill)
            return

        self._purge_vanished(step_idx)

        if (not cfg.frozen_weights and cfg.reweight_every
                and (step_idx + 1) % cfg.reweight_every == 0):
            snap = self.hub.snapshot()
            for cp, ids in zip(self.cps, self.instance_members):
                sub = {m: t for m, t in snap.items() if m in cp.members}
                eid = cp.feedback(sub, self.fleet.event_number)
                if eid is not None:
                    self.epoch_switches += 1
                cp.garbage_collect(self.fleet.event_number)
            self.weight_trajectory.append(
                (step_idx, {m: round(w, 4) for cp in self.cps
                            for m, w in cp.weights.items()}))
        self.queue_fill_trace.append(
            (self.clock.now(), [round(float(f), 4) for f in fill]))
        self._emit_metrics(step_idx, fill)

    def _purge_vanished(self, step_idx: int) -> None:
        """Bundles that lost every segment before any reassembler saw them
        (WAN/downlink loss, queue drops, discards) never time out anywhere,
        so their emit state would leak in soak runs — purge on a horizon
        comfortably past the reassembly timeout and account them."""
        horizon = max(4 * (self.cfg.timeout_windows or 1), 64)
        if step_idx % 32 == 31:
            dead = [k for k, s in self.emit_step.items()
                    if s < step_idx - horizon]
            for k in dead:
                self.emit_time.pop(k, None)
                self.emit_step.pop(k, None)
                self._expected.pop(k, None)
            self.bundles_vanished += len(dead)

    def _controld_window(self, step_idx: int, fill,
                         busy_s, accepted) -> None:
        """The controld-mode control loop: every live CN heartbeats its
        *measured* occupancy (the same number the embedded hub would call
        fill) — one ``SendStateBatch`` per instance per window, not one
        message per CN — then the daemon ticks at the reweight cadence:
        lease expiry, one fused policy feedback over the member lanes, and
        epoch GC all happen inside the service."""
        cfg = self.cfg
        cap = max(cfg.queue_capacity_pkts, 1)
        if self.trace is not None:
            from repro.telemetry.trace import trace_id
            # window-scoped trace context: daemon-side spans of this
            # window's control messages correlate under one id
            self.client.trace = trace_id((1 << 62) | step_idx)
        for inst, ids in enumerate(self.instance_members):
            live, fills, rates = [], [], []
            for m in ids:
                if m in self.muted:
                    continue  # a silent CN daemon: its lease will lapse
                ra = self.reassemblers.get(m)
                backlog = max(int(round(fill[m] * cap)),
                              ra.n_incomplete if ra is not None else 0)
                rate = 1.0
                if (busy_s is not None and accepted is not None
                        and accepted[m] > 0):
                    step_time = float(busy_s[m] / accepted[m])
                    rate = 1.0 / step_time if step_time > 0 else 1.0
                live.append(m)
                fills.append(min(1.0, backlog / cap))
                rates.append(rate)
            if live:
                reply = self.client.send_state_batch(
                    self.tokens[inst], live, fills, rates)
                # lapsed leases come back as per-member rejections: the
                # protocol says re-register, not heartbeat
                self.heartbeats_rejected += len(reply["rejected"])
        if (not cfg.frozen_weights and cfg.reweight_every
                and (step_idx + 1) % cfg.reweight_every == 0):
            res = self.client.tick(current_event=self.fleet.event_number)
            for r in res["sessions"].values():
                if r.get("epoch") is not None:
                    self.epoch_switches += 1
            self.weight_trajectory.append(
                (step_idx, {m: round(w, 4) for cp in self.cps
                            for m, w in cp.weights.items()}))

    # -- whole run --------------------------------------------------------------
    def run(self) -> SimReport:
        if self.cfg.engine == "fused":
            from repro.simnet import fused
            if fused.fused_supported(self.cfg, self.scenario):
                return fused.FusedEngine(self).run()
            # outside the fused scope (hooks, controld, >16 members, ...):
            # fall through to the host loop, which is always complete
        elif self.cfg.engine != "host":
            raise ValueError(f"unknown engine {self.cfg.engine!r}")
        t_wall = time.perf_counter()
        for i in range(self.cfg.steps):
            self.step(i)
        wall = time.perf_counter() - t_wall
        if self._ts_writer is not None:
            self._ts_writer.close()

        pending = sum(ra.n_incomplete for ra in self.reassemblers.values())
        timed_out = sum(ra.stats.n_timed_out_groups
                        for ra in self.reassemblers.values())
        dups = sum(ra.stats.n_duplicate for ra in self.reassemblers.values())
        lat = np.asarray(self.latencies)
        completed = len(self.latencies)

        violations = []
        split = sum(1 for ms in self.event_members.values() if len(ms) > 1)
        if split:
            violations.append(f"{split} events split across members")
        if self.corrupt:
            violations.append(f"{self.corrupt} corrupt bundles")
        if self.restart_digest_mismatches:
            violations.append(
                f"{self.restart_digest_mismatches} daemon restarts did not "
                "replay to byte-identical state")
        if self.cluster is not None:
            if self.ha_digest_mismatches:
                violations.append(
                    f"{self.ha_digest_mismatches} failovers resumed from a "
                    "digest differing from the dead leader's last state")
            limit = 1.25 * self._ha_term_s()
            slow = [d for d in self.ha_failover_durations if d > limit]
            if slow:
                violations.append(
                    f"{len(slow)} failovers exceeded 1.25x the lease term "
                    f"(worst {max(slow):.3f}s vs limit {limit:.3f}s)")
            if self._ha_kill_t is not None:
                violations.append(
                    "leader killed but no standby promoted by run end")
        lossless = (self.wan.n_lost == 0 and self.daq_uplinks.n_lost == 0
                    and self.member_links.n_lost == 0
                    and self.farm.n_dropped == 0 and self.discarded == 0)
        if lossless and completed + pending + timed_out < self.bundles_sent:
            violations.append("bundles unaccounted with zero loss")

        weights = {}
        for cp in self.cps:
            weights.update({str(m): round(w, 4) for m, w in cp.weights.items()})
        return SimReport(
            scenario=self.scenario.name if self.scenario else "custom",
            steps=self.cfg.steps,
            sim_time_s=self.clock.now(),
            wall_s=wall,
            packets_sent=self.packets_sent,
            packets_delivered=self.packets_delivered,
            packets_lost_wan=self.wan.n_lost + self.daq_uplinks.n_lost,
            packets_lost_downlink=self.member_links.n_lost,
            packets_dropped_queue=self.farm.n_dropped,
            packets_discarded_invalid=self.discarded,
            duplicates_absorbed=dups,
            bundles_sent=self.bundles_sent,
            bundles_completed=completed,
            bundles_pending=pending,
            bundles_timed_out=timed_out,
            bundles_vanished=self.bundles_vanished,
            latency_p50_s=float(np.percentile(lat, 50)) if completed else 0.0,
            latency_p99_s=float(np.percentile(lat, 99)) if completed else 0.0,
            latency_max_s=float(lat.max()) if completed else 0.0,
            latency_mean_s=float(lat.mean()) if completed else 0.0,
            epoch_switches=self.epoch_switches,
            final_weights=weights,
            weight_trajectory=self.weight_trajectory,
            queue_fill_trace=self.queue_fill_trace,
            per_member_segments=dict(sorted(self.per_member_segments.items())),
            violations=violations,
            daemon_restarts=self.daemon_restarts,
            ha_failovers=self.ha_failovers,
            ha_revivals=self.ha_revivals,
            ha_failover_durations=[round(d, 6)
                                   for d in self.ha_failover_durations],
            leases_expired=(sum(s.counters["leases_expired"]
                                for s in self.daemon.sessions.values())
                            if self.daemon is not None else 0),
            heartbeats_rejected=self.heartbeats_rejected,
        )
