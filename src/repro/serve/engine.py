"""Serving engine: LB front door + continuous-batched prefill/decode.

Requests are *events*: the front door assigns each request a monotonically
increasing event number and an entropy value; requests accumulate and are
then routed lazily — a single batched ``DataPlane.route_events`` device call
per engine tick, not one round-trip per request — through the same
epoch-calendar data plane used for training ingest. The routed member is a
model replica (DP slice), the lane (entropy & mask, the paper's RSS
mechanism) picks a decode slot *within* the replica's node. Replica weights /
membership change hit-lessly via the control plane (e.g. drain a replica by
weighting it to 0 in the next epoch — in-flight requests keep their member).

The decode engine is slot-based continuous batching: each replica owns
``n_lanes`` slots; finished sequences free their slot for the next routed
request.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.control_plane import LoadBalancerControlPlane
from repro.core.dataplane import DataPlane, DataPlaneCache
from repro.core.epoch import EpochManager
from repro.core.tables import MemberSpec
from repro.telemetry.metrics import TelemetryHub
from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # int32[T]
    max_new_tokens: int = 16
    event_number: int = -1
    entropy: int = 0
    member: int = -1             # calendar member id (-1 until routed)
    node: int = -1               # destination replica (DP slice)
    lane: int = -1
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    n_replicas: int = 2
    lane_bits: int = 1           # 2**lane_bits decode slots per replica
    max_len: int = 256
    greedy: bool = True
    backend: str = "auto"        # data-plane backend (DataPlane)
    rebalance_every: int = 0     # ticks between control-plane reweights (0=off)
    # Delegate the rebalance loop to a controld session (repro.controld):
    # the engine reserves an LB instance, registers each replica as a
    # leased member, and rebalance() becomes heartbeats + a daemon tick.
    use_controld: bool = False
    controld_policy: str = "proportional"
    lease_s: float = 30.0        # replica lease (wall clock)
    # record controld.<kind> spans for the rebalance loop (requires
    # use_controld): each rebalance window is stamped with a
    # (1 << 62) | count trace id and the daemon records one span per
    # message, exposed on ``engine.trace`` (a telemetry.trace.TraceBuffer)
    trace: bool = False


class ServingEngine:
    def __init__(self, model_cfg: ModelConfig, serve_cfg: ServeConfig, params,
                 metrics=None):
        self.mcfg = model_cfg
        self.scfg = serve_cfg
        self.params = params
        # optional MetricsRegistry (repro.telemetry): metrics=None keeps the
        # engine bit-identical to the uninstrumented path
        self._mx_decode = self._mx_requests = self._mx_completed = None
        if metrics is not None:
            self._mx_decode = metrics.histogram(
                "serve_decode_step_seconds",
                "Per-replica decode step latency.")
            self._mx_requests = metrics.counter(
                "serve_requests_total", "Requests submitted.")
            self._mx_completed = metrics.counter(
                "serve_completed_total", "Requests finished.")
            metrics.gauge(
                "serve_queue_depth",
                "Requests routed-or-submitted but not yet in a decode slot."
            ).set_function(lambda: len(self.queue) + len(self.unrouted))
            metrics.gauge(
                "serve_active_slots", "Occupied decode slots across replicas."
            ).set_function(lambda: sum(
                r is not None for slots in self.slots for r in slots))
        if serve_cfg.use_controld:
            # the control plane as a service: the engine is one tenant of a
            # ControlDaemon; replicas are leased members of its reservation
            from repro.controld import (ControlDaemon, ControldClient,
                                        FailoverTransport, InProcTransport,
                                        RetryPolicy)
            self.trace = None
            if serve_cfg.trace:
                from repro.telemetry.trace import TraceBuffer
                self.trace = TraceBuffer()
            # journal=None: the engine never recovers this daemon (it lives
            # and dies with the process), and an unread in-memory journal
            # would grow by one entry per heartbeat forever
            self.daemon = ControlDaemon(
                n_instances=1, lease_s=serve_cfg.lease_s,
                max_members=max(64, serve_cfg.n_replicas), journal=None,
                trace=self.trace)
            # the client failover path: mutating calls are request-id
            # stamped (idempotent resend) and retried with capped backoff
            # through FailoverTransport — the identical machinery an HA
            # deployment uses, here over the single in-proc endpoint
            self.client = ControldClient(FailoverTransport(
                [InProcTransport(self.daemon)],
                retry=RetryPolicy(max_elapsed_s=5.0, seed=0)))
            self.token = self.client.reserve(
                policy=serve_cfg.controld_policy)["token"]
            self.client.register_batch(self.token,
                                       range(serve_cfg.n_replicas),
                                       lane_bits=serve_cfg.lane_bits)
            self.client.tick(current_event=0)  # starts the session (epoch 0)
            session = self.daemon.sessions[self.token]
            self.manager = session.manager
            self.cp = session.cp
        else:
            self.daemon = None
            self.trace = None
            self.manager = EpochManager(max_members=max(64, serve_cfg.n_replicas))
            self.cp = LoadBalancerControlPlane(self.manager)
            members = {
                i: MemberSpec(node_id=i, base_lane=0,
                              lane_bits=serve_cfg.lane_bits)
                for i in range(serve_cfg.n_replicas)
            }
            self.cp.start(members)
        self.n_lanes = 1 << serve_cfg.lane_bits
        # per replica: decode state over n_lanes slots + slot occupancy
        self.states = [
            M.init_decode_state(model_cfg, self.n_lanes, serve_cfg.max_len)
            for _ in range(serve_cfg.n_replicas)
        ]
        self.slots: list[list[Optional[Request]]] = [
            [None] * self.n_lanes for _ in range(serve_cfg.n_replicas)
        ]
        self.queue: deque[Request] = deque()      # routed, awaiting a slot
        self.unrouted: deque[Request] = deque()   # submitted, awaiting routing
        self.next_event = 1000
        self.next_rid = 0
        self._decode = jax.jit(
            lambda p, tok, st: M.decode_step(p, tok, st, self.mcfg))
        self.stats = {"routed": {}, "completed": 0, "rejected": 0,
                      "route_calls": 0, "rebalances": 0}
        self._dp_cache = DataPlaneCache(self.manager, backend=serve_cfg.backend)
        # Telemetry feedback loop: per-replica decode-step time + queue depth
        # feed the control plane exactly like CN ingest daemons do
        # (DESIGN.md §Ingest); a reweight reprograms the calendar hit-lessly.
        self.hub = TelemetryHub(queue_capacity=max(2 * self.n_lanes, 1))
        self._tick = 0

    # -- front door -------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> Request:
        """Assign an event number + entropy and enqueue; routing happens
        lazily in one batched device call per tick (``_route_pending``)."""
        req = Request(rid=self.next_rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens)
        self.next_rid += 1
        req.event_number = self.next_event
        self.next_event += int(np.random.default_rng(req.rid).integers(1, 5))
        req.entropy = int(np.random.default_rng(req.rid + 7).integers(0, 1 << 16))
        self.unrouted.append(req)
        if self._mx_requests is not None:
            self._mx_requests.inc()
        return req

    def _dataplane(self) -> DataPlane:
        """Facade over the current tables; recompiled only after the control
        plane touches the epoch state (audit-log watermark)."""
        return self._dp_cache.get()

    def _route_pending(self) -> None:
        """Route every accumulated submission in ONE device call."""
        if not self.unrouted:
            return
        batch = list(self.unrouted)
        self.unrouted.clear()
        r = self._dataplane().route_events(
            np.asarray([q.event_number for q in batch], np.uint64),
            np.asarray([q.entropy for q in batch], np.uint32))
        self.stats["route_calls"] += 1
        member = np.asarray(r.member)
        node = np.asarray(r.node)
        lane = np.asarray(r.lane)
        valid = np.asarray(r.valid)
        for i, req in enumerate(batch):
            if not valid[i]:
                # The calendar discards events with no programmed slot; a
                # request-event should never hit this, but account for it.
                req.done = True
                self.stats["rejected"] += 1
                continue
            req.member = int(member[i])
            req.node = int(node[i])
            req.lane = int(lane[i])
            self.stats["routed"][req.member] = (
                self.stats["routed"].get(req.member, 0) + 1)
            self.queue.append(req)

    # -- scheduling ---------------------------------------------------------------
    def _try_place(self) -> None:
        pending = []
        while self.queue:
            req = self.queue.popleft()
            lane = req.lane % self.n_lanes
            if self.slots[req.node][lane] is None:
                self.slots[req.node][lane] = req
                self._prefill_into_slot(req)
            else:
                pending.append(req)  # lane busy: wait (RSS lane affinity)
        self.queue.extend(pending)

    def _prefill_into_slot(self, req: Request) -> None:
        """Single-sequence prefill into the slot's cache lane."""
        node, lane = req.node, req.lane % self.n_lanes
        state = self.states[node]
        tokens = jnp.asarray(req.prompt[None, :], jnp.int32)
        # Per-lane decode state: run prefill on a batch-1 view, then scatter
        # the lane back. For simplicity the slot engine keeps per-lane states.
        one = M.init_decode_state(self.mcfg, 1, self.scfg.max_len)
        logits, one = M.prefill(self.params, {"tokens": tokens}, one, self.mcfg)
        nxt = int(jnp.argmax(logits[0]))
        req.output.append(nxt)
        self.states[node] = _scatter_lane(state, one, lane)

    def step(self) -> int:
        """One engine tick: batch-route new submissions (one device call),
        place them, one decode step per replica, then report telemetry (and
        periodically close the control loop with a reweight)."""
        import time

        self._route_pending()
        self._try_place()
        n_active = 0
        queued = np.zeros((self.scfg.n_replicas,), np.int64)
        for req in self.queue:
            queued[req.node] += 1
        for m in range(self.scfg.n_replicas):
            active = [(l, r) for l, r in enumerate(self.slots[m]) if r is not None]
            if not active:
                # Idle tick: clear the stale busy-tick backlog so a drained
                # replica's fill can actually decay (only queued work counts).
                self.hub.report_queue(m, int(queued[m]))
                continue
            n_active += len(active)
            toks = np.zeros((self.n_lanes,), np.int32)
            for l, r in active:
                toks[l] = r.output[-1]
            t0 = time.perf_counter()
            logits, self.states[m] = self._decode(
                self.params, jnp.asarray(toks), self.states[m])
            logits = jax.block_until_ready(logits)
            dt = time.perf_counter() - t0
            if self._mx_decode is not None:
                self._mx_decode.observe(dt)
            self.hub.report_step(
                m, step_time=dt,
                backlog=int(queued[m]) + len(active), processed=len(active))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for l, r in active:
                r.output.append(int(nxt[l]))
                if len(r.output) >= r.max_new_tokens:
                    r.done = True
                    self.slots[m][l] = None
                    self.stats["completed"] += 1
                    if self._mx_completed is not None:
                        self._mx_completed.inc()
        self._tick += 1
        if (self.scfg.rebalance_every
                and self._tick % self.scfg.rebalance_every == 0):
            self.rebalance()
        return n_active

    def rebalance(self) -> Optional[int]:
        """Close the loop: telemetry snapshot -> policy reweight -> (maybe) a
        hit-less epoch switch. In-flight requests keep their member; the
        next ``_route_pending`` picks up the new tables via the audit-log
        watermark in ``_dataplane``. Drained epochs are quiesced right away
        (every event below the routed watermark has already been routed), so
        repeated reweights never exhaust the calendar rows.

        With ``use_controld`` the same loop runs through the daemon session:
        each replica's snapshot becomes a SendState heartbeat (renewing its
        lease) and the feedback/GC happen inside the daemon's Tick."""
        # Watermark: everything below the smallest still-unrouted event
        # number has been through the data plane already.
        unrouted = [q.event_number for q in self.unrouted]
        watermark = min(unrouted) if unrouted else self.next_event
        if self.daemon is not None:
            if self.trace is not None:
                # one trace id per rebalance window, same namespace the
                # simnet controld loop uses for its window spans
                from repro.telemetry.trace import trace_id
                self._trace_windows = getattr(self, "_trace_windows", 0) + 1
                self.client.trace = trace_id(
                    (1 << 62) | self._trace_windows)
            # one SendStateBatch per rebalance: every replica's sample in a
            # single frame (and a single journal entry / telemetry scatter);
            # replicas whose lease lapsed (a long gap between rebalances)
            # are re-registered and their samples resent by the helper
            self.client.heartbeat_window(self.token, self.hub.snapshot(),
                                         lane_bits=self.scfg.lane_bits)
            res = self.client.tick(current_event=self.next_event,
                                   gc_event=watermark)
            eid = res["sessions"][self.token]["epoch"]
        else:
            eid = self.cp.feedback(self.hub.snapshot(),
                                   current_event=self.next_event)
            self.cp.garbage_collect(watermark)
        if eid is not None:
            self.stats["rebalances"] += 1
        return eid

    def run_until_done(self, max_ticks: int = 1000) -> None:
        for _ in range(max_ticks):
            n_active = self.step()
            if not self.queue and not self.unrouted and n_active == 0:
                break


def _scatter_lane(state, one, lane: int):
    """Write batch-1 decode state ``one`` into lane ``lane`` of ``state``.

    Batch dims differ per leaf family; we detect the dim whose size matches
    the lane count by structure (leaves share [..., B, ...] layout per family).
    """
    def sc(dst, src):
        if dst.ndim == 0 or dst.shape == src.shape:
            return src if dst.shape == src.shape else dst
        # find axis where dst has n_lanes and src has 1
        for ax in range(dst.ndim):
            if src.ndim == dst.ndim and dst.shape[ax] != src.shape[ax] and src.shape[ax] == 1:
                idx = [slice(None)] * dst.ndim
                idx[ax] = slice(lane, lane + 1)
                return dst.at[tuple(idx)].set(src)
        return dst
    return jax.tree.map(sc, state, one)
