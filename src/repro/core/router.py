"""Stateless data-plane routing (jnp) + on-mesh redistribution (shard_map).

This is the TPU mapping of the paper's data plane: the routing decision for a
packet is a pure function of (header fields, programmed tables) — examine a
single packet with no other history and determine its final destination
(paper §I-B.3). The Pallas kernel in kernels/lb_route.py implements the same
math with explicit VMEM tiling; this module is the reference semantics and
the default path, and also provides the dispatch/redistribution collectives
that realize "delivery to the selected compute node" over the TPU ICI fabric.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.protocol import SLOT_MASK, validate
from repro.core.tables import DeviceTables


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Route:
    member: jnp.ndarray  # int32[N]  (-1 => discard)
    node: jnp.ndarray    # int32[N]  destination node / DP slice
    lane: jnp.ndarray    # int32[N]  receive lane (UDP port analogue)
    valid: jnp.ndarray   # bool[N]


def _ge_u64(e_hi, e_lo, s_hi, s_lo):
    """(e_hi, e_lo) >= (s_hi, s_lo) on uint32 pairs, broadcasting."""
    return (e_hi > s_hi) | ((e_hi == s_hi) & (e_lo >= s_lo))


def epoch_row(tables: DeviceTables, event_hi, event_lo):
    """Sorted-boundary segment lookup: row index into the calendar table.

    Equivalent to the P4 LPM 'Calendar Epoch Assignment' (equivalence is
    property-tested against core/lpm.py). idx = (#segments with start <= e) - 1.
    """
    e_hi = event_hi[..., None].astype(jnp.uint32)
    e_lo = event_lo[..., None].astype(jnp.uint32)
    ge = _ge_u64(e_hi, e_lo, tables.seg_start_hi, tables.seg_start_lo)
    idx = jnp.sum(ge.astype(jnp.int32), axis=-1) - 1
    idx = jnp.clip(idx, 0, tables.seg_row.shape[-1] - 1)
    return tables.seg_row[idx]


def route(
    tables: DeviceTables,
    event_hi: jnp.ndarray,
    event_lo: jnp.ndarray,
    entropy: jnp.ndarray,
    header_words: jnp.ndarray | None = None,
) -> Route:
    """Route N packets. All lookups are vectorized gathers on small tables."""
    event_hi = event_hi.astype(jnp.uint32)
    event_lo = event_lo.astype(jnp.uint32)
    row = epoch_row(tables, event_hi, event_lo)
    slot = (event_lo & SLOT_MASK).astype(jnp.int32)
    member = tables.calendars[jnp.clip(row, 0, tables.calendars.shape[0] - 1), slot]

    m = jnp.clip(member, 0, tables.member_node.shape[0] - 1)
    node = tables.member_node[m]
    lane = tables.member_base_lane[m] + (
        entropy.astype(jnp.int32) & tables.member_lane_mask[m]
    )
    ok = (row >= 0) & (tables.member_valid[m] > 0) & (member >= 0)
    if header_words is not None:
        ok = ok & validate(header_words)
    member = jnp.where(ok, member, -1)
    node = jnp.where(ok, node, -1)
    lane = jnp.where(ok, lane, -1)
    return Route(member=member, node=node, lane=lane, valid=ok)


def route_instances(
    stacked: DeviceTables,
    instance_id: jnp.ndarray,
    event_hi, event_lo, entropy,
    header_words=None,
) -> Route:
    """Route packets across virtual LB instances (paper §I-C, 4 instances).

    ``stacked`` carries a leading instance dim (tables.stack_tables); each
    packet's tables are selected by its instance id (from the L3 filter).

    Single fused pass: every lookup gathers the packet's own instance's row
    directly (O(N) work regardless of instance count), instead of routing
    through all N instances and selecting — same table reads per packet as
    the single-instance path. Callers go through core/dataplane.DataPlane.
    """
    n_inst = stacked.seg_row.shape[0]
    iid = jnp.clip(instance_id.astype(jnp.int32), 0, n_inst - 1)
    event_hi = event_hi.astype(jnp.uint32)
    event_lo = event_lo.astype(jnp.uint32)

    # Calendar Epoch Assignment on per-packet segment tables [N, S].
    e_hi = event_hi[..., None]
    e_lo = event_lo[..., None]
    ge = _ge_u64(e_hi, e_lo, stacked.seg_start_hi[iid], stacked.seg_start_lo[iid])
    idx = jnp.sum(ge.astype(jnp.int32), axis=-1) - 1
    idx = jnp.clip(idx, 0, stacked.seg_row.shape[-1] - 1)
    row = stacked.seg_row[iid, idx]

    # Calendar to Member Map.
    slot = (event_lo & SLOT_MASK).astype(jnp.int32)
    member = stacked.calendars[iid, jnp.clip(row, 0, stacked.calendars.shape[1] - 1), slot]

    # Member Lookup and Rewrite.
    m = jnp.clip(member, 0, stacked.member_node.shape[-1] - 1)
    node = stacked.member_node[iid, m]
    lane = stacked.member_base_lane[iid, m] + (
        entropy.astype(jnp.int32) & stacked.member_lane_mask[iid, m]
    )
    ok = (row >= 0) & (stacked.member_valid[iid, m] > 0) & (member >= 0)
    if header_words is not None:
        ok = ok & validate(header_words)
    return Route(
        member=jnp.where(ok, member, -1),
        node=jnp.where(ok, node, -1),
        lane=jnp.where(ok, lane, -1),
        valid=ok,
    )


# ---------------------------------------------------------------------------
# Dispatch: pack routed packets into per-member buffers (capacity model).
# ---------------------------------------------------------------------------

def member_positions(member: jnp.ndarray, n_members: int, capacity: int):
    """Position of each packet within its member's buffer (sort-based pack).

    pos_i = #packets j<i with member_j == member_i, computed as a stable
    argsort by member followed by a segment-offset subtraction: within the
    sorted order, a packet's position is its sorted rank minus the rank of
    the first packet of its member segment. O(N log N) work and O(N) memory
    versus the old one-hot cumsum's O(N*M) (see DESIGN.md §Perf; benchmarked
    in benchmarks/bench_dispatch.py).

    Returns (pos int32[N], keep bool[N], counts int32[n_members]). Packets
    beyond ``capacity`` are dropped — the analogue of the paper's note that
    events targeting an unprogrammed slot are discarded, except here we
    account for every drop (tested).
    """
    n = member.shape[0]
    if (n_members + 2) * max(n, 1) >= 2**31:
        raise ValueError("n_members * n must fit in int32 for the sort keys")
    mem = member.astype(jnp.int32)
    i = jnp.arange(n, dtype=jnp.int32)
    valid = (mem >= 0) & (mem < n_members)
    mv = jnp.where(valid, mem, n_members)  # invalid packets sort last
    # Stable sort by member: key = member * n + arrival index. Keys are
    # unique, so a plain value sort is a stable argsort (and jnp.sort is far
    # cheaper than jnp.argsort or a scatter on CPU/TPU alike).
    sk = jnp.sort(mv * n + i)
    sm = sk // jnp.int32(max(n, 1))       # sorted member ids
    orig = sk % jnp.int32(max(n, 1))      # original index of each sorted slot
    # Segment boundaries: one tiny searchsorted (n_members + 1 probes) gives
    # every member's first sorted position AND the per-member totals.
    starts = jnp.searchsorted(
        sk, jnp.arange(n_members + 1, dtype=jnp.int32) * n, side="left"
    ).astype(jnp.int32)
    counts = starts[1:] - starts[:-1]  # [n_members]
    # Position within the member segment = sorted rank - segment start
    # (starts[n_members] opens the invalid-packet segment).
    pos_sorted = i - starts[jnp.clip(sm, 0, n_members)]
    if n * n < 2**31:
        # Undo the permutation with a second key sort instead of a scatter
        # (cheaper than scatter on CPU/TPU; key = orig * n + pos needs n^2
        # to fit in int32).
        pos = (jnp.sort(orig * n + pos_sorted) % jnp.int32(max(n, 1))).astype(jnp.int32)
    else:
        pos = jnp.zeros((n,), jnp.int32).at[orig].set(pos_sorted)
    pos = jnp.where(valid, pos, 0)
    keep = valid & (pos < capacity)
    return pos, keep, counts


def dispatch(
    payload: jnp.ndarray,  # [N, ...]
    member: jnp.ndarray,   # int32[N], -1 = dropped
    n_members: int,
    capacity: int,
):
    """Scatter payloads into [n_members, capacity, ...] buffers + occupancy."""
    pos, keep, counts = member_positions(member, n_members, capacity)
    buf = jnp.zeros((n_members, capacity) + payload.shape[1:], payload.dtype)
    # Masked packets go to an out-of-bounds index; mode='drop' discards the
    # write (an in-bounds dummy index would clobber a real packet's slot).
    m_idx = jnp.where(keep, member, n_members)
    p_idx = jnp.where(keep, pos, capacity)
    buf = buf.at[m_idx, p_idx].set(payload, mode="drop")
    occ = jnp.zeros((n_members, capacity), jnp.int32).at[m_idx, p_idx].set(
        jnp.ones_like(member, jnp.int32), mode="drop"
    )
    return buf, occ, counts


# ---------------------------------------------------------------------------
# On-mesh redistribution: the "LB -> CN delivery" as an all_to_all collective.
# ---------------------------------------------------------------------------

def make_redistribute(mesh, axis_names, capacity_per_src: int):
    """Build a shard_map fn exchanging event payloads between DP members.

    Each data-parallel shard plays both DAQ-aggregation point (arrival order)
    and CN (event owner). Within a shard: pack local events into per-member
    send buffers sized ``capacity_per_src``; ``lax.all_to_all`` swaps the
    member dim across shards; each member then holds every event routed to it.

    Returns fn(payload[B_local*W, ...], member[B_local*W]) ->
      (recv[W*capacity_per_src, ...], occ[W*capacity_per_src]) per shard.
    """
    axis = axis_names if isinstance(axis_names, (tuple, list)) else (axis_names,)

    def _local(payload, member):
        n_members = 1
        for a in axis:
            n_members *= mesh.shape[a]
        buf, occ, _ = dispatch(payload, member, n_members, capacity_per_src)
        # [M, cap, ...] -> all_to_all over member dim -> [M, cap, ...] where
        # dim0 is now the source shard index.
        recv = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0, tiled=False)
        rocc = jax.lax.all_to_all(occ, axis, split_axis=0, concat_axis=0, tiled=False)
        flat = recv.reshape((-1,) + recv.shape[2:])
        return flat, rocc.reshape(-1)

    from jax.experimental.shard_map import shard_map

    pspec = P(axis if len(axis) > 1 else axis[0])
    return shard_map(
        _local,
        mesh=mesh,
        in_specs=(pspec, pspec),
        out_specs=(pspec, pspec),
        check_rep=False,
    )
