"""Weighted 512-slot Load Balance Calendar construction (paper §III-B.3).

"All (or any subset of) the Member IDs ... should be distributed into the 512
Calendar Slots available in the Calendar. Any members can occur between 0-512
times in the calendar. A member occurring more times in the calendar has a
higher 'weight' ... NOTE: All 512 slots MUST have a member assigned to them or
events that target the empty slot will be entirely discarded."

Because the slot index is ``event_number & 0x1FF`` and event numbers are
(required to be) uniform in their 9 LSBs, the traffic share of a member equals
its slot count / 512. We build calendars with:

  * exact largest-remainder quotas (counts sum to 512, proportional to weight
    within ±1 slot), and
  * smooth interleaved placement (deficit round-robin) so a member's slots are
    spread across the slot space rather than clustered — this keeps short
    event-number windows balanced too, not just the long-run average.
"""
from __future__ import annotations

import numpy as np

from repro.core.protocol import CALENDAR_SLOTS


def quotas_from_weights(weights: np.ndarray, n_slots: int = CALENDAR_SLOTS) -> np.ndarray:
    """Largest-remainder apportionment of ``n_slots`` by weight.

    Members with weight 0 get 0 slots. Every member with positive weight gets
    at least one slot when feasible (n_positive <= n_slots).
    """
    w = np.asarray(weights, dtype=np.float64)
    if w.ndim != 1 or w.size == 0:
        raise ValueError("weights must be a non-empty 1-D array")
    if (w < 0).any():
        raise ValueError("weights must be non-negative")
    total = w.sum()
    if total <= 0:
        raise ValueError("at least one member must have positive weight")
    ideal = w / total * n_slots
    counts = np.floor(ideal).astype(np.int64)
    # Guarantee >=1 slot for active members (paper: a member absent from the
    # calendar simply receives no traffic; we keep active members reachable).
    active = w > 0
    if active.sum() > n_slots:
        raise ValueError(f"more active members ({int(active.sum())}) than slots ({n_slots})")
    counts[active & (counts == 0)] = 1
    # Largest-remainder fixup to land exactly on n_slots.
    rem = ideal - np.floor(ideal)
    while counts.sum() > n_slots:
        # Remove from the largest over-represented count (never below 1 for active).
        over = np.where(counts > 1, counts - ideal, -np.inf)
        counts[int(np.argmax(over))] -= 1
    order = np.argsort(-rem)
    i = 0
    while counts.sum() < n_slots:
        m = int(order[i % len(order)])
        if active[m]:
            counts[m] += 1
        i += 1
    assert counts.sum() == n_slots
    return counts


def build_calendar(
    member_ids: np.ndarray,
    weights: np.ndarray,
    n_slots: int = CALENDAR_SLOTS,
) -> np.ndarray:
    """Build an int32[n_slots] calendar: slot -> member id.

    Placement uses smooth weighted round-robin (deficit counters), producing a
    maximally interleaved pattern: e.g. weights [2, 1] over 6 slots give
    A B A A B A — not A A A A B B.
    """
    member_ids = np.asarray(member_ids, dtype=np.int32)
    counts = quotas_from_weights(weights, n_slots)
    credit = np.zeros(len(member_ids), dtype=np.float64)
    remaining = counts.astype(np.float64).copy()
    out = np.empty(n_slots, dtype=np.int32)
    for s in range(n_slots):
        credit += remaining
        pick = int(np.argmax(credit))
        out[s] = member_ids[pick]
        credit[pick] -= n_slots  # one full cycle of credit
        remaining[pick] = max(remaining[pick] - 0.0, 0.0)
    # The credit scheme above keeps proportions but can drift off exact
    # quotas; enforce exact counts with a corrective pass.
    out = _enforce_quotas(out, member_ids, counts)
    return out


def _enforce_quotas(cal: np.ndarray, member_ids: np.ndarray, counts: np.ndarray) -> np.ndarray:
    cal = cal.copy()
    want = {int(m): int(c) for m, c in zip(member_ids, counts)}
    have: dict[int, int] = {int(m): 0 for m in member_ids}
    for v in cal:
        have[int(v)] = have.get(int(v), 0) + 1
    surplus = [m for m in have if have[m] > want.get(m, 0)]
    deficit = [m for m in want if have.get(m, 0) < want[m]]
    if not surplus and not deficit:
        return cal
    # Replace surplus occurrences (evenly spaced) with deficit members.
    di = 0
    need = {m: want[m] - have.get(m, 0) for m in deficit}
    for i in range(len(cal)):
        m = int(cal[i])
        if have[m] > want.get(m, 0) and di < len(deficit):
            d = deficit[di]
            cal[i] = d
            have[m] -= 1
            need[d] -= 1
            have[d] = have.get(d, 0) + 1
            if need[d] == 0:
                di += 1
    return cal


def calendar_counts(cal: np.ndarray, n_members: int) -> np.ndarray:
    return np.bincount(np.asarray(cal, dtype=np.int64), minlength=n_members)


def max_run_length(cal: np.ndarray, member: int) -> int:
    """Longest run of consecutive slots owned by ``member`` (dispersion metric)."""
    best = cur = 0
    for v in np.asarray(cal):
        cur = cur + 1 if int(v) == member else 0
        best = max(best, cur)
    return best
