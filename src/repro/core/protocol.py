"""EJ-FAT Load Balancer protocol header (paper fig. 2).

Wire layout (16 bytes, network order), carried after the UDP header::

    0               1               2               3
    +-------+-------+-------+-------+-------+-------+-------+-------+
    | 'L'   | 'B'   |Version|Proto  |     rsvd      |    Entropy    |
    +-------+-------+-------+-------+-------+-------+-------+-------+
    |                     Event Number (64 bit)                     |
    +---------------------------------------------------------------+

Device-side representation: packets are carried as ``uint32[..., 4]`` words

    word0 = magic(16) << 16 | version(8) << 8 | protocol(8)
    word1 = rsvd(16)  << 16 | entropy(16)
    word2 = event number high 32 bits
    word3 = event number low  32 bits

JAX runs with 32-bit ints by default, so 64-bit event numbers live as
(hi, lo) uint32 pairs on device; host code uses python ints / np.uint64.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

# 'L' << 8 | 'B'  — also the LB UDP service port (paper §III-A: 19522 = 0x4C42).
MAGIC = 0x4C42
VERSION = 1
PROTOCOL = 1
LB_SERVICE_PORT = 19522

HEADER_WORDS = 4
HEADER_BYTES = 16
# Paper §II-C: 9KB max network packet size bounds a segment (headers included).
MAX_PACKET_BYTES = 9000
MAX_SEGMENT_PAYLOAD = MAX_PACKET_BYTES - HEADER_BYTES - 28  # IP(20) + UDP(8)

# Paper §III fig. 4: the 9 LSBs of the event number select the calendar slot.
CALENDAR_SLOT_BITS = 9
CALENDAR_SLOTS = 1 << CALENDAR_SLOT_BITS
SLOT_MASK = CALENDAR_SLOTS - 1


@dataclasses.dataclass(frozen=True)
class LBHeader:
    """Host-side view of one LB protocol header."""

    event_number: int
    entropy: int
    version: int = VERSION
    protocol: int = PROTOCOL
    rsvd: int = 0

    def words(self) -> np.ndarray:
        return encode_headers(
            np.asarray([self.event_number], dtype=np.uint64),
            np.asarray([self.entropy], dtype=np.uint32),
            version=self.version,
            protocol=self.protocol,
            rsvd=self.rsvd,
        )[0]


def split64(x) -> tuple[np.ndarray, np.ndarray]:
    """Split uint64 -> (hi, lo) uint32. Host-side helper."""
    x = np.asarray(x, dtype=np.uint64)
    hi = (x >> np.uint64(32)).astype(np.uint32)
    lo = (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return hi, lo


def join64(hi, lo) -> np.ndarray:
    hi = np.asarray(hi, dtype=np.uint64)
    lo = np.asarray(lo, dtype=np.uint64)
    return (hi << np.uint64(32)) | lo


def encode_headers(
    event_numbers: np.ndarray,
    entropy: np.ndarray,
    *,
    version: int = VERSION,
    protocol: int = PROTOCOL,
    rsvd: int = 0,
) -> np.ndarray:
    """Encode N headers into uint32[N, 4] wire words (host side, numpy)."""
    event_numbers = np.asarray(event_numbers, dtype=np.uint64)
    entropy = np.asarray(entropy, dtype=np.uint32)
    if event_numbers.shape != entropy.shape:
        raise ValueError("event_numbers and entropy must have matching shapes")
    n = event_numbers.shape[0]
    out = np.empty((n, HEADER_WORDS), dtype=np.uint32)
    out[:, 0] = (MAGIC << 16) | ((version & 0xFF) << 8) | (protocol & 0xFF)
    out[:, 1] = ((rsvd & 0xFFFF) << 16) | (entropy & 0xFFFF)
    hi, lo = split64(event_numbers)
    out[:, 2] = hi
    out[:, 3] = lo
    return out


def encode_seg_headers(daq_id, seg_index, n_segs, payload_len) -> np.ndarray:
    """Encode N segmentation headers into uint32[N, 2] words (host side).

    The segmentation header (paper §II-C) is opaque to the LB and rides after
    the LB header: ``(daq_id u16, seg_index u16, n_segs u16, payload_len u16)``
    packed as

        word0 = daq_id(16) << 16 | seg_index(16)
        word1 = n_segs(16) << 16 | payload_len(16)
    """
    daq_id = np.asarray(daq_id, np.uint32)
    seg_index = np.asarray(seg_index, np.uint32)
    n_segs = np.asarray(n_segs, np.uint32)
    payload_len = np.asarray(payload_len, np.uint32)
    out = np.empty(daq_id.shape + (2,), np.uint32)
    out[..., 0] = ((daq_id & 0xFFFF) << 16) | (seg_index & 0xFFFF)
    out[..., 1] = ((n_segs & 0xFFFF) << 16) | (payload_len & 0xFFFF)
    return out


def decode_seg_headers(words):
    """Decode seg-header words -> dict of uint32 field arrays (np or jnp)."""
    w0 = words[..., 0]
    w1 = words[..., 1]
    return {
        "daq_id": (w0 >> 16) & 0xFFFF,
        "seg_index": w0 & 0xFFFF,
        "n_segs": (w1 >> 16) & 0xFFFF,
        "payload_len": w1 & 0xFFFF,
    }


def decode_fields(words):
    """Decode header words -> dict of field arrays. Works on jnp or np arrays.

    Returns uint32 arrays: magic, version, protocol, rsvd, entropy,
    event_hi, event_lo.
    """
    w = words
    w0 = w[..., 0]
    w1 = w[..., 1]
    return {
        "magic": (w0 >> 16) & 0xFFFF,
        "version": (w0 >> 8) & 0xFF,
        "protocol": w0 & 0xFF,
        "rsvd": (w1 >> 16) & 0xFFFF,
        "entropy": w1 & 0xFFFF,
        "event_hi": w[..., 2],
        "event_lo": w[..., 3],
    }


def validate(words):
    """Parser validation (paper §III-A): magic and version must match.

    Returns a bool array; packets failing validation are discarded upstream.
    No parsing is done on any bytes beyond the LB header.
    """
    f = decode_fields(words)
    return jnp.logical_and(f["magic"] == MAGIC, f["version"] == VERSION)


def event_slot(event_lo):
    """Calendar slot = 9 LSBs of the event number (paper fig. 4)."""
    return event_lo & SLOT_MASK
