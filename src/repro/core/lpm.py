"""P4-faithful LPM (longest-prefix-match) machinery over the Event Number space.

The paper (§II-A, §III-C) programs Calendar *Epoch* boundaries as ranges over
the 64-bit Event Number, expressed — because P4 has no range matches — as a
set of prefix matches: "Compute a set of LPM prefix matches over the Event ID
space which describe the entire range of Event IDs from the start of the
current Epoch up to the start of the new Epoch."

This module implements that decomposition exactly (host side, python ints),
plus an LPM table with longest-prefix semantics. The device data plane uses an
equivalent sorted-boundary representation (core/tables.py); equivalence between
the two is property-tested in tests/test_lpm.py.
"""
from __future__ import annotations

import dataclasses

EVENT_BITS = 64
EVENT_SPACE = 1 << EVENT_BITS


@dataclasses.dataclass(frozen=True)
class Prefix:
    """A prefix match: matches keys whose top ``length`` bits equal value's."""

    value: int  # left-aligned: low (64 - length) bits are zero
    length: int  # 0..64; 0 is the wildcard

    def __post_init__(self):
        if not 0 <= self.length <= EVENT_BITS:
            raise ValueError(f"bad prefix length {self.length}")
        mask = self.mask
        if self.value & ~mask & (EVENT_SPACE - 1):
            raise ValueError("prefix value has bits below the prefix length")

    @property
    def mask(self) -> int:
        if self.length == 0:
            return 0
        return ((1 << self.length) - 1) << (EVENT_BITS - self.length)

    @property
    def lo(self) -> int:
        return self.value

    @property
    def hi(self) -> int:  # exclusive
        return self.value + (1 << (EVENT_BITS - self.length))

    def matches(self, key: int) -> bool:
        return (key & self.mask) == self.value


def range_to_prefixes(lo: int, hi: int) -> list[Prefix]:
    """Minimal prefix cover of the half-open range [lo, hi).

    Classic greedy: at each step emit the largest aligned power-of-two block
    starting at ``lo`` that fits inside the remaining range.
    """
    if not 0 <= lo <= hi <= EVENT_SPACE:
        raise ValueError(f"bad range [{lo}, {hi})")
    out: list[Prefix] = []
    while lo < hi:
        # Largest block size allowed by alignment of lo (lowest set bit).
        align = lo & -lo if lo else EVENT_SPACE
        size = align
        # Shrink to fit the remaining span.
        while size > hi - lo:
            size >>= 1
        length = EVENT_BITS - size.bit_length() + 1
        out.append(Prefix(value=lo, length=length))
        lo += size
    return out


@dataclasses.dataclass
class LPMTable:
    """Longest-prefix-match table: (prefix -> data), longest length wins.

    Mirrors the P4 'Calendar Epoch Assignment' table: keys are Event Numbers,
    data is the Calendar Epoch id. A wildcard (length-0) entry plays the role
    of the paper's wildcard match that is flipped to activate a new epoch.
    """

    entries: dict[Prefix, object] = dataclasses.field(default_factory=dict)

    def insert(self, prefix: Prefix, data) -> None:
        self.entries[prefix] = data

    def insert_range(self, lo: int, hi: int, data) -> list[Prefix]:
        ps = range_to_prefixes(lo, hi)
        for p in ps:
            self.insert(p, data)
        return ps

    def set_wildcard(self, data) -> None:
        self.insert(Prefix(0, 0), data)

    def delete(self, prefix: Prefix) -> None:
        del self.entries[prefix]

    def delete_many(self, prefixes) -> None:
        for p in prefixes:
            self.delete(p)

    def lookup(self, key: int):
        """Longest-prefix match; returns the entry data or None."""
        best = None
        best_len = -1
        for p, data in self.entries.items():
            if p.length > best_len and p.matches(key):
                best, best_len = data, p.length
        return best

    def boundaries(self) -> list[tuple[int, object]]:
        """Compile to a sorted list of (start_event, data) half-open segments.

        This is the equivalent dense representation the TPU data plane uses:
        segment i covers [start_i, start_{i+1}). Longest-prefix semantics are
        resolved here, once, at programming time.
        """
        # Collect all range edges.
        edges = {0, EVENT_SPACE}
        for p in self.entries:
            edges.add(p.lo)
            edges.add(p.hi)
        starts = sorted(edges)
        segs: list[tuple[int, object]] = []
        for s in starts[:-1]:
            segs.append((s, self.lookup(s)))
        # Merge adjacent segments with identical data.
        merged: list[tuple[int, object]] = []
        for s, d in segs:
            if merged and merged[-1][1] == d:
                continue
            merged.append((s, d))
        return merged
