"""Control plane: telemetry-driven dynamic load balancing (paper §I-B.4/5).

"Once an experiment starts running, for various reasons some compute nodes
will be faster or slower than others. The load balancer needs a mechanism to
change the weighting of the work it is delivering to each compute node."

The controller consumes per-member telemetry (receive-queue fill fraction and
processing rate — what the real EJ-FAT CP reads from CN daemons; in this
framework: per-DP-worker step time and backlog from telemetry/metrics.py),
produces new calendar weights with a PI controller per member, and schedules
hit-less epoch switches through the EpochManager. It also handles elastic
membership (add/remove CNs mid-run) and straggler mitigation (weight decay
for slow members).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Optional

import numpy as np

from repro.core.epoch import EpochManager, ReconfigurationError
from repro.core.tables import MemberSpec, TableError

logger = logging.getLogger(__name__)


@dataclasses.dataclass
class MemberTelemetry:
    """One feedback sample from a member (CN / DP worker)."""

    fill: float = 0.0          # receive-queue fill fraction in [0, 1]
    rate: float = 1.0          # events/s processed (relative ok)
    healthy: bool = True


@dataclasses.dataclass
class TelemetryArray:
    """One window of telemetry for ``[M]`` members as struct-of-arrays —
    the array-native form ``update_weights``/``feedback`` accept so the
    whole policy update runs as one fused pass (``WeightPolicy.update_lanes``)
    instead of M scalar dict updates.

    ``present[i] = False`` is the array form of a missing dict entry
    (``telemetry.get(mid) is None``): that member's weight and controller
    state are left untouched. ``present & ~healthy`` is an explicit drain."""

    member_ids: np.ndarray      # int64[M]
    fill: np.ndarray            # float64[M]
    rate: np.ndarray            # float64[M]
    healthy: np.ndarray         # bool[M]
    present: Optional[np.ndarray] = None   # bool[M]; None = all present

    @classmethod
    def from_dict(cls, telemetry: dict, member_ids) -> "TelemetryArray":
        """Lift a ``{member_id: MemberTelemetry | None}`` dict onto lanes
        aligned with ``member_ids`` (missing / None -> not present)."""
        ids = np.asarray(list(member_ids), np.int64)
        samples = [telemetry.get(int(m)) for m in ids]
        return cls(
            member_ids=ids,
            fill=np.asarray([0.0 if t is None else t.fill for t in samples],
                            np.float64),
            rate=np.asarray([1.0 if t is None else t.rate for t in samples],
                            np.float64),
            healthy=np.asarray([True if t is None else bool(t.healthy)
                                for t in samples], bool),
            present=np.asarray([t is not None for t in samples], bool))

    def align(self, member_ids) -> "TelemetryArray":
        """Re-lane onto ``member_ids``: members absent from this snapshot
        come back ``present=False`` (scalar-path "no sample"). The common
        case — already in the caller's lane order — is a no-op."""
        ids = np.asarray(member_ids, np.int64)
        if ids.shape == self.member_ids.shape and np.array_equal(
                ids, self.member_ids):
            return self
        if len(self.member_ids) == 0:
            # an empty window (no heartbeats at all) ≡ the empty dict: every
            # member is simply not-present (gathering via src=0 from
            # zero-length arrays would IndexError)
            n = len(ids)
            return TelemetryArray(
                member_ids=ids, fill=np.zeros(n), rate=np.ones(n),
                healthy=np.ones(n, bool), present=np.zeros(n, bool))
        pos = {int(m): i for i, m in enumerate(self.member_ids.tolist())}
        idx = np.asarray([pos.get(int(m), -1) for m in ids.tolist()],
                         np.int64)
        have = idx >= 0
        src = np.where(have, idx, 0)
        present = (np.ones(len(self.member_ids), bool)
                   if self.present is None else self.present)
        return TelemetryArray(
            member_ids=ids,
            fill=np.where(have, self.fill[src], 0.0),
            rate=np.where(have, self.rate[src], 1.0),
            healthy=np.where(have, self.healthy[src], True),
            present=have & present[src])


@dataclasses.dataclass
class ControlPolicy:
    target_fill: float = 0.5   # setpoint for receive-queue occupancy
    kp: float = 0.5            # proportional gain on (target - fill)
    ki: float = 0.1            # integral gain
    min_weight: float = 0.05   # floor so a member stays reachable
    max_weight: float = 8.0
    epoch_horizon: int = 1024  # events in the future to place the boundary


class LoadBalancerControlPlane:
    """Monitors telemetry, recomputes weights, drives epoch transitions.

    The reweighting math itself is pluggable (``repro.controld.policy``):
    ``reweighter`` is any ``WeightPolicy``; the default reproduces the
    historical proportional-PI update built from this instance's
    ``ControlPolicy`` gains. controld reservations select a policy per
    tenant (e.g. the EJFAT-style PID fill controller).
    """

    def __init__(self, manager: EpochManager, policy: ControlPolicy | None = None,
                 reweighter=None):
        self.manager = manager
        self.policy = policy or ControlPolicy()
        if reweighter is None:
            # deferred import: controld builds on core, not the reverse —
            # only the default-policy shim reaches back into controld
            from repro.controld.policy import PolicyConfig, ProportionalPolicy
            p = self.policy
            reweighter = ProportionalPolicy(PolicyConfig(
                target_fill=p.target_fill, kp=p.kp, ki=p.ki,
                min_weight=p.min_weight, max_weight=p.max_weight))
        self.reweighter = reweighter
        # engine for TelemetryArray updates: "np" (bit-identical to the
        # scalar dict path) or "jnp" (one fused device call per update)
        self.array_engine = "np"
        self.weights: dict[int, float] = {}
        self.members: dict[int, MemberSpec] = {}
        self.gc_skipped: list[tuple[int, str]] = []  # last sweep's (epoch_id, reason)
        self._scheduled_weights: dict[int, float] = {}  # as of the last epoch

    # -- lifecycle -----------------------------------------------------------
    def start(self, members: dict[int, MemberSpec], weights: Optional[dict] = None) -> int:
        self.members = dict(members)
        self.weights = {m: 1.0 for m in members} if weights is None else dict(weights)
        self.reweighter.reset(members)
        eid = self.manager.initialize(self.members, self.weights)
        self._scheduled_weights = dict(self.weights)
        return eid

    # -- feedback ------------------------------------------------------------
    def update_weights(self, telemetry) -> dict[int, float]:
        """One policy update: slow/full members shed slots, fast/empty
        members gain (see the concrete ``WeightPolicy`` for the math).

        ``telemetry`` is either the classic ``{member_id: MemberTelemetry}``
        dict or a ``TelemetryArray`` — the array form runs the whole update
        as one fused ``update_lanes`` pass over every member (the controld
        hot path: no per-member dict churn)."""
        if isinstance(telemetry, TelemetryArray):
            ids = np.fromiter(self.weights.keys(), np.int64,
                              len(self.weights))
            arr = telemetry.align(ids)
            w = np.fromiter(self.weights.values(), np.float64, len(ids))
            new = self.reweighter.update_lanes(
                ids, w, arr.fill, arr.healthy, present=arr.present,
                engine=self.array_engine)
            self.weights = {int(m): float(v)
                            for m, v in zip(ids.tolist(), new.tolist())}
        else:
            self.weights = self.reweighter.update(self.weights, telemetry)
        return self.weights

    def feedback(self, telemetry,
                 current_event: int,
                 reweight_threshold: float = 0.05) -> Optional[int]:
        """One closed-loop tick: PI-update the weights from telemetry and, if
        the result differs materially from what the *live epoch* was
        scheduled with (membership delta, a member going to zero / coming
        back, or a relative weight change above ``reweight_threshold``),
        schedule a hit-less epoch switch. Returns the new epoch id, or None
        when the weighting was left in place (no pointless reconfigurations —
        every epoch switch costs calendar rows until the old epoch quiesces).

        Hysteresis: while the previously scheduled boundary is still ahead of
        the traffic (the switch hasn't taken effect), no new epoch is
        scheduled — rescheduling before the last reconfiguration even
        activates would only stack up undrained future epochs and exhaust
        the calendar rows (paper §III-C: reconfigure, *wait to quiesce*,
        then reconfigure again).
        """
        cur = self.manager.records.get(self.manager.current_epoch)
        if cur is not None and current_event < cur.start_event:
            self.update_weights(telemetry)  # keep integrating telemetry
            return None
        sched = self._scheduled_weights
        new = self.update_weights(telemetry)
        changed = set(sched) != set(new)
        if not changed:
            for mid, w in new.items():
                sw = sched.get(mid, 0.0)
                if (w == 0.0) != (sw == 0.0):
                    changed = True
                    break
                if sw > 0 and abs(w - sw) / sw > reweight_threshold:
                    changed = True
                    break
        if not changed:
            return None
        return self.schedule_epoch(current_event)

    # -- elastic membership ----------------------------------------------------
    def add_members(self, members: dict[int, MemberSpec], weight: float = 1.0) -> None:
        for mid, spec in members.items():
            self.members[mid] = spec
            self.weights[mid] = weight
            self.reweighter.add_member(mid)

    def remove_members(self, member_ids) -> None:
        for mid in member_ids:
            self.members.pop(mid, None)
            self.weights.pop(mid, None)
            self.reweighter.forget_member(mid)

    def mark_failed(self, member_ids) -> None:
        """Fault handling: failed members are removed from the *next* epoch;
        the current epoch is immutable (stateless data plane keeps running)."""
        self.remove_members(member_ids)

    # -- quiesce / garbage collection ---------------------------------------------
    def garbage_collect(self, processed_event: int) -> list[int]:
        """Quiesce every drained epoch (end_event <= high-watermark of
        processed events). The paper's 'after waiting an appropriate time
        for all events from the previous Epoch to have quiesced' — here the
        watermark is explicit. Frees calendar rows + member entries.

        Epochs whose teardown is (legitimately) not yet possible — still
        reachable from the LPM table, or racing a concurrent reconfiguration
        — are recorded in ``gc_skipped`` (reset each sweep, so it reflects
        the most recent pass) and logged, then retried on the next sweep.
        Any other exception is a bug and propagates.
        """
        freed = []
        self.gc_skipped = []
        for eid, rec in sorted(self.manager.records.items()):
            if (rec.active and rec.end_event is not None
                    and rec.end_event <= processed_event
                    and eid != self.manager.current_epoch):
                try:
                    self.manager.quiesce(eid)
                    freed.append(eid)
                except (ReconfigurationError, TableError) as exc:
                    self.gc_skipped.append((eid, str(exc)))
                    logger.warning("gc: skipping epoch %d: %s", eid, exc)
        return freed

    # -- epoch scheduling --------------------------------------------------------
    def schedule_epoch(self, current_event: int, boundary: Optional[int] = None) -> int:
        """Activate the new weighting/membership at a near-future boundary."""
        if boundary is None:
            boundary = current_event + self.policy.epoch_horizon
        # Rapid successive reconfigurations: the boundary must stay strictly
        # ahead of the (possibly just-created) current epoch's start.
        cur = self.manager.records.get(self.manager.current_epoch)
        if cur is not None:
            boundary = max(boundary, cur.start_event + 1)
        live = {m: s for m, s in self.members.items() if self.weights.get(m, 0.0) > 0.0}
        live_w = {m: self.weights[m] for m in live}
        if not live:
            raise RuntimeError("no healthy members to schedule")
        eid = self.manager.reconfigure(live, live_w, boundary)
        self._scheduled_weights = dict(self.weights)
        return eid
