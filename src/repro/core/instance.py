"""Virtual LB instances (paper §I-C): four independent balancing contexts.

"The load balancer supports multiple IPv4 and IPv6 addresses, with each
destination address mapping to one of four independent instances of all of
the load balancing context." Instance selection is the L3 filter's job; each
instance owns an independent EpochManager/RouterState. Device-side, the four
table sets are stacked on a leading instance dimension and packets are routed
per-instance in one fused gather pass through core/dataplane.DataPlane
(DESIGN.md §2). Isolation is tested.
"""
from __future__ import annotations

from repro.core.epoch import EpochManager
from repro.core.tables import DeviceTables, L2L3Filter, L3Entry, stack_tables

N_INSTANCES = 4


class VirtualLoadBalancer:
    """One physical LB hosting N_INSTANCES independent contexts."""

    def __init__(self, max_members: int = 512):
        self.filter = L2L3Filter()
        self.instances = [EpochManager(max_members=max_members) for _ in range(N_INSTANCES)]

    def bind_address(self, ethertype: int, dst_ip: str, src_ip: str, instance_id: int) -> None:
        if not 0 <= instance_id < N_INSTANCES:
            raise ValueError(f"instance id {instance_id} out of range")
        self.filter.add_l3(L3Entry(ethertype=ethertype, dst_ip=dst_ip,
                                   src_ip=src_ip, instance_id=instance_id))

    def classify(self, mac_da: str, ethertype: int, dst_ip: str):
        """L2/L3 admission -> instance id, or None (packet discarded)."""
        entry = self.filter.admit(mac_da, ethertype, dst_ip)
        return None if entry is None else entry.instance_id

    def device_tables(self) -> DeviceTables:
        """Stacked tables, leading dim = instance id."""
        return stack_tables([em.device_tables() for em in self.instances])
