"""The unified data plane: one routing/dispatch entry point for the system.

The paper's keystone is a single low-latency pipeline — parse -> epoch ->
calendar -> member rewrite — that every packet traverses identically at line
rate (DESIGN.md §2). ``DataPlane`` is that pipeline's facade: it owns the
compiled ``DeviceTables`` (one LB instance, or the paper's four virtual
instances stacked on a leading dim) and exposes

    route(headers)          -> Route        (batched; one device call)
    route_events(ev, ent)   -> Route        (host-side event numbers)
    plan(member)            -> (pos, counts)  sort-based dispatch plan
    dispatch(...)           -> per-member packed buffers + drop accounting
    redistribute(mesh, ...) -> all_to_all exchange fn (shard_map)
    segment(bundles)        -> PacketBatch  (vectorized segmentation §II-C)
    reassembly_plan(...)    -> sort-based completion detection (DESIGN §Ingest)
    make_reassembler(...)   -> stateful batched CN-side reassembler

with a selectable backend:

    "jnp"     — the reference semantics in core/router.py (default off-TPU);
    "pallas"  — the VMEM-tiled kernels in kernels/ (interpret=True gives the
                CPU functional model; on TPU the compiled kernel);
    "auto"    — "pallas" on TPU, "jnp" elsewhere.

Both backends are property-tested equivalent (tests/test_dataplane.py),
including the multi-instance path. Every subsystem — serving front door,
streaming pipeline, training ingest, benchmarks — routes through this facade;
nothing else constructs table tuples or duplicates the routing math
(DESIGN.md §2, backend selection in §3).

``DataPlane`` is a registered pytree, so it can be constructed from traced
``DeviceTables`` inside jit (train_step does this) and passed across jit
boundaries.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import router as _router
from repro.core.protocol import decode_fields, encode_headers
from repro.core.router import Route
from repro.core.tables import DeviceTables, stack_tables

BACKENDS = ("jnp", "pallas", "auto")


def resolve_backend(backend: str) -> str:
    """"auto" -> "pallas" on TPU, "jnp" elsewhere (the interpret-mode kernel
    is a functional model, not a fast path — see DESIGN.md §3)."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DataPlane:
    """Facade over the programmed tables + routing/dispatch kernels."""

    tables: DeviceTables
    backend: str = dataclasses.field(default="auto", metadata=dict(static=True))
    interpret: Optional[bool] = dataclasses.field(default=None,
                                                  metadata=dict(static=True))

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_manager(cls, manager, backend: str = "auto",
                     interpret: Optional[bool] = None) -> "DataPlane":
        """One LB instance from an EpochManager (or anything with
        ``device_tables()``)."""
        return cls(tables=manager.device_tables(), backend=backend,
                   interpret=interpret)

    @classmethod
    def from_instances(cls, managers, backend: str = "auto",
                       interpret: Optional[bool] = None) -> "DataPlane":
        """Stacked virtual instances (paper §I-C) from per-instance managers."""
        return cls(tables=stack_tables([m.device_tables() for m in managers]),
                   backend=backend, interpret=interpret)

    def with_tables(self, tables: DeviceTables) -> "DataPlane":
        """Same backend selection, freshly programmed tables (epoch switch)."""
        return dataclasses.replace(self, tables=tables)

    # -- introspection -------------------------------------------------------
    @property
    def multi_instance(self) -> bool:
        return self.tables.seg_row.ndim == 2

    @property
    def n_instances(self) -> int:
        return int(self.tables.seg_row.shape[0]) if self.multi_instance else 1

    def _resolved(self) -> tuple[str, bool]:
        backend = resolve_backend(self.backend)
        interpret = (jax.default_backend() != "tpu"
                     if self.interpret is None else self.interpret)
        return backend, interpret

    # -- routing -------------------------------------------------------------
    def route(self, headers, instance_id=None) -> Route:
        """Route a batch of wire headers u32[N, 4] in one device call.

        ``instance_id`` (i32[N], from the L3 filter) is required iff the
        tables are stacked multi-instance.
        """
        if headers.ndim != 2 or headers.shape[-1] != 4:
            raise ValueError(f"headers must be [N, 4] u32 words, got {headers.shape}")
        if self.multi_instance and instance_id is None:
            raise ValueError("stacked tables require per-packet instance_id")
        if not self.multi_instance and instance_id is not None:
            raise ValueError("instance_id given but tables are single-instance")
        backend, interpret = self._resolved()
        if backend == "pallas":
            from repro.kernels import lb_route as _lb

            member, node, lane, valid = _lb.lb_route(
                headers, self.tables, instance_id, interpret=interpret)
            return Route(member=member, node=node, lane=lane, valid=valid > 0)
        w = headers.astype(jnp.uint32)
        f = decode_fields(w)
        if self.multi_instance:
            return _router.route_instances(
                self.tables, instance_id, f["event_hi"], f["event_lo"],
                f["entropy"], header_words=w)
        return _router.route(self.tables, f["event_hi"], f["event_lo"],
                             f["entropy"], header_words=w)

    def route_window(self, batch, instance_id=None):
        """Route a host-side ``PacketBatch`` arrival window.

        Pads the window to a power of two so window-size jitter doesn't grow
        the jit cache; padding rows carry a zero magic and fail header
        validation, so they can never alias a real packet. Returns host
        ``(member, node, lane, valid)`` arrays sliced back to the window.
        """
        from repro.data.segmentation import next_pow2

        n = len(batch)
        words = np.zeros((next_pow2(n), 4), np.uint32)
        words[:n] = batch.headers
        iid = None
        if instance_id is not None:
            iid = np.zeros((words.shape[0],), np.int32)
            iid[:n] = instance_id
            iid = jnp.asarray(iid)
        r = self.route(jnp.asarray(words), iid)
        return (np.asarray(r.member)[:n], np.asarray(r.node)[:n],
                np.asarray(r.lane)[:n], np.asarray(r.valid)[:n].astype(bool))

    def route_events(self, event_numbers, entropy, instance_id=None) -> Route:
        """Route host-side events (uint64 numbers + entropy) in one call.

        Encodes protocol headers and goes through the same ``route`` path, so
        hosts that never see wire packets (the serving front door) still
        traverse the identical pipeline.
        """
        ev = np.asarray(event_numbers, np.uint64)
        en = np.asarray(entropy, np.uint32)
        headers = jnp.asarray(encode_headers(ev, en))
        iid = None if instance_id is None else jnp.asarray(instance_id, jnp.int32)
        return self.route(headers, iid)

    # -- dispatch (pack routed packets into per-member buffers) --------------
    def plan(self, member, n_members: int):
        """Per-packet buffer positions + per-member totals (pos=-1 invalid)."""
        backend, interpret = self._resolved()
        if backend == "pallas":
            from repro.kernels import dispatch as _dispatch

            return _dispatch.dispatch_plan(member, n_members=n_members,
                                           interpret=interpret)
        from repro.kernels import ref as _ref

        return _ref.dispatch_plan_ref(member, n_members=n_members)

    def member_positions(self, member, n_members: int, capacity: int):
        """(pos, keep, counts) — the capacity-bounded sort-based pack."""
        return _router.member_positions(member, n_members, capacity)

    def dispatch(self, payload, member, n_members: int, capacity: int):
        """Scatter payloads into [n_members, capacity, ...] + occupancy."""
        return _router.dispatch(payload, member, n_members, capacity)

    def combine(self, payload, member, pos, n_members: int, capacity: int):
        """Scatter by a precomputed plan; returns (buf, occ, dropped)."""
        return combine_payloads(payload, member, pos, n_members=n_members,
                                capacity=capacity)

    # -- on-mesh redistribution ----------------------------------------------
    def redistribute(self, mesh, axis_names, capacity_per_src: int):
        """Build the shard_map all_to_all exchange (LB -> CN delivery)."""
        return _router.make_redistribute(mesh, axis_names, capacity_per_src)

    # -- ingest (segmentation & reassembly, paper §II-C) ----------------------
    @staticmethod
    def segment(bundles, mtu_payload: Optional[int] = None):
        """Segment a bundle batch into a PacketBatch (one vectorized pass).

        Host-side by construction (DAQ bundles are host bytes); the LB does
        not participate in segmentation, but the facade is the one ingest
        entry point so callers never touch the layout directly.
        """
        from repro.data import segmentation as _seg

        mtu = _seg.DEFAULT_MTU_PAYLOAD if mtu_payload is None else mtu_payload
        return _seg.segment_bundles(bundles, mtu)

    def reassembly_plan(self, ev_hi, ev_lo, daq, seg_index, n_segs, valid):
        """Sort-based reassembly program for one window (same backend switch
        as routing: jnp reference or the Pallas seg-mask kernel)."""
        from repro.data import reassembly as _ra

        backend, interpret = self._resolved()
        return _ra.reassembly_plan(ev_hi, ev_lo, daq, seg_index, n_segs,
                                   valid, backend=backend, interpret=interpret)

    def make_reassembler(self, mtu_payload: Optional[int] = None,
                         timeout_windows: Optional[int] = None,
                         device_plan: bool = False):
        """A stateful BatchReassembler. The CN reassembly daemon is host-side
        (the LB does not participate, paper §II-C), so the default engine is
        the numpy plan; ``device_plan=True`` binds it to this plane's jnp /
        Pallas ``reassembly_plan`` instead (device-resident ingest)."""
        from repro.data import reassembly as _ra
        from repro.data import segmentation as _seg

        backend, interpret = self._resolved()
        mtu = _seg.DEFAULT_MTU_PAYLOAD if mtu_payload is None else mtu_payload
        return _ra.BatchReassembler(
            mtu_payload=mtu, timeout_windows=timeout_windows,
            backend=backend if device_plan else "np", interpret=interpret)


class DataPlaneCache:
    """Audit-log-watermark cache around ``DataPlane.from_manager`` /
    ``from_instances``.

    Hosts that stream against mutable ``EpochManager``s (pipeline, serving
    front door, closed-loop and simnet drivers) must not recompile tables
    once per arrival window — only after a control plane actually touches
    the epoch state. The audit log length (summed across managers for the
    stacked multi-instance case) is that watermark; this is the one shared
    implementation of the idiom.
    """

    def __init__(self, manager, backend: str = "auto",
                 interpret: Optional[bool] = None):
        """``manager``: one EpochManager, or a list of them (one per
        stacked virtual LB instance)."""
        self.managers = manager if isinstance(manager, (list, tuple)) \
            else [manager]
        self.backend = backend
        self.interpret = interpret
        self._dp: Optional[DataPlane] = None
        self._version = -1

    @property
    def manager(self):
        return self.managers[0]

    def get(self) -> DataPlane:
        version = sum(len(m.audit) for m in self.managers)
        if self._dp is None or version != self._version:
            if len(self.managers) > 1:
                self._dp = DataPlane.from_instances(
                    self.managers, backend=self.backend,
                    interpret=self.interpret)
            else:
                self._dp = DataPlane.from_manager(
                    self.managers[0], backend=self.backend,
                    interpret=self.interpret)
            self._version = version
        return self._dp


@functools.partial(jax.jit, static_argnames=("n_members", "capacity"))
def combine_payloads(payload, member, pos, *, n_members: int, capacity: int):
    """Scatter payloads by (member, pos) into [n_members, capacity, ...] buffers.

    Returns (buffers, occupancy, dropped_count). Drops (pos >= capacity) are
    counted, never silent.
    """
    keep = (member >= 0) & (pos >= 0) & (pos < capacity)
    # Masked packets are sent to an out-of-bounds index so mode="drop"
    # discards the write entirely (an in-bounds dummy index would clobber a
    # real packet's slot).
    m_idx = jnp.where(keep, member, n_members)
    p_idx = jnp.where(keep, pos, capacity)
    buf = jnp.zeros((n_members, capacity) + payload.shape[1:], payload.dtype)
    buf = buf.at[m_idx, p_idx].set(payload, mode="drop")
    occ = jnp.zeros((n_members, capacity), jnp.int32).at[m_idx, p_idx].set(
        jnp.ones_like(member, jnp.int32), mode="drop"
    )
    dropped = jnp.sum((member >= 0) & ~keep)
    return buf, occ, dropped
