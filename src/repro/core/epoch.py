"""Hit-less epoch reconfiguration (paper §III-B.2..4 and §III-C).

The paper's central operational procedure: a new configuration is built
*from the end of the P4 pipeline toward the start* — members first, then the
calendar, then the epoch LPM connection — so that by the time an Event Number
can reach a new epoch, every downstream table it needs is already programmed.
Activation is the LPM/wildcard flip; cleanup happens only after the old epoch
has quiesced. Epochs that are reachable are immutable.

`EpochManager` enforces that ordering mechanically and keeps an audit log so
tests can assert the invariants (no reachable-epoch mutation, build-backwards
order, zero-drop transitions).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.calendar import build_calendar
from repro.core.tables import DeviceTables, MemberSpec, RouterState, TableError


class ReconfigurationError(RuntimeError):
    pass


@dataclasses.dataclass
class EpochRecord:
    epoch_id: int
    start_event: int           # inclusive
    end_event: Optional[int]   # exclusive; None = open-ended (wildcard)
    prefixes: list = dataclasses.field(default_factory=list)
    members: dict = dataclasses.field(default_factory=dict)  # member_id -> MemberSpec
    active: bool = True


class EpochManager:
    """Drives one LB instance through initialize / reconfigure / quiesce."""

    def __init__(self, max_members: int = 512):
        self.state = RouterState(max_members=max_members)
        self.records: dict[int, EpochRecord] = {}
        self._next_epoch_id = 0
        self._next_member_id = 0
        self.audit: list[tuple] = []
        self.current_epoch: Optional[int] = None

    # -- member id allocation (control plane owns ids, paper §III-B.2) ------
    def allocate_member_ids(self, n: int) -> list[int]:
        ids = list(range(self._next_member_id, self._next_member_id + n))
        self._next_member_id += n
        return ids

    def _allocate_epoch_id(self) -> int:
        eid = self._next_epoch_id
        self._next_epoch_id += 1
        return eid

    # -- initialization (out-of-service, paper §III-B) ------------------------
    def initialize(self, members: dict[int, MemberSpec], weights) -> int:
        """Program members -> calendar -> map ALL event numbers to epoch 0."""
        if self.records:
            raise ReconfigurationError("already initialized; use reconfigure()")
        eid = self._allocate_epoch_id()
        # 1) Populate Member Lookup and Rewrite (end of pipeline).
        for mid, spec in members.items():
            self.state.insert_member(mid, spec)
            self.audit.append(("member_insert", eid, mid))
        # 2) Populate the Calendar for this epoch.
        cal = build_calendar(
            np.asarray(sorted(members), dtype=np.int32),
            np.asarray([weights[m] for m in sorted(members)], dtype=np.float64),
            n_slots=self.state.n_slots,
        )
        self.state.insert_calendar(eid, cal)
        self.audit.append(("calendar_insert", eid))
        # 3) Connect: map the entire Event Number space to the first epoch.
        self.state.set_wildcard_epoch(eid)
        self.audit.append(("epoch_connect", eid))
        self.records[eid] = EpochRecord(
            epoch_id=eid, start_event=0, end_event=None, prefixes=[],
            members=dict(members),
        )
        self.current_epoch = eid
        return eid

    # -- in-service reconfiguration (paper §III-C) -----------------------------
    def reconfigure(
        self,
        members: dict[int, MemberSpec],
        weights,
        boundary_event: int,
    ) -> int:
        """Activate a new epoch at ``boundary_event`` without disruption.

        Steps follow §III-C literally; the old epoch's range is pinned with
        explicit LPM prefixes *before* the wildcard is flipped, so no event is
        ever routed by a half-programmed configuration.
        """
        if self.current_epoch is None:
            raise ReconfigurationError("initialize() first")
        cur = self.records[self.current_epoch]
        if cur.end_event is not None:
            raise ReconfigurationError("current epoch already bounded")
        if boundary_event <= cur.start_event:
            raise ReconfigurationError("boundary must be in the (near) future")

        # 1) Allocate the next free Calendar Epoch ID.
        eid = self._allocate_epoch_id()
        # 2) Insert new Member entries for any CNs changed in the next epoch.
        for mid, spec in members.items():
            if mid not in self.state.members or self.state.members[mid] != spec:
                self.state.insert_member(mid, spec)
                self.audit.append(("member_insert", eid, mid))
        # 3) Compute and insert an entirely new calendar under the new id.
        cal = build_calendar(
            np.asarray(sorted(members), dtype=np.int32),
            np.asarray([weights[m] for m in sorted(members)], dtype=np.float64),
            n_slots=self.state.n_slots,
        )
        self.state.insert_calendar(eid, cal)
        self.audit.append(("calendar_insert", eid))
        # 4) Pin the current epoch: LPM prefixes over [cur.start, boundary).
        prefixes = self.state.connect_epoch_range(
            cur.start_event, boundary_event, cur.epoch_id
        )
        cur.prefixes.extend(prefixes)
        cur.end_event = boundary_event
        self.audit.append(("epoch_pin", cur.epoch_id, cur.start_event, boundary_event))
        # 5) Flip the wildcard to the new epoch => activation.
        self.state.set_wildcard_epoch(eid)
        self.audit.append(("epoch_connect", eid))

        self.records[eid] = EpochRecord(
            epoch_id=eid, start_event=boundary_event, end_event=None,
            members=dict(members),
        )
        self.current_epoch = eid
        return eid

    # -- cleanup after quiesce (paper §III-C tail) ------------------------------
    def quiesce(self, epoch_id: int) -> None:
        """Tear down a drained epoch: LPM prefixes -> calendar -> members."""
        rec = self.records[epoch_id]
        if rec.end_event is None or epoch_id == self.current_epoch:
            raise ReconfigurationError("cannot quiesce the active epoch")
        # 1) Delete the LPM prefix matches (disconnects the epoch).
        self.state.epoch_lpm.delete_many(rec.prefixes)
        self.audit.append(("epoch_disconnect", epoch_id))
        # 2) Delete the LB Calendar for the epoch.
        self.state.delete_calendar(epoch_id)
        self.audit.append(("calendar_delete", epoch_id))
        # 3) Delete any unreferenced member rewrites.
        still_used = set()
        for cal in self.state.calendars.values():
            still_used.update(int(v) for v in np.unique(cal))
        for mid in list(self.state.members):
            if mid not in still_used:
                try:
                    self.state.delete_member(mid)
                    self.audit.append(("member_delete", mid))
                except TableError:
                    pass
        rec.active = False

    # -- device view -----------------------------------------------------------
    def device_tables(self) -> DeviceTables:
        return self.state.compile()
