"""The P4 match-action table suite (paper fig. 4), host + device representations.

Pipeline order (paper §III):

    L2 Input Filter -> L3 Input Filter -> Calendar Epoch Assignment
        -> Calendar to Member Map -> Member Lookup and Rewrite

The L2/L3 filters are control-plane/NIC concerns (MAC/IP identities, ARP/ND/
ICMP participation); they are modeled host-side for fidelity and select the
LB *instance*. The last three tables are the data plane proper and compile to
dense arrays (`DeviceTables`) consumed by the jnp router and the Pallas
kernel. Epoch LPM entries are kept P4-faithful (core/lpm.py) and compiled to a
sorted-boundary segment representation at programming time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lpm
from repro.core.protocol import CALENDAR_SLOTS, LB_SERVICE_PORT, split64

# Fixed device-table capacities (jit-stable shapes).
MAX_EPOCH_SEGMENTS = 16  # distinct contiguous event-number segments
MAX_EPOCH_ROWS = 8       # resident calendars (past/current/future epochs)
DEFAULT_MAX_MEMBERS = 512


class TableError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class MemberSpec:
    """Value side of the 'Member Lookup and Rewrite' table.

    In the TPU mapping, ``node_id`` is the data-parallel slice index the
    member corresponds to, ``base_lane``/``lane_bits`` replace the UDP base
    port / entropy-mask width (2**lane_bits receive lanes per member — the
    paper's RSS mechanism). ``ip``/``mac`` are kept for protocol fidelity.
    """

    node_id: int
    base_lane: int = 0
    lane_bits: int = 0  # 2**lane_bits contiguous lanes
    ip: str = ""
    mac: str = ""
    udp_base_port: int = LB_SERVICE_PORT + 1

    def __post_init__(self):
        if not 0 <= self.lane_bits <= 16:
            raise TableError("entropy/lane bits must be a power-of-2 range, 0..16")


@dataclasses.dataclass(frozen=True)
class L2Entry:
    mac_da: str
    src_mac: str  # preferred unicast MAC SA for responses


@dataclasses.dataclass(frozen=True)
class L3Entry:
    ethertype: int  # 0x0800 IPv4 / 0x86dd IPv6 / 0x0806 ARP
    dst_ip: str
    src_ip: str  # preferred unicast IP for responses
    instance_id: int


class L2L3Filter:
    """Layer 2 + Layer 3 input filters. Reject-by-default (paper §III-B.1)."""

    def __init__(self):
        self.l2: dict[str, L2Entry] = {}
        self.l3: dict[tuple[int, str], L3Entry] = {}

    def add_l2(self, entry: L2Entry) -> None:
        self.l2[entry.mac_da.lower()] = entry

    def add_l3(self, entry: L3Entry) -> None:
        self.l3[(entry.ethertype, entry.dst_ip.lower())] = entry

    def admit(self, mac_da: str, ethertype: int, dst_ip: str) -> Optional[L3Entry]:
        """Returns the matched L3 entry (with instance id) or None (drop)."""
        if mac_da.lower() not in self.l2:
            return None
        return self.l3.get((ethertype, dst_ip.lower()))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeviceTables:
    """Dense, jit-stable arrays for the data-plane lookups.

    seg_* arrays describe sorted half-open segments of the event-number
    space: event e belongs to segment i where i is the largest index with
    seg_start_i <= e; ``seg_row[i]`` is the row in ``calendars`` (or -1 =>
    discard). Calendars hold member ids; member_* hold the rewrite table.
    """

    seg_start_hi: jnp.ndarray  # uint32[MAX_EPOCH_SEGMENTS]
    seg_start_lo: jnp.ndarray  # uint32[MAX_EPOCH_SEGMENTS]
    seg_row: jnp.ndarray       # int32[MAX_EPOCH_SEGMENTS]
    calendars: jnp.ndarray     # int32[MAX_EPOCH_ROWS, 512]
    member_node: jnp.ndarray   # int32[M]
    member_base_lane: jnp.ndarray  # int32[M]
    member_lane_mask: jnp.ndarray  # int32[M]  ((1<<lane_bits) - 1)
    member_valid: jnp.ndarray  # int32[M]

    @property
    def max_members(self) -> int:
        return int(self.member_node.shape[0])

    def tree_flatten(self):  # manual pytree-ish helper
        return dataclasses.astuple(self)


class RouterState:
    """Host-side mutable programming state for ONE LB instance.

    Owns the P4-faithful structures (LPM table over event numbers, calendar
    rows, member map) and compiles them to `DeviceTables`.
    """

    def __init__(self, max_members: int = DEFAULT_MAX_MEMBERS, n_slots: int = CALENDAR_SLOTS):
        self.n_slots = n_slots
        self.max_members = max_members
        self.epoch_lpm = lpm.LPMTable()
        self.calendars: dict[int, np.ndarray] = {}  # epoch_id -> int32[n_slots]
        self.members: dict[int, MemberSpec] = {}    # member_id -> spec
        self._epoch_rows: dict[int, int] = {}       # epoch_id -> device row
        self._free_rows = list(range(MAX_EPOCH_ROWS))

    # -- Member Lookup and Rewrite table ------------------------------------
    def insert_member(self, member_id: int, spec: MemberSpec) -> None:
        if not 0 <= member_id < self.max_members:
            raise TableError(f"member id {member_id} out of range (max {self.max_members})")
        self.members[member_id] = spec

    def delete_member(self, member_id: int) -> None:
        for eid, cal in self.calendars.items():
            if (cal == member_id).any():
                raise TableError(
                    f"member {member_id} still referenced by calendar epoch {eid}"
                )
        del self.members[member_id]

    # -- Calendar to Member Map table ---------------------------------------
    def insert_calendar(self, epoch_id: int, calendar: np.ndarray) -> None:
        calendar = np.asarray(calendar, dtype=np.int32)
        if calendar.shape != (self.n_slots,):
            raise TableError(f"calendar must have {self.n_slots} slots")
        # Paper NOTE: all slots MUST have a member assigned.
        missing = set(np.unique(calendar).tolist()) - set(self.members)
        if missing:
            raise TableError(f"calendar references unprogrammed members {sorted(missing)}")
        if epoch_id in self.calendars:
            raise TableError(f"epoch {epoch_id} calendar is immutable once programmed")
        if not self._free_rows:
            raise TableError("no free calendar rows; quiesce old epochs first")
        self.calendars[epoch_id] = calendar
        self._epoch_rows[epoch_id] = self._free_rows.pop(0)

    def delete_calendar(self, epoch_id: int) -> None:
        for _, data in self.epoch_lpm.entries.items():
            if data == epoch_id:
                raise TableError(f"epoch {epoch_id} still reachable from LPM table")
        del self.calendars[epoch_id]
        self._free_rows.append(self._epoch_rows.pop(epoch_id))

    # -- Calendar Epoch Assignment table ------------------------------------
    def connect_epoch_range(self, lo: int, hi: int, epoch_id: int) -> list[lpm.Prefix]:
        if epoch_id not in self.calendars:
            raise TableError("downstream tables must be populated before connecting an epoch")
        return self.epoch_lpm.insert_range(lo, hi, epoch_id)

    def set_wildcard_epoch(self, epoch_id: int) -> None:
        if epoch_id not in self.calendars:
            raise TableError("downstream tables must be populated before connecting an epoch")
        self.epoch_lpm.set_wildcard(epoch_id)

    def reachable_epochs(self) -> set[int]:
        return {d for d in self.epoch_lpm.entries.values() if d is not None}

    # -- Compilation ----------------------------------------------------------
    def compile(self) -> DeviceTables:
        segs = self.epoch_lpm.boundaries()
        if len(segs) > MAX_EPOCH_SEGMENTS:
            raise TableError(
                f"{len(segs)} epoch segments exceed device capacity {MAX_EPOCH_SEGMENTS}"
            )
        starts = np.zeros(MAX_EPOCH_SEGMENTS, dtype=np.uint64)
        rows = np.full(MAX_EPOCH_SEGMENTS, -1, dtype=np.int32)
        for i, (start, eid) in enumerate(segs):
            starts[i] = start
            rows[i] = self._epoch_rows[eid] if eid is not None and eid in self._epoch_rows else -1
        # Pad trailing segments at the top of the event space, repeating the
        # last real row so an event equal to 2**64-1 still routes correctly
        # (the compare-count lookup lands on the last padded segment).
        pad_row = rows[len(segs) - 1] if segs else np.int32(-1)
        for i in range(len(segs), MAX_EPOCH_SEGMENTS):
            starts[i] = np.uint64(2**64 - 1)
            rows[i] = pad_row

        cal = np.zeros((MAX_EPOCH_ROWS, self.n_slots), dtype=np.int32)
        for eid, c in self.calendars.items():
            cal[self._epoch_rows[eid]] = c

        m = self.max_members
        node = np.full(m, -1, dtype=np.int32)
        base = np.zeros(m, dtype=np.int32)
        mask = np.zeros(m, dtype=np.int32)
        valid = np.zeros(m, dtype=np.int32)
        for mid, spec in self.members.items():
            node[mid] = spec.node_id
            base[mid] = spec.base_lane
            mask[mid] = (1 << spec.lane_bits) - 1
            valid[mid] = 1

        hi, lo = split64(starts)
        return DeviceTables(
            seg_start_hi=jnp.asarray(hi),
            seg_start_lo=jnp.asarray(lo),
            seg_row=jnp.asarray(rows),
            calendars=jnp.asarray(cal),
            member_node=jnp.asarray(node),
            member_base_lane=jnp.asarray(base),
            member_lane_mask=jnp.asarray(mask),
            member_valid=jnp.asarray(valid),
        )


def stack_tables(tables: list[DeviceTables]) -> DeviceTables:
    """Stack per-instance tables along a leading 'LB instance' dimension.

    The paper supports four independent virtual LB instances per device
    (§I-C); the router gathers by instance id.
    """
    fields = {}
    for f in dataclasses.fields(DeviceTables):
        fields[f.name] = jnp.stack([getattr(t, f.name) for t in tables])
    return DeviceTables(**fields)
