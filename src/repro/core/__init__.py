"""EJ-FAT core: the paper's contribution as a composable JAX module."""

from repro.core.calendar import build_calendar, calendar_counts, quotas_from_weights
from repro.core.control_plane import (
    ControlPolicy,
    LoadBalancerControlPlane,
    MemberTelemetry,
)
from repro.core.epoch import EpochManager, ReconfigurationError
from repro.core.instance import N_INSTANCES, VirtualLoadBalancer
from repro.core.dataplane import DataPlane, combine_payloads, resolve_backend
from repro.core.lpm import LPMTable, Prefix, range_to_prefixes
from repro.core.protocol import (
    CALENDAR_SLOTS,
    LB_SERVICE_PORT,
    LBHeader,
    MAGIC,
    decode_fields,
    encode_headers,
    join64,
    split64,
    validate,
)
from repro.core.router import Route, dispatch, make_redistribute, member_positions, route
from repro.core.tables import DeviceTables, MemberSpec, RouterState, TableError

__all__ = [
    "CALENDAR_SLOTS", "ControlPolicy", "DataPlane", "DeviceTables", "EpochManager",
    "LBHeader", "LB_SERVICE_PORT", "LPMTable", "LoadBalancerControlPlane",
    "MAGIC", "MemberSpec", "MemberTelemetry", "N_INSTANCES", "Prefix",
    "ReconfigurationError", "Route", "RouterState", "TableError",
    "VirtualLoadBalancer", "build_calendar", "calendar_counts",
    "combine_payloads", "decode_fields", "dispatch", "encode_headers",
    "join64", "make_redistribute", "member_positions",
    "quotas_from_weights", "range_to_prefixes", "resolve_backend", "route",
    "split64", "validate",
]
