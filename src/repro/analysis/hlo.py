"""Collective-byte accounting over compiled (post-SPMD) HLO text —
computation-aware: ops inside while bodies (scan-over-layers, q-chunk scans)
are multiplied by the loop trip count (XLA annotates scheduled whiles with
backend_config known_trip_count).

In scheduled HLO text operands are bare value names, so sizes derive from the
*output* shape + the replica-group size, with ring-algorithm wire factors
(per participating device):

    all-gather:         out = full gathered buffer F;  wire = F*(g-1)/g
    all-reduce:         out = F;                       wire = 2*F*(g-1)/g
    reduce-scatter:     out = shard s, F = s*g;        wire = F*(g-1)/g
    all-to-all:         out = F;                       wire = F*(g-1)/g
    collective-permute: out = F;                       wire = F

NOTE (documented in EXPERIMENTS.md): the CPU backend's float normalization
widens bf16 buffers to f32, so byte figures are ~2x the TPU bf16 values;
the roofline applies the bf16 correction.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COMP_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*->.*\{\s*$")
_OP_RE = re.compile(
    r"=\s*(?P<out>.*?)\s*"
    r"\b(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?(?:\.\d+)?\("
)
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_BODY_RE = re.compile(r"\bbody=%?([\w\.\-]+)")
_COND_RE = re.compile(r"\bcondition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"n"\s*:\s*"(\d+)"')
_CALL_RE = re.compile(r"\b(?:to_apply|true_computation|false_computation)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^\}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2


def _parse_computations(hlo_text: str):
    """Split text into {comp_name: [lines]}, and find the entry name."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps, entry


def _multipliers(comps, entry):
    """Effective execution count per computation (trip-count propagation)."""
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            mb = _BODY_RE.search(line)
            if mb and "while(" in line:
                trip = _TRIP_RE.search(line)
                n = float(trip.group(1)) if trip else 1.0
                edges[name].append((mb.group(1), n))
                mc = _COND_RE.search(line)
                if mc:
                    edges[name].append((mc.group(1), n + 1))
                continue
            for callee in _CALL_RE.findall(line):
                edges[name].append((callee, 1.0))
            mbr = _BRANCH_RE.search(line)
            if mbr:
                for c in mbr.group(1).split(","):
                    c = c.strip().lstrip("%")
                    if c:
                        edges[name].append((c, 1.0))
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        return {k: 1.0 for k in comps}
    mult[entry] = 1.0
    # propagate (graph is a DAG of computations)
    changed = True
    it = 0
    while changed and it < 100:
        changed = False
        it += 1
        snapshot = dict(mult)
        new = defaultdict(float)
        new[entry] = 1.0
        for src, outs in edges.items():
            for dst, n in outs:
                new[dst] += snapshot.get(src, 0.0) * n
        new[entry] = 1.0
        if dict(new) != dict(snapshot):
            changed = True
        mult = new
    return mult


@dataclasses.dataclass
class CollectiveStats:
    ops: dict            # op kind -> static count
    dynamic_ops: dict    # op kind -> trip-weighted count
    payload_bytes: dict  # op kind -> full-buffer bytes (per device, weighted)
    wire_bytes: dict     # op kind -> ring-model wire bytes (per device, weighted)
    total_payload: float
    total_wire: float

    def to_json(self):
        return {
            "ops": dict(self.ops),
            "dynamic_ops": {k: float(v) for k, v in self.dynamic_ops.items()},
            "payload_bytes": {k: float(v) for k, v in self.payload_bytes.items()},
            "wire_bytes": {k: float(v) for k, v in self.wire_bytes.items()},
            "total_payload_bytes": float(self.total_payload),
            "total_wire_bytes": float(self.total_wire),
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    comps, entry = _parse_computations(hlo_text)
    mult = _multipliers(comps, entry)
    ops = defaultdict(int)
    dyn = defaultdict(float)
    payload = defaultdict(float)
    wire = defaultdict(float)
    for cname, lines in comps.items():
        k = mult.get(cname, 0.0)
        if k <= 0:
            continue
        for line in lines:
            m = _OP_RE.search(line)
            if not m:
                continue
            kind = m.group("kind")
            shapes = _SHAPE_RE.findall(m.group("out"))
            if not shapes:
                continue
            out_bytes = sum(_shape_bytes(d, s) for d, s in shapes)
            if m.group("start"):
                out_bytes //= 2  # async tuple aliases (in, out)
            g = _group_size(line)
            if g <= 1:
                continue
            frac = (g - 1) / g
            if kind == "all-gather":
                full, w = out_bytes, out_bytes * frac
            elif kind == "all-reduce":
                full, w = out_bytes, 2.0 * out_bytes * frac
            elif kind == "reduce-scatter":
                full = out_bytes * g
                w = full * frac
            elif kind == "all-to-all":
                full, w = out_bytes, out_bytes * frac
            else:  # collective-permute
                full, w = out_bytes, float(out_bytes)
            ops[kind] += 1
            dyn[kind] += k
            payload[kind] += k * full
            wire[kind] += k * w
    return CollectiveStats(
        ops=ops, dynamic_ops=dyn, payload_bytes=payload, wire_bytes=wire,
        total_payload=float(sum(payload.values())),
        total_wire=float(sum(wire.values())),
    )
