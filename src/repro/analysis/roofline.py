"""Three-term roofline from dry-run artifacts (TPU v5e constants).

    compute term    = FLOPs_global   / (chips * 197e12)      [bf16 peak]
    memory term     = bytes_global   / (chips * 819e9)       [HBM bw]
    collective term = wire_bytes_gbl / (chips * 50e9)        [per-link ICI]

cost_analysis() on the partitioned module reports *per-device* flops/bytes;
global = per_device * chips, so each term equals per_device_quantity /
per_chip_rate. MODEL_FLOPS = 6*N*D (train) or 2*N_active*D (prefill/decode);
the ratio MODEL_FLOPS / HLO_FLOPs_global exposes remat/padding/redundancy.
"""
from __future__ import annotations

import dataclasses
import json
import os

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # bytes/s / chip
ICI_BW = 50e9         # bytes/s / link (1 effective link assumed; see notes)
BF16_CORRECTION = 0.5  # CPU backend widens bf16 buffers to f32 in HLO text


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float
    step_time_s: float
    hw_utilization: float  # model_flops / (step_time * chips * peak)
    roofline_fraction: float  # max(compute, memory) / step — how close the
    # projected step sits to its unavoidable (compute|memory) bound; the
    # right score for memory-bound decode shapes where compute-MFU ~ 0.

    def to_json(self):
        return dataclasses.asdict(self)


def analyze(artifact: dict) -> Roofline:
    """Terms: compute/memory from the analytic per-device model
    (analysis/perfmodel.py — HLO cost_analysis counts scan bodies once, see
    module docstring), collectives measured from trip-count-aware HLO parsing
    with the bf16 correction (CPU HLO stores would-be-bf16 buffers as f32)."""
    chips = artifact["chips"]
    fpd = float(artifact["analytic"]["flops"])
    bpd = float(artifact["analytic"]["bytes_hbm"])
    wire = float(artifact["collectives"]["total_wire_bytes"]) * BF16_CORRECTION
    model_flops = float(artifact.get("model_flops", 0.0))

    compute_s = fpd / PEAK_FLOPS
    memory_s = bpd / HBM_BW
    collective_s = wire / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step = max(terms.values())
    useful = model_flops / (fpd * chips) if fpd else 0.0
    hw_util = model_flops / (step * chips * PEAK_FLOPS) if step > 0 else 0.0
    bound = max(compute_s, memory_s)
    return Roofline(
        arch=artifact["arch"].replace("-", "_").replace(".", "_"),
        shape=artifact["shape"], mesh=artifact["mesh"],
        chips=chips, flops_per_device=fpd, bytes_per_device=bpd,
        wire_bytes_per_device=wire, model_flops=model_flops,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, useful_ratio=useful, step_time_s=step,
        hw_utilization=hw_util,
        roofline_fraction=bound / step if step > 0 else 0.0,
    )


def load_artifacts(art_dir: str) -> list[dict]:
    out = []
    for f in sorted(os.listdir(art_dir)):
        if f.endswith(".json"):
            with open(os.path.join(art_dir, f)) as fh:
                out.append(json.load(fh))
    return out


def markdown_table(rooflines: list[Roofline]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | useful FLOP ratio | roofline util |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in rooflines:
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.4g} | "
            f"{r.memory_s:.4g} | {r.collective_s:.4g} | **{r.bottleneck}** | "
            f"{r.useful_ratio:.3f} | {r.hw_utilization:.3f} |"
        )
    return hdr + "\n".join(rows) + "\n"
