"""Analytic per-device FLOP and HBM-byte model per (arch x shape) cell.

Why analytic: XLA's HloCostAnalysis visits while bodies once, so with
scan-over-layers the reported flops/bytes undercount by ~n_layers. The
collective term IS measured (trip-count-aware HLO parsing, analysis/hlo.py);
compute and memory terms come from this model, which follows standard MFU
accounting (PaLM appendix-B style), itemized:

  fwd flops  = 2 * N_active_local * tokens_local + attention/ssm mixer terms
  train      = 4x fwd (bwd = 2x, +1 fwd remat)   [remat=full per layer]
  bytes      = params traffic + moments + saved residuals + mixer working set
               + logits + (decode) cache read

Everything is per device per step, assuming bf16 weights/activations and
fp32 (or int8, for 8-bit Adam) moments. Accuracy target is the bottleneck
decision, not 3 digits; each item is listed in the artifact for inspection.
"""
from __future__ import annotations

import dataclasses

from repro.launch import shapes as SH
from repro.models.config import ModelConfig

WB = 2       # bf16 weight/activation bytes
F32B = 4


@dataclasses.dataclass
class PerfEstimate:
    flops: float                 # per device per step
    bytes_hbm: float             # per device per step
    items: dict

    def to_json(self):
        return {"flops": self.flops, "bytes_hbm": self.bytes_hbm,
                "items": self.items}


def _mixer_flops_per_token(cfg: ModelConfig, ctx: int) -> float:
    """Attention-score/value (or SSM) flops per token, full model (all
    layers), excluding the projections (those are in 6N)."""
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        w = min(ctx, cfg.swa_window) if cfg.swa_window else ctx
        eff = w if cfg.swa_window else ctx / 2 if cfg.causal else ctx
        per_layer = 2 * 2 * eff * cfg.n_heads * cfg.hd  # qk^T + pv
        layers = cfg.n_layers
        if cfg.family == "vlm":
            n_cross = cfg.n_layers // cfg.cross_attn_every
            layers = cfg.n_layers - n_cross
            per_layer_cross = 2 * 2 * cfg.n_vision_tokens * cfg.n_heads * cfg.hd
            return layers * per_layer + n_cross * per_layer_cross
        return layers * per_layer
    if cfg.family == "hybrid":
        # mamba2 SSD, chunk L=128: intra (L*(N + P)) + state (2*N*P) per head
        L, N, P, H = 128, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_heads
        mamba = 2 * H * (L * (N + P) + 2 * N * P)
        n_attn = cfg.n_layers // cfg.attn_every
        attn = n_attn * 2 * 2 * (ctx / 2) * cfg.n_heads * cfg.hd / cfg.n_layers
        return cfg.n_layers * (mamba + attn)
    if cfg.family == "ssm":
        P, H = cfg.ssm_head_dim, cfg.rwkv_heads
        return cfg.n_layers * 5 * H * P * P  # wkv state read+update
    return 0.0


def _decode_mixer_flops(cfg: ModelConfig, ctx: int) -> float:
    """Per new token: attention against the cache / state update."""
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        w = min(ctx, cfg.swa_window) if cfg.swa_window else ctx
        return cfg.n_layers * 2 * 2 * w * cfg.n_heads * cfg.hd
    if cfg.family == "hybrid":
        N, P, H = cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_heads
        mamba = 2 * H * 2 * N * P
        n_attn = cfg.n_layers // cfg.attn_every
        attn = n_attn * 2 * 2 * ctx * cfg.n_heads * cfg.hd / cfg.n_layers
        return cfg.n_layers * (mamba + attn)
    if cfg.family == "ssm":
        P, H = cfg.ssm_head_dim, cfg.rwkv_heads
        return cfg.n_layers * 5 * H * P * P
    return 0.0


def _cache_bytes(cfg: ModelConfig, batch: int, ctx: int) -> float:
    if cfg.family in ("dense", "moe"):
        size = min(ctx, cfg.swa_window) if cfg.swa_window else ctx
        return batch * size * cfg.n_kv_heads * cfg.hd * 2 * WB * cfg.n_layers
    if cfg.family == "vlm":
        g = cfg.n_layers // cfg.cross_attn_every
        return batch * ctx * cfg.n_kv_heads * cfg.hd * 2 * WB * (cfg.n_layers - g)
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        kv = batch * ctx * cfg.n_kv_heads * cfg.hd * 2 * WB * n_attn
        ssm = batch * cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * F32B * cfg.n_layers
        return kv + ssm
    if cfg.family == "ssm":
        return batch * cfg.rwkv_heads * cfg.ssm_head_dim ** 2 * F32B * cfg.n_layers
    return 0.0


def estimate(cfg: ModelConfig, shape_name: str, chips: int, dp: int, tp: int,
             *, eight_bit_opt: bool = False) -> PerfEstimate:
    s = SH.SHAPES[shape_name]
    n_total, n_active = cfg.param_count()
    b, t = s.global_batch, s.seq_len
    tokens = b * t if s.kind != "decode" else b
    tokens_loc = tokens / dp
    p_loc = n_total / chips  # fully sharded (TP x FSDP)
    d = cfg.d_model

    items = {}
    if s.kind == "train":
        fwd = 2 * n_active / chips * tokens + tokens_loc * \
            _mixer_flops_per_token(cfg, t) / tp
        flops = 4.0 * fwd  # bwd 2x + remat refwd 1x
        items["fwd_flops"] = fwd
        # params: read fwd + read remat + read bwd + write; moments r/w
        opt_b = 1 if eight_bit_opt else F32B
        params_traffic = p_loc * WB * 4 + p_loc * F32B  # + f32 grad write
        moments = 2 * 2 * p_loc * opt_b
        resid = cfg.n_layers * (b / dp) * t * d * WB * 3  # save+read+rewrite
        logits = (b / dp) * t * (cfg.vocab / tp) * F32B * 2
        mixer = 4 * (b / dp) * t * d * WB * cfg.n_layers  # qkv/ffn act traffic
        bytes_hbm = params_traffic + moments + resid + logits + mixer
        items.update(params_traffic=params_traffic, moments=moments,
                     residuals=resid, logits=logits, mixer_act=mixer)
    elif s.kind == "prefill":
        fwd = 2 * n_active / chips * tokens + tokens_loc * \
            _mixer_flops_per_token(cfg, t) / tp
        flops = fwd
        cache_w = _cache_bytes(cfg, b, t) / chips
        resid = cfg.n_layers * (b / dp) * t * d * WB * 2
        bytes_hbm = p_loc * WB + cache_w + resid
        items.update(fwd_flops=fwd, params_read=p_loc * WB, cache_write=cache_w,
                     residuals=resid)
    else:  # decode
        fwd = 2 * n_active / chips * b + (b / dp) * _decode_mixer_flops(cfg, t) / tp
        flops = fwd
        cache_r = _cache_bytes(cfg, b, t) / chips
        bytes_hbm = p_loc * WB + cache_r
        items.update(fwd_flops=fwd, params_read=p_loc * WB, cache_read=cache_r)
    items["params_local_bytes"] = p_loc * WB
    return PerfEstimate(flops=flops, bytes_hbm=bytes_hbm, items=items)
