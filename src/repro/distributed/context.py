"""Logical-axis sharding context.

Model code annotates activations with *logical* axis names
(``constrain(x, ("batch", None, "embed"))``); the active `ShardingRules`
maps logical names to physical mesh axes. With no mesh set, annotations are
no-ops — the same model code runs in single-device smoke tests and in the
512-chip dry-run unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> physical mesh axis (or tuple of axes, or None)."""

    mesh: object
    rules: dict

    def spec(self, logical) -> P:
        phys = []
        for name in logical:
            if name is None:
                phys.append(None)
            else:
                phys.append(self.rules.get(name))
        return P(*phys)

    def sharding(self, logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))


def set_rules(rules: Optional[ShardingRules]) -> None:
    _state.rules = rules


def get_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = get_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


def constrain(x, logical):
    """with_sharding_constraint by logical axes; no-op without active rules."""
    r = get_rules()
    if r is None:
        return x
    return jax.lax.with_sharding_constraint(x, r.sharding(logical))
