"""Parameter/activation sharding rules per architecture family.

Physical mesh axes: ("pod", "data", "model") multi-pod or ("data", "model")
single-pod (launch/mesh.py). Policy:

  * TP on "model" for: q-head projections, d_ff, expert d_ff, vocab — only
    when the dim is divisible by the model-axis size (checked per param; the
    fallback is FSDP-only for that param).
  * FSDP (ZeRO-3 flavored) on "data" (+"pod") for the largest remaining dim
    of every large param — XLA inserts per-layer all-gathers; with
    scan-over-layers these batch across the stack.
  * Activations: batch on ("pod","data"); long-context decode shards the KV
    cache sequence dim on "data" when batch < data-axis size.

The rules are *logical name -> physical axes* maps consumed by
distributed.context.ShardingRules plus a param-pytree annotator keyed on
path names. Divisibility is decided at annotation time so awkward head
counts (arctic 56H on 16-way model axis) degrade gracefully instead of
padding silently.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.context import ShardingRules
from repro.models.config import ModelConfig


def data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def logical_rules(mesh: Mesh, *, seq_axis: Optional[str] = None) -> ShardingRules:
    """seq_axis="model" => Megatron-style sequence-parallel activations (the
    residual stream stays seq-sharded on the model axis between blocks, so
    row-parallel outputs reduce-scatter instead of all-reduce)."""
    d_ax = data_axes(mesh)
    batch = d_ax if len(d_ax) > 1 else (d_ax[0] if d_ax else None)
    return ShardingRules(
        mesh=mesh,
        rules={
            "batch": batch,
            "vocab": model_axis(mesh),
            "ff": model_axis(mesh),
            "heads": model_axis(mesh),
            "seq": (model_axis(mesh) if seq_axis == "model" else None),
        },
    )


# -- parameter annotation -----------------------------------------------------

_TP_RULES = [
    # (path regex, dim index (negative ok), logical group)
    (r".*attn/w[qkv]$", -1, "tp_out"),     # [*, d, H*hd] shard H*hd
    (r".*attn/wo$", -2, "tp_in"),          # [*, H*hd, d] shard H*hd (input dim)
    (r".*(mlp|dense)/w_(gate|up)$", -1, "tp_out"),
    (r".*(mlp|dense)/w_down$", -2, "tp_in"),
    (r".*moe/w_(gate|up)$", -1, "tp_out"),  # [L, E, d, ff]
    (r".*moe/w_down$", -2, "tp_in"),        # [L, E, ff, d]
    (r".*embed$", 0, "vocab"),
    (r".*head$", -1, "vocab"),
    (r".*rwkv/(ck)$", -1, "tp_out"),
    (r".*rwkv/(cv)$", -2, "tp_in"),
    (r".*rwkv/w[rkvg]$|.*rwkv/wo$", -1, "tp_out_sq"),
    (r".*mamba/w_in$", -1, "tp_out"),
    (r".*mamba/w_out$", -2, "tp_in"),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_sharding(
    params,
    mesh: Mesh,
    cfg: ModelConfig,
    *,
    fsdp: bool = True,
    min_fsdp_size: int = 2**16,
    wide_tp: bool = False,
    tp_enabled: bool = True,
):
    """Returns a pytree of NamedSharding matching ``params``.

    TP where divisible; optional FSDP on the largest remaining dim (prefers
    dims already unsharded). kv-head projections smaller than the model axis
    stay replicated across "model" (GQA kv<TP: MQA/GQA-friendly).

    ``wide_tp`` (beyond-paper, serving): TP dims shard over ALL mesh axes
    (data+model combined) when divisible — params are read from local HBM
    with zero per-token gathers; used by the decode perf variants.
    ``tp_enabled=False``: pure-DP/FSDP layout (no model-axis param sharding).
    """
    m_ax = model_axis(mesh)
    m_size = mesh.shape[m_ax] if m_ax else 1
    d_ax = data_axes(mesh)
    d_size = int(np.prod([mesh.shape[a] for a in d_ax])) if d_ax else 1
    all_ax = tuple(d_ax) + ((m_ax,) if m_ax else ())
    all_size = d_size * m_size

    def one(path, x):
        pstr = _path_str(path)
        ndim = x.ndim
        spec = [None] * ndim
        if tp_enabled and m_ax and m_size > 1:
            for pat, dim, _group in _TP_RULES:
                if re.match(pat, pstr):
                    di = dim % ndim
                    # wide TP only where no head-reshape follows the matmul
                    # (attention projections reshape H*hd -> [H, hd]; a
                    # 256-way shard of that dim would force regathers).
                    wide_ok = wide_tp and "attn/" not in pstr
                    if wide_ok and x.shape[di] % all_size == 0:
                        spec[di] = all_ax
                    elif x.shape[di] % m_size == 0:
                        spec[di] = m_ax
                    break
        if fsdp and d_ax and d_size > 1 and x.size >= min_fsdp_size:
            # largest unsharded dim divisible by the data extent
            used = {a for s in spec if s for a in (s if isinstance(s, tuple) else (s,))}
            if not (used & set(d_ax)):
                cand = sorted(
                    (i for i in range(ndim) if spec[i] is None),
                    key=lambda i: -x.shape[i],
                )
                for i in cand:
                    if x.shape[i] % d_size == 0:
                        spec[i] = d_ax if len(d_ax) > 1 else d_ax[0]
                        break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, params)


def batch_sharding(mesh: Mesh, ndim: int, *, batch_dim: int = 0):
    d_ax = data_axes(mesh)
    spec = [None] * ndim
    if d_ax:
        spec[batch_dim] = d_ax if len(d_ax) > 1 else d_ax[0]
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
