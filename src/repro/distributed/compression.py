"""Gradient compression: int8 block-quantized all-reduce with error feedback.

Distributed-optimization trick for WAN-/pod-boundary-constrained meshes (the
paper's own setting is WAN transport): gradients are quantized to int8 with
per-block fp32 scales before the data-parallel all-reduce, cutting the
collective term ~4x for the pod axis at the cost of quantization noise; an
error-feedback accumulator keeps the bias bounded (residual carried to the
next step). Used optionally by train/train_step.py (config.grad_compress).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
    return flat.reshape(-1, BLOCK), n


def quantize_int8(x):
    """x: any-shape float -> (q int8 [Nb, BLOCK], scale f32 [Nb, 1], n)."""
    blocks, n = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize_int8(q, scale, n, shape):
    x = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return x.reshape(shape)


def compress_decompress(x):
    """Round-trip (for error analysis and as the psum payload transform)."""
    q, s, n = quantize_int8(x)
    return dequantize_int8(q, s, n, x.shape)


def psum_compressed(x, axis_name):
    """all-reduce with int8 payload + error feedback residual.

    Returns (mean_reduced, residual). Caller adds ``residual`` to the next
    step's gradient before compressing (error feedback). Inside shard_map.
    """
    q, s, n = quantize_int8(x)
    deq = dequantize_int8(q, s, n, x.shape)
    residual = x.astype(jnp.float32) - deq
    summed = jax.lax.psum(deq, axis_name)
    return summed, residual
