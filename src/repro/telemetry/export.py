"""Exposition transports for the metrics registry.

Two ways out of process, matching the two ways the repro runs:

- ``start_http_server(registry)`` — a daemon-thread HTTP server serving
  Prometheus text on ``/metrics`` for long-running services
  (``run_controld --serve --metrics-port N``). Stdlib only.
- ``TimeSeriesWriter`` — an append-only JSONL emitter for finite runs
  (``run_simnet.py --metrics-interval K``): one flat
  ``registry.sample()`` row per emission, stamped with whatever the
  caller knows (virtual time, window index, wall clock).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from .registry import MetricsRegistry

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_INDEX = b"""<html><head><title>repro telemetry</title></head>
<body><h1>repro telemetry</h1><p><a href="/metrics">/metrics</a></p></body></html>
"""


def start_http_server(registry: MetricsRegistry, host: str = "127.0.0.1", port: int = 0):
    """Serve ``registry.render()`` on ``/metrics`` in a daemon thread.

    Returns ``(server, bound_port)``; pass ``port=0`` to let the OS pick
    (tests and --metrics-port 0 rely on this). Call ``server.shutdown()``
    to stop, or just let the daemon thread die with the process.
    """

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 - stdlib API name
            path = self.path.split("?", 1)[0]
            if path == "/metrics":
                body = registry.render().encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
            elif path == "/":
                body = _INDEX
                self.send_response(200)
                self.send_header("Content-Type", "text/html; charset=utf-8")
            else:
                body = b"not found\n"
                self.send_response(404)
                self.send_header("Content-Type", "text/plain")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, fmt, *args):
            pass  # scrapes must not spam the service's stdout

    server = ThreadingHTTPServer((host, port), _Handler)
    server.daemon_threads = True
    thread = threading.Thread(target=server.serve_forever, name="metrics-http", daemon=True)
    thread.start()
    return server, server.server_address[1]


class TimeSeriesWriter:
    """Append ``registry.sample()`` rows to a JSONL file.

    Each ``write(**stamp)`` emits one line ``{**stamp, "metrics": {...}}``
    and flushes, so a killed run keeps every window it completed.
    """

    def __init__(self, path: str, registry: MetricsRegistry):
        self.path = path
        self.registry = registry
        self._fh = open(path, "a", encoding="utf-8")

    def write(self, **stamp) -> None:
        row = dict(stamp)
        row["metrics"] = self.registry.sample()
        self._fh.write(json.dumps(row) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.close()
        return None
