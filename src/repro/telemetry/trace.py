"""Per-bundle distributed tracing: a vectorized flight recorder.

Every stage the plant already computes as arrays — DAQ emission wait,
uplink serialization, WAN prop+jitter, the LB's fixed-latency hop, the
fabric inter-LB hop, downlink FIFO wait, Lindley farm-queue wait, service
time, reassembly completion — lands here as struct-of-arrays span buffers:
one ``record_window(stage, bundle_ids, t_start, t_end)`` call per stage per
window, never per-packet Python. Engines differ only in *when* they call
it: the host simulator records inline as each window's arrays materialize;
the fused engine returns the masked stage-time arrays from the donated
device program and materializes the identical span set post-hoc
(tests/test_trace.py asserts set equality on ``baseline``/``straggler``).

Sampling policy (both engines, bit-identical):

* **Head sampling** — deterministic ``mix64`` over the *event number*
  (``fabric.spray``'s splitmix64 finalizer), salted with the trace seed and
  compared against ``head_rate * 2^64``. A bundle's fate is a pure function
  of (event, seed): no RNG state, no ordering dependence, identical across
  engines and runs.
* **Tail-biased sampling** — a top-k reservoir over completed-bundle E2E
  latency always retains the K slowest bundles of the run (ties broken by
  bundle key, so retention is insertion-order independent). The p99.9
  waterfall is therefore always available even at ``head_rate=0``.

Span identity: ``key`` packs the bundle id ``(event << 16) | daq``; ``pid``
identifies one physical packet copy (a monotone delivered-row counter both
engines derive identically), with bundle-level spans (emission wait,
reassembly) using ``BUNDLE_PID + key``. ``aux`` carries a stage-specific
attribute (fabric: the stacked-calendar ``instance_id = lb*2 + class``;
farm stages: the member id).

Export is Chrome trace-event JSON (``to_perfetto()``; open the file in
ui.perfetto.dev) with one "process" per bundle and one "thread" per packet
copy; ``to_perfetto_json()`` is canonical bytes, golden-tested. Completion
latencies are recorded for *every* bundle (retention only filters spans),
so percentile selection in ``traceview`` is exact, not sample-biased.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

# splitmix64 finalizer (the same hash fabric.spray uses), defined locally:
# telemetry sits below both simnet and fabric in the import graph, so it
# must not import from either (fabric.sim imports this module).
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64 (wrapping arithmetic)."""
    z = np.asarray(x, np.uint64)
    with np.errstate(over="ignore"):
        z = z + _GOLDEN
        z = (z ^ (z >> np.uint64(30))) * _MIX1
        z = (z ^ (z >> np.uint64(27))) * _MIX2
        return z ^ (z >> np.uint64(31))


#: core pipeline stages, in pipeline order. Index = stage id. Extra stages
#: (e.g. per-message controld spans) are registered on first use.
STAGES: Tuple[str, ...] = (
    "emit_wait", "uplink", "wan", "lb", "fabric", "downlink",
    "farm_wait", "service", "reassembly",
)

#: pid namespace for bundle-level spans (emission wait, reassembly): the
#: packet-copy counter never reaches 2^63, so ``BUNDLE_PID + key`` cannot
#: collide with a row pid.
BUNDLE_PID = np.uint64(1) << np.uint64(63)

_SEED_SALT = np.uint64(0xA24BAED4963EE407)


def trace_id(key: int) -> str:
    """The wire/display form of a bundle key — 16 hex digits."""
    return f"{int(key):016x}"


def parse_trace_id(s: str) -> int:
    return int(s, 16)


def bundle_key(event_number, daq_id) -> np.ndarray:
    """Pack (event, daq) into the u64 bundle key, vectorized."""
    ev = np.asarray(event_number, np.uint64)
    dq = np.asarray(daq_id, np.uint64)
    return (ev << np.uint64(16)) | dq


@dataclasses.dataclass
class TraceConfig:
    """Sampling knobs. ``head_rate=1.0`` keeps every bundle's spans."""

    head_rate: float = 1.0
    tail_k: int = 64
    seed: int = 0
    compact_every: int = 256   # windows between span-buffer compactions


class TraceBuffer:
    """SoA span buffers + completion table + sampling/retention."""

    def __init__(self, cfg: Optional[TraceConfig] = None):
        self.cfg = cfg or TraceConfig()
        self.stage_names: List[str] = list(STAGES)
        self._stage_ids: Dict[str, int] = {s: i for i, s in enumerate(STAGES)}
        # span chunks: parallel lists of (stage u16, key u64, pid u64,
        # t0 f64, t1 f64, aux i64) arrays — appended per record_window call
        self._chunks: List[Tuple[np.ndarray, ...]] = []
        # completion chunks: (key u64, t_emit f64, t_done f64)
        self._done: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self.windows = 0
        self.n_recorded = 0          # spans ever recorded (pre-compaction)
        self._salt = mix64(np.uint64(self.cfg.seed) ^ _SEED_SALT)
        rate = min(max(float(self.cfg.head_rate), 0.0), 1.0)
        # head threshold in u64 hash space; rate=1.0 keeps everything
        self._thresh = (np.uint64(0xFFFFFFFFFFFFFFFF) if rate >= 1.0
                        else np.uint64(int(rate * float(2**64))))
        self._keep_all = rate >= 1.0

    # -- sampling ----------------------------------------------------------
    def stage_id(self, name: str) -> int:
        sid = self._stage_ids.get(name)
        if sid is None:
            sid = len(self.stage_names)
            self.stage_names.append(name)
            self._stage_ids[name] = sid
        return sid

    def head_sampled(self, keys: np.ndarray) -> np.ndarray:
        """Deterministic head-sampling mask: mix64 over the event number."""
        if self._keep_all:
            return np.ones(np.shape(keys), bool)
        ev = np.asarray(keys, np.uint64) >> np.uint64(16)
        with np.errstate(over="ignore"):
            h = mix64(ev ^ self._salt)
        return h <= self._thresh

    # -- recording (one call per stage per window) -------------------------
    def record_window(self, stage, keys, t_start, t_end,
                      pid=None, aux=None) -> None:
        keys = np.atleast_1d(np.asarray(keys, np.uint64))
        n = len(keys)
        if n == 0:
            return
        sid = stage if isinstance(stage, int) else self.stage_id(stage)
        t0 = np.broadcast_to(np.asarray(t_start, np.float64), (n,))
        t1 = np.broadcast_to(np.asarray(t_end, np.float64), (n,))
        if pid is None:
            p = (keys + BUNDLE_PID).astype(np.uint64)
        else:
            p = np.broadcast_to(np.asarray(pid, np.uint64), (n,))
        a = (np.full((n,), -1, np.int64) if aux is None
             else np.broadcast_to(np.asarray(aux, np.int64), (n,)))
        self._chunks.append((np.full((n,), sid, np.uint16), keys.copy(),
                             p.copy(), t0.copy(), t1.copy(), a.copy()))
        self.n_recorded += n

    def complete_window(self, keys, t_emit, t_done) -> None:
        """Register completed bundles (every one, retained or not)."""
        keys = np.atleast_1d(np.asarray(keys, np.uint64))
        if len(keys) == 0:
            return
        self._done.append((keys.copy(),
                           np.asarray(t_emit, np.float64).copy(),
                           np.asarray(t_done, np.float64).copy()))

    def end_window(self) -> None:
        self.windows += 1
        ce = self.cfg.compact_every
        if ce and self.windows % ce == 0:
            self._compact()

    # -- retention ---------------------------------------------------------
    def completions(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(key, t_emit, t_done) over every completed bundle, append order."""
        if not self._done:
            z = np.zeros((0,), np.uint64)
            return z, np.zeros((0,)), np.zeros((0,))
        ks = np.concatenate([c[0] for c in self._done])
        te = np.concatenate([c[1] for c in self._done])
        td = np.concatenate([c[2] for c in self._done])
        return ks, te, td

    def tail_keys(self) -> np.ndarray:
        """The K slowest completed bundles — deterministic under ties
        (sorted by (e2e, key) descending), independent of append order."""
        ks, te, td = self.completions()
        if len(ks) == 0 or self.cfg.tail_k <= 0:
            return np.zeros((0,), np.uint64)
        e2e = td - te
        order = np.lexsort((ks, e2e))[::-1]     # e2e desc, key desc on ties
        return ks[order[:self.cfg.tail_k]]

    def retained_keys(self) -> np.ndarray:
        """head-sampled ∪ tail top-k, over every key ever seen."""
        seen = [c[1] for c in self._chunks]
        if self._done:
            seen.append(np.concatenate([c[0] for c in self._done]))
        if not seen:
            return np.zeros((0,), np.uint64)
        keys = np.unique(np.concatenate(seen))
        keep = self.head_sampled(keys)
        tail = self.tail_keys()
        if len(tail):
            keep |= np.isin(keys, tail)
        return keys[keep]

    def _compact(self) -> None:
        """Drop spans of completed-and-unretained bundles. Safe: the head
        set is fixed, an evicted reservoir bundle never re-enters, and
        incomplete bundles are kept until they complete or the run ends."""
        if not self._chunks:
            return
        done_k, te, td = self.completions()
        if len(done_k) == 0:
            return
        e2e = td - te
        order = np.lexsort((done_k, e2e))[::-1]
        tail = done_k[order[:self.cfg.tail_k]] if self.cfg.tail_k > 0 \
            else np.zeros((0,), np.uint64)
        st, ky, pi, t0, t1, ax = [np.concatenate([c[i] for c in self._chunks])
                                  for i in range(6)]
        drop = np.isin(ky, done_k) & ~self.head_sampled(ky)
        if len(tail):
            drop &= ~np.isin(ky, tail)
        keep = ~drop
        self._chunks = [(st[keep], ky[keep], pi[keep], t0[keep], t1[keep],
                         ax[keep])]

    # -- materialized output ----------------------------------------------
    def spans(self) -> Dict[str, np.ndarray]:
        """Retained spans in canonical order (key, pid, t0, stage) — the
        parity-comparable form: engines may record in different orders but
        land on the same sorted set."""
        if not self._chunks:
            z = np.zeros((0,), np.uint64)
            return dict(stage=np.zeros((0,), np.uint16), key=z, pid=z,
                        t0=np.zeros((0,)), t1=np.zeros((0,)),
                        aux=np.zeros((0,), np.int64))
        st, ky, pi, t0, t1, ax = [np.concatenate([c[i] for c in self._chunks])
                                  for i in range(6)]
        keep = np.isin(ky, self.retained_keys())
        st, ky, pi, t0, t1, ax = (st[keep], ky[keep], pi[keep], t0[keep],
                                  t1[keep], ax[keep])
        order = np.lexsort((st, t0, pi, ky))
        return dict(stage=st[order], key=ky[order], pid=pi[order],
                    t0=t0[order], t1=t1[order], aux=ax[order])

    def retained_completions(self) -> Tuple[np.ndarray, np.ndarray]:
        """(key, e2e) of retained completed bundles, sorted by key."""
        ks, te, td = self.completions()
        if len(ks) == 0:
            return ks, np.zeros((0,))
        keep = np.isin(ks, self.retained_keys())
        ks, e2e = ks[keep], (td - te)[keep]
        order = np.argsort(ks, kind="stable")
        return ks[order], e2e[order]

    # -- exemplars: LATENCY_BUCKETS_S bucket -> a sampled trace id ---------
    def exemplars(self, buckets) -> Dict[int, Tuple[str, float]]:
        """Per histogram bucket (index into ``buckets``), the retained
        completed bundle with the largest E2E falling in that bucket —
        ``{bucket_idx: (trace_id, e2e_seconds)}``. Deterministic (max e2e,
        ties by key)."""
        ks, e2e = self.retained_completions()
        out: Dict[int, Tuple[str, float]] = {}
        if len(ks) == 0:
            return out
        b = np.searchsorted(np.asarray(buckets, np.float64), e2e,
                            side="left")
        order = np.lexsort((ks, e2e))       # ascending: last-in wins = max
        for i in order:
            out[int(b[i])] = (trace_id(ks[i]), float(e2e[i]))
        return out

    # -- Chrome trace-event / Perfetto export ------------------------------
    def to_perfetto(self) -> dict:
        """Chrome trace-event JSON (dict form): one complete-event ("X")
        per span, pid = bundle key, tid = packet copy (0 for bundle-level
        spans), timestamps in microseconds of virtual time."""
        sp = self.spans()
        events = []
        for i in range(len(sp["key"])):
            key = int(sp["key"][i])
            pid_raw = int(sp["pid"][i])
            tid = 0 if pid_raw >= int(BUNDLE_PID) else pid_raw + 1
            ev = dict(
                name=self.stage_names[int(sp["stage"][i])],
                cat="bundle", ph="X",
                ts=round(float(sp["t0"][i]) * 1e6, 3),
                dur=round(float(sp["t1"][i] - sp["t0"][i]) * 1e6, 3),
                pid=key, tid=tid,
                args=dict(trace_id=trace_id(key),
                          event=key >> 16, daq=key & 0xFFFF),
            )
            if int(sp["aux"][i]) >= 0:
                ev["args"]["aux"] = int(sp["aux"][i])
            events.append(ev)
        return dict(displayTimeUnit="ns", traceEvents=events)

    def to_perfetto_json(self) -> bytes:
        """Canonical bytes of ``to_perfetto()`` — golden-tested: keys
        sorted, compact separators, deterministic span order."""
        return json.dumps(self.to_perfetto(), sort_keys=True,
                          separators=(",", ":")).encode()

    # -- persistence for analyze_trace -------------------------------------
    def to_summary(self) -> dict:
        """Raw retained spans + all completions, JSON-serializable — the
        lossless form ``scripts/analyze_trace.py`` consumes."""
        sp = self.spans()
        ks, te, td = self.completions()
        return dict(
            stage_names=self.stage_names,
            windows=self.windows,
            n_recorded=self.n_recorded,
            spans=dict(stage=sp["stage"].tolist(),
                       key=[int(k) for k in sp["key"]],
                       pid=[int(p) for p in sp["pid"]],
                       t0=sp["t0"].tolist(), t1=sp["t1"].tolist(),
                       aux=sp["aux"].tolist()),
            completions=dict(key=[int(k) for k in ks],
                             t_emit=te.tolist(), t_done=td.tolist()),
        )

    @classmethod
    def from_summary(cls, d: dict) -> "TraceBuffer":
        tb = cls(TraceConfig(head_rate=1.0, tail_k=0, compact_every=0))
        tb.stage_names = list(d["stage_names"])
        tb._stage_ids = {s: i for i, s in enumerate(tb.stage_names)}
        tb.windows = int(d.get("windows", 0))
        tb.n_recorded = int(d.get("n_recorded", 0))
        sp = d["spans"]
        if sp["key"]:
            tb._chunks.append((
                np.asarray(sp["stage"], np.uint16),
                np.asarray(sp["key"], np.uint64),
                np.asarray(sp["pid"], np.uint64),
                np.asarray(sp["t0"], np.float64),
                np.asarray(sp["t1"], np.float64),
                np.asarray(sp["aux"], np.int64)))
        c = d["completions"]
        if c["key"]:
            tb._done.append((np.asarray(c["key"], np.uint64),
                             np.asarray(c["t_emit"], np.float64),
                             np.asarray(c["t_done"], np.float64)))
        return tb
