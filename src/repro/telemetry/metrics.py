"""Per-member telemetry: what the control plane consumes.

Mirrors the real EJ-FAT deployment where CN daemons report receive-queue fill
and processing rate back to the control plane. Here members are DP workers
(or serving replicas); fill is estimated from queue depth / step-time EWMAs
plus the reassembly incomplete-buffer backlog reported by the ingest lanes
(``report_ingest`` — see DESIGN.md §Ingest for the feedback wiring).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict

from repro.core.control_plane import MemberTelemetry


@dataclasses.dataclass
class _MemberStats:
    ewma_step_time: float = 0.0
    backlog: int = 0
    processed: int = 0
    healthy: bool = True
    last_seen: float = 0.0
    # ingest-side accounting (reassembly daemons, DESIGN.md §Ingest)
    ingest_pending: int = 0      # incomplete reassembly buffers (groups)
    ingest_completed: int = 0
    ingest_timed_out: int = 0


class TelemetryHub:
    """Collects member reports; emits control-plane telemetry snapshots."""

    def __init__(self, alpha: float = 0.2, queue_capacity: int = 64):
        self.alpha = alpha
        self.queue_capacity = queue_capacity
        self.members: dict[int, _MemberStats] = defaultdict(_MemberStats)

    def report_step(self, member_id: int, step_time: float, backlog: int = 0,
                    processed: int = 1) -> None:
        s = self.members[member_id]
        s.ewma_step_time = (step_time if s.ewma_step_time == 0
                            else (1 - self.alpha) * s.ewma_step_time
                            + self.alpha * step_time)
        s.backlog = backlog
        s.processed += processed
        s.last_seen = time.time()

    def report_queue(self, member_id: int, backlog: int) -> None:
        """Queue-depth-only report (no step ran this tick — e.g. an idle
        decode replica). Without it a member's last busy-tick backlog would
        stick forever and keep its fill high after it drained."""
        s = self.members[member_id]
        s.backlog = backlog
        s.last_seen = time.time()

    def report_ingest(self, member_id: int, pending: int,
                      completed: int = 0, timed_out: int = 0) -> None:
        """Reassembly-lane report: ``pending`` incomplete (event, daq)
        buffers right now (the real receive-queue backlog the paper's CN
        daemons feed back), plus completion/timeout counters. The pending
        backlog folds into the member's queue-fill estimate in snapshot()."""
        s = self.members[member_id]
        s.ingest_pending = pending
        s.ingest_completed += completed
        s.ingest_timed_out += timed_out
        s.last_seen = time.time()

    def report_failure(self, member_id: int) -> None:
        self.members[member_id].healthy = False

    def report_recovered(self, member_id: int) -> None:
        self.members[member_id].healthy = True

    def snapshot(self) -> dict[int, MemberTelemetry]:
        out = {}
        times = [s.ewma_step_time for s in self.members.values()
                 if s.healthy and s.ewma_step_time > 0]
        t_ref = min(times) if times else 1.0
        for mid, s in self.members.items():
            # fill: combination of backlog fraction and relative slowness —
            # a member 2x slower than the fastest behaves like a 2x-full queue.
            # The backlog is whichever queue is deeper: the decode/work queue
            # or the reassembly incomplete-buffer backlog (ingest daemons).
            backlog = max(s.backlog, s.ingest_pending)
            rel = s.ewma_step_time / t_ref if t_ref > 0 else 1.0
            fill = min(1.0, 0.5 * (backlog / max(self.queue_capacity, 1)) +
                       0.5 * (1 - 1 / max(rel, 1e-6)) * 2)
            rate = 1.0 / s.ewma_step_time if s.ewma_step_time > 0 else 1.0
            out[mid] = MemberTelemetry(fill=max(0.0, fill), rate=rate,
                                       healthy=s.healthy)
        return out
