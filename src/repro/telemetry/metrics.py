"""Per-member telemetry: what the control plane consumes.

Mirrors the real EJ-FAT deployment where CN daemons report receive-queue fill
and processing rate back to the control plane. Here members are DP workers
(or serving replicas); fill is estimated from queue depth / step-time EWMAs
plus the reassembly incomplete-buffer backlog reported by the ingest lanes
(``report_ingest`` — see DESIGN.md §Ingest for the feedback wiring).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Callable

from repro.core.control_plane import MemberTelemetry

# The production metrics surface (Prometheus registry) lives next door in
# telemetry.registry; re-export it here so `telemetry.metrics` is the single
# import point for both the per-member hub and the service-level registry.
from repro.telemetry.registry import (  # noqa: F401  (re-exports)
    LATENCY_BUCKETS_S,
    SIZE_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    log_buckets,
)


@dataclasses.dataclass
class _MemberStats:
    ewma_step_time: float = 0.0
    backlog: int = 0
    processed: int = 0
    healthy: bool = True
    last_seen: float = 0.0
    # ingest-side accounting (reassembly daemons, DESIGN.md §Ingest)
    ingest_pending: int = 0      # incomplete reassembly buffers (groups)
    ingest_completed: int = 0
    ingest_timed_out: int = 0


class TelemetryHub:
    """Collects member reports; emits control-plane telemetry snapshots.

    ``clock`` is injectable (default wall time) so simulated deployments
    (``repro.simnet``) can run the hub on virtual time. When ``stale_after``
    is set, a member whose last report is older than that many clock ticks is
    reported unhealthy in ``snapshot()`` — the paper's liveness rule: a CN
    daemon that stops feeding back is presumed down and drains hit-lessly.

    ``fill_mode`` selects what ``snapshot()`` calls fill:

    * ``"blend"`` (default) — the legacy estimate for deployments whose
      backlog numbers are coarse (DP workers): half queue fraction, half
      relative slowness vs the fastest member. The slowness term saturates
      fast — any member ~1.4x slower than the fastest reads over-target even
      with an empty queue — which is the right bias when backlog is unreliable
      but *starves* a heterogeneous farm whose queues are actually fine.
    * ``"occupancy"`` — fill IS the measured receive-queue occupancy
      (backlog / queue_capacity), what the real EJ-FAT CN daemons report.
      Service-rate differences only matter through the queues they actually
      build, so a 2x-slow member with an empty queue keeps its share.
      ``repro.simnet`` runs in this mode.
    """

    def __init__(self, alpha: float = 0.2, queue_capacity: int = 64,
                 clock: Callable[[], float] = time.time,
                 stale_after: float | None = None,
                 fill_mode: str = "blend"):
        if fill_mode not in ("blend", "occupancy"):
            raise ValueError(f"unknown fill_mode {fill_mode!r}")
        self.alpha = alpha
        self.queue_capacity = queue_capacity
        self.clock = clock
        self.stale_after = stale_after
        self.fill_mode = fill_mode
        self.members: dict[int, _MemberStats] = defaultdict(_MemberStats)

    def report_step(self, member_id: int, step_time: float, backlog: int = 0,
                    processed: int = 1) -> None:
        s = self.members[member_id]
        s.ewma_step_time = (step_time if s.ewma_step_time == 0
                            else (1 - self.alpha) * s.ewma_step_time
                            + self.alpha * step_time)
        s.backlog = backlog
        s.processed += processed
        s.last_seen = self.clock()

    def report_queue(self, member_id: int, backlog: int) -> None:
        """Queue-depth-only report (no step ran this tick — e.g. an idle
        decode replica). Without it a member's last busy-tick backlog would
        stick forever and keep its fill high after it drained."""
        s = self.members[member_id]
        s.backlog = backlog
        s.last_seen = self.clock()

    def report_ingest(self, member_id: int, pending: int,
                      completed: int = 0, timed_out: int = 0) -> None:
        """Reassembly-lane report: ``pending`` incomplete (event, daq)
        buffers right now (the real receive-queue backlog the paper's CN
        daemons feed back), plus completion/timeout counters. The pending
        backlog folds into the member's queue-fill estimate in snapshot()."""
        s = self.members[member_id]
        s.ingest_pending = pending
        s.ingest_completed += completed
        s.ingest_timed_out += timed_out
        s.last_seen = self.clock()

    def is_stale(self, member_id: int) -> bool:
        """True when the member's last report is older than ``stale_after``."""
        if self.stale_after is None:
            return False
        s = self.members.get(member_id)
        if s is None:
            return True
        return (self.clock() - s.last_seen) > self.stale_after

    def report_failure(self, member_id: int) -> None:
        self.members[member_id].healthy = False

    def report_recovered(self, member_id: int) -> None:
        self.members[member_id].healthy = True

    def snapshot(self) -> dict[int, MemberTelemetry]:
        out = {}
        # stale members must not anchor t_ref: a dead-but-fast node would
        # inflate every live member's relative slowness indefinitely
        times = [s.ewma_step_time for mid, s in self.members.items()
                 if s.healthy and s.ewma_step_time > 0
                 and not self.is_stale(mid)]
        t_ref = min(times) if times else 1.0
        for mid, s in self.members.items():
            if self.is_stale(mid):
                out[mid] = MemberTelemetry(fill=1.0, rate=0.0, healthy=False)
                continue
            # The backlog is whichever queue is deeper: the decode/work queue
            # or the reassembly incomplete-buffer backlog (ingest daemons).
            backlog = max(s.backlog, s.ingest_pending)
            if self.fill_mode == "occupancy":
                fill = min(1.0, backlog / max(self.queue_capacity, 1))
            else:
                # blend: half backlog fraction, half relative slowness — a
                # member 2x slower than the fastest behaves like a 2x-full
                # queue even when its (coarse) backlog number reads low.
                rel = s.ewma_step_time / t_ref if t_ref > 0 else 1.0
                fill = min(1.0, 0.5 * (backlog / max(self.queue_capacity, 1)) +
                           0.5 * (1 - 1 / max(rel, 1e-6)) * 2)
            rate = 1.0 / s.ewma_step_time if s.ewma_step_time > 0 else 1.0
            out[mid] = MemberTelemetry(fill=max(0.0, fill), rate=rate,
                                       healthy=s.healthy)
        return out
