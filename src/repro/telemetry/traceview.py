"""Critical-path analysis over ``trace.TraceBuffer`` span sets.

A bundle's *critical path* is the chain of the packet copy whose service
completion defined the bundle's completion time (the first-served copy of
the last-finishing segment): uplink -> WAN -> LB [-> fabric] -> downlink ->
farm wait -> service. By construction the chain partitions
``[t_emit, t_done]`` exactly, so the stage sums reconcile with the
measured E2E latency to machine precision — ``reconcile()`` is the gate
``scripts/analyze_trace.py`` enforces (<1%).

Percentile selection uses the *complete* completion table (every bundle's
E2E is recorded; sampling only filters spans), so "the p99 bundle" is the
true p99, and the tail-biased reservoir guarantees its waterfall was
retained.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.telemetry.trace import BUNDLE_PID, TraceBuffer, trace_id

#: stages that sit on the critical path, in pipeline order
PATH_STAGES = ("uplink", "wan", "lb", "fabric", "downlink",
               "farm_wait", "service", "reassembly")


def critical_path(tb: TraceBuffer, key: int) -> Optional[List[Tuple[str, float]]]:
    """``[(stage, seconds), ...]`` along the bundle's critical chain, or
    None if the bundle's spans were not retained / it never completed."""
    ks, te, td = tb.completions()
    hit = np.flatnonzero(ks == np.uint64(key))
    if len(hit) == 0:
        return None
    t_done = float(td[hit[0]])
    sp = tb.spans()
    mine = sp["key"] == np.uint64(key)
    if not mine.any():
        return None
    st, pid, t0, t1 = (sp["stage"][mine], sp["pid"][mine],
                       sp["t0"][mine], sp["t1"][mine])
    svc_id = tb.stage_id("service")
    # critical copy: the service span ending exactly at t_done (duplicate
    # copies of the same segment can finish later; they are off-path)
    svc = np.flatnonzero((st == svc_id) & (t1 <= t_done + 1e-12))
    if len(svc) == 0:
        return None
    crit = svc[np.lexsort((pid[svc], t1[svc]))[-1]]
    chain = np.flatnonzero((pid == pid[crit]) & (pid[crit] < BUNDLE_PID))
    chain = chain[np.argsort(t0[chain], kind="stable")]
    path = [(tb.stage_names[int(st[i])], float(t1[i] - t0[i]))
            for i in chain]
    # reassembly residual: completion minus the critical service finish
    path.append(("reassembly", t_done - float(t1[crit])))
    return path


def reconcile(tb: TraceBuffer, key: int) -> Optional[Tuple[float, float, float]]:
    """(stage_sum, e2e, relative_error) for one bundle's critical path."""
    path = critical_path(tb, key)
    if path is None:
        return None
    ks, te, td = tb.completions()
    i = np.flatnonzero(ks == np.uint64(key))[0]
    e2e = float(td[i] - te[i])
    ssum = float(sum(d for _, d in path))
    rel = abs(ssum - e2e) / e2e if e2e > 0 else 0.0
    return ssum, e2e, rel


def percentile_key(tb: TraceBuffer, percentile: float) -> Optional[int]:
    """The retained completed bundle nearest the requested E2E percentile
    (preferring the slower side, so p100/p99.9 land on retained tails)."""
    ks, te, td = tb.completions()
    if len(ks) == 0:
        return None
    e2e = td - te
    pv = float(np.percentile(e2e, percentile))
    rk, re2e = tb.retained_completions()
    if len(rk) == 0:
        return None
    at_or_above = re2e >= pv
    if at_or_above.any():
        cand = np.flatnonzero(at_or_above)
        pick = cand[np.lexsort((rk[cand], re2e[cand]))[0]]  # slowest side, min
    else:
        pick = int(np.lexsort((rk, -re2e))[0])              # closest below
    return int(rk[pick])


def stage_decomposition(tb: TraceBuffer, percentile: float) -> Optional[dict]:
    """The analyzer's payload: the percentile bundle's waterfall plus the
    mean decomposition over the tail band (every retained bundle at or
    above the percentile value)."""
    key = percentile_key(tb, percentile)
    if key is None:
        return None
    rec = reconcile(tb, key)
    path = critical_path(tb, key)
    if rec is None or path is None:
        return None
    ks, te, td = tb.completions()
    e2e_all = td - te
    pv = float(np.percentile(e2e_all, percentile))
    rk, re2e = tb.retained_completions()
    band = rk[re2e >= pv]
    agg: Dict[str, List[float]] = {}
    for k in band[:256]:                      # bounded host work
        p = critical_path(tb, int(k))
        if p is None:
            continue
        for sname, dur in p:
            agg.setdefault(sname, []).append(dur)
    band_mean = {s: float(np.mean(v)) for s, v in agg.items()}
    stages = {s: d for s, d in path}
    dominant = max(stages, key=lambda s: stages[s])
    return dict(percentile=percentile, percentile_value_s=pv,
                key=int(key), trace_id=trace_id(key),
                e2e_s=rec[1], stage_sum_s=rec[0], reconcile_rel_err=rec[2],
                stages=stages, dominant=dominant,
                band_n=int(len(band)), band_mean=band_mean)


def format_table(d: dict) -> str:
    """Human-readable stage-decomposition table."""
    lines = [
        f"p{d['percentile']:g} bundle {d['trace_id']}  "
        f"e2e={d['e2e_s'] * 1e3:.3f}ms  "
        f"(percentile value {d['percentile_value_s'] * 1e3:.3f}ms, "
        f"band n={d['band_n']})",
        f"{'stage':<12} {'ms':>10} {'% of e2e':>9} {'band mean ms':>13}",
    ]
    e2e = d["e2e_s"] or 1.0
    for s in PATH_STAGES:
        if s not in d["stages"]:
            continue
        dur = d["stages"][s]
        bm = d["band_mean"].get(s)
        lines.append(
            f"{s:<12} {dur * 1e3:>10.4f} {100.0 * dur / e2e:>8.1f}% "
            f"{(bm * 1e3 if bm is not None else float('nan')):>13.4f}")
    lines.append(
        f"{'sum':<12} {d['stage_sum_s'] * 1e3:>10.4f} "
        f"{100.0 * d['stage_sum_s'] / e2e:>8.1f}% "
        f"(reconciles to {d['reconcile_rel_err'] * 100:.4f}%)")
    lines.append(f"dominant stage: {d['dominant']} "
                 f"({d['stages'][d['dominant']] * 1e3:.4f}ms, "
                 f"{100.0 * d['stages'][d['dominant']] / e2e:.1f}% of e2e)")
    return "\n".join(lines)


def summary_json(tb: TraceBuffer, percentiles=(50.0, 99.0)) -> dict:
    """Compact per-stage breakdown for the bench-trend dashboard."""
    out: dict = dict(windows=tb.windows, n_spans=int(len(tb.spans()["key"])),
                     n_completions=int(len(tb.completions()[0])),
                     percentiles={})
    for p in percentiles:
        d = stage_decomposition(tb, p)
        if d is not None:
            out["percentiles"][f"p{p:g}"] = dict(
                e2e_s=d["e2e_s"], trace_id=d["trace_id"],
                dominant=d["dominant"], stages=d["stages"])
    return out
