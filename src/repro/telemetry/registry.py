"""Metrics registry: counters, gauges, histograms -> Prometheus text format.

The live-telemetry surface the paper's LB host implies but our repro lacked:
every long-running component (``controld`` daemon, socket server, simnet /
serve loops) registers its counters and histograms here, and the registry
renders the Prometheus text-exposition format (v0.0.4) for the ``/metrics``
endpoint (``telemetry.export.start_http_server``) or a flat sample dict for
JSONL time-series emission (``telemetry.export.TimeSeriesWriter``).

Hot-path contract (bench_metrics gates this at <5% on the batched heartbeat
path): a counter ``inc`` is one attribute add, a histogram ``observe`` is one
bisect + three adds, and ``observe_many`` ingests a whole window of latencies
as a single ``np.searchsorted`` + ``bincount``. Gauges can be *callbacks*
(``set_function``) so occupancy-style metrics cost nothing until scrape time.
Updates are plain Python ops under the GIL — approximately atomic, which is
the right trade for monitoring data (a scrape racing an increment reads a
value at most one update stale, never a corrupt one).

Latency histograms share one fixed log-spaced bucket layout
(``LATENCY_BUCKETS_S``: 1 us .. 10 s, 4 buckets per decade) so series from
different subsystems are comparable and dashboards can overlay them.
"""
from __future__ import annotations

import re
from bisect import bisect_left
from typing import Callable, Iterable, Optional, Sequence

import numpy as np

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def log_buckets(lo: float, hi: float, per_decade: int = 4) -> tuple:
    """Fixed log-spaced bucket upper bounds from ``lo`` to ``hi`` inclusive."""
    if not (lo > 0 and hi > lo and per_decade > 0):
        raise ValueError("need 0 < lo < hi and per_decade > 0")
    n = int(round(np.log10(hi / lo) * per_decade))
    edges = [lo * 10.0 ** (i / per_decade) for i in range(n + 1)]
    return tuple(edges)


#: the shared latency layout: 1 us .. 10 s, 4 buckets/decade (29 edges)
LATENCY_BUCKETS_S = log_buckets(1e-6, 10.0, per_decade=4)

#: power-of-two size layout for batch/pipeline-depth histograms
SIZE_BUCKETS = tuple(float(1 << i) for i in range(15))  # 1 .. 16384


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _escape_help(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n")


def _fmt(v: float) -> str:
    f = float(v)
    if f != f:  # NaN
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return f"{f:.10g}"


def _fmt_le(e: float) -> str:
    return f"{float(e):.6g}"


def _labelstr(names: Sequence[str], values: Sequence[str],
              extra: Optional[tuple] = None) -> str:
    pairs = [f'{n}="{_escape_label(v)}"' for n, v in zip(names, values)]
    if extra is not None:
        pairs.append(f'{extra[0]}="{extra[1]}"')
    return "{" + ",".join(pairs) + "}" if pairs else ""


class _CounterChild:
    __slots__ = ("_value",)

    def __init__(self):
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    def value(self) -> float:
        return self._value


class _GaugeChild:
    __slots__ = ("_value", "_fn")

    def __init__(self):
        self._value = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self._value -= amount

    def set_function(self, fn: Callable[[], float]) -> None:
        """Collect-time callback: the gauge costs nothing until scraped."""
        self._fn = fn

    def value(self) -> float:
        if self._fn is not None:
            try:
                return float(self._fn())
            except Exception:
                return float("nan")  # a scrape must never crash the server
        return self._value


class _HistogramChild:
    __slots__ = ("buckets", "_counts", "_sum", "_count", "_exemplars")

    def __init__(self, buckets: tuple):
        self.buckets = buckets
        self._counts = [0] * (len(buckets) + 1)  # last slot = +Inf
        self._sum = 0.0
        self._count = 0
        # bucket index -> (trace_id, value): cross-reference into the
        # tracing layer (telemetry.trace); rendered as an OpenMetrics-style
        # exemplar suffix only when present, so the plain text format (and
        # its golden test) is unchanged without tracing
        self._exemplars: dict = {}

    def observe(self, v: float) -> None:
        self._counts[bisect_left(self.buckets, v)] += 1
        self._sum += v
        self._count += 1

    def observe_many(self, values) -> None:
        """One window of samples in one vectorized pass."""
        arr = np.asarray(values, np.float64)
        if arr.size == 0:
            return
        idx = np.searchsorted(self.buckets, arr, side="left")
        add = np.bincount(idx, minlength=len(self.buckets) + 1)
        for i in np.flatnonzero(add):
            self._counts[i] += int(add[i])
        self._sum += float(arr.sum())
        self._count += int(arr.size)

    def put_exemplars(self, values, trace_ids) -> None:
        """Link sampled trace ids to the buckets their values land in (the
        last value per bucket wins — freshest exemplar, one vectorized
        bucketing pass per window)."""
        arr = np.asarray(values, np.float64)
        if arr.size == 0:
            return
        idx = np.searchsorted(self.buckets, arr, side="left")
        for i, b in enumerate(idx):
            self._exemplars[int(b)] = (trace_ids[i], float(arr[i]))

    def value(self) -> tuple:
        return (tuple(self._counts), self._sum, self._count)


class _Family:
    """A named metric family; labeled children keyed by label-value tuple.

    A family declared without labels is bound straight to one child, so
    ``registry.counter("x_total").inc()`` works without a ``labels()`` hop.
    """

    kind = "untyped"
    child_cls: type = _CounterChild

    def __init__(self, name: str, help: str = "",
                 labelnames: Iterable[str] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        for ln in self.labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self._children: dict[tuple, object] = {}
        if not self.labelnames:
            self._children[()] = self._make_child()

    def _make_child(self):
        return self.child_cls()

    def labels(self, **kv):
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.labelnames)}")
        key = tuple(str(kv[n]) for n in self.labelnames)
        child = self._children.get(key)
        if child is None:
            child = self._make_child()
            self._children[key] = child
        return child

    def remove(self, **kv) -> None:
        """Drop one labeled child (e.g. a freed controld session)."""
        key = tuple(str(kv[n]) for n in self.labelnames)
        self._children.pop(key, None)

    def _bound(self):
        if self.labelnames:
            raise ValueError(
                f"{self.name} declares labels {self.labelnames}; "
                "use .labels(...)")
        return self._children[()]

    # -- unlabeled convenience pass-throughs ----------------------------------
    def inc(self, amount: float = 1.0) -> None:
        self._bound().inc(amount)

    def samples(self):
        for key in sorted(self._children):
            yield key, self._children[key]


class Counter(_Family):
    kind = "counter"
    child_cls = _CounterChild

    def value(self) -> float:
        return self._bound().value()


class Gauge(_Family):
    kind = "gauge"
    child_cls = _GaugeChild

    def set(self, v: float) -> None:
        self._bound().set(v)

    def dec(self, amount: float = 1.0) -> None:
        self._bound().dec(amount)

    def set_function(self, fn: Callable[[], float]) -> None:
        self._bound().set_function(fn)

    def value(self) -> float:
        return self._bound().value()


class Histogram(_Family):
    kind = "histogram"
    child_cls = _HistogramChild

    def __init__(self, name: str, help: str = "",
                 labelnames: Iterable[str] = (),
                 buckets: Optional[tuple] = None):
        self.buckets = tuple(sorted(buckets)) if buckets else LATENCY_BUCKETS_S
        super().__init__(name, help, labelnames)

    def _make_child(self):
        return _HistogramChild(self.buckets)

    def observe(self, v: float) -> None:
        self._bound().observe(v)

    def observe_many(self, values) -> None:
        self._bound().observe_many(values)

    def put_exemplars(self, values, trace_ids) -> None:
        self._bound().put_exemplars(values, trace_ids)


class MetricsRegistry:
    """Get-or-create registry over named metric families.

    ``counter``/``gauge``/``histogram`` are idempotent: asking again with the
    same name returns the existing family (kind and labelnames must match —
    a name collision across kinds is a bug, not a merge)."""

    def __init__(self):
        self._families: dict[str, _Family] = {}

    def _get_or_create(self, cls, name: str, help: str,
                       labelnames: Iterable[str], **kw) -> _Family:
        fam = self._families.get(name)
        if fam is not None:
            if not isinstance(fam, cls) or fam.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind} "
                    f"with labels {fam.labelnames}")
            return fam
        fam = cls(name, help, labelnames, **kw)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = (),
                  buckets: Optional[tuple] = None) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def unregister(self, name: str) -> None:
        self._families.pop(name, None)

    # -- exposition -----------------------------------------------------------
    def render(self) -> str:
        """Prometheus text-exposition format (version 0.0.4), families
        sorted by name, children by label values — deterministic, so a
        golden test can pin the exact bytes."""
        out = []
        for name in sorted(self._families):
            fam = self._families[name]
            out.append(f"# HELP {name} {_escape_help(fam.help)}")
            out.append(f"# TYPE {name} {fam.kind}")
            for key, child in fam.samples():
                ls = _labelstr(fam.labelnames, key)
                if fam.kind == "histogram":
                    counts, total, count = child.value()
                    ex = getattr(child, "_exemplars", {})
                    cum = 0
                    for bi, (edge, c) in enumerate(zip(fam.buckets, counts)):
                        cum += c
                        line = (
                            f"{name}_bucket"
                            f"{_labelstr(fam.labelnames, key, ('le', _fmt_le(edge)))}"
                            f" {cum}")
                        if bi in ex:
                            tid, val = ex[bi]
                            line += (f' # {{trace_id="{_escape_label(str(tid))}"}}'
                                     f" {_fmt(val)}")
                        out.append(line)
                    out.append(
                        f"{name}_bucket"
                        f"{_labelstr(fam.labelnames, key, ('le', '+Inf'))}"
                        f" {count}")
                    out.append(f"{name}_sum{ls} {_fmt(total)}")
                    out.append(f"{name}_count{ls} {count}")
                else:
                    out.append(f"{name}{ls} {_fmt(child.value())}")
        return "\n".join(out) + "\n"

    def sample(self) -> dict:
        """Flat ``{series: value}`` snapshot for JSONL time-series rows.
        Histograms contribute ``_count`` and ``_sum`` (bucket vectors stay
        out of the time series — the /metrics endpoint serves those)."""
        out = {}
        for name in sorted(self._families):
            fam = self._families[name]
            for key, child in fam.samples():
                ls = _labelstr(fam.labelnames, key)
                if fam.kind == "histogram":
                    _counts, total, count = child.value()
                    out[f"{name}_count{ls}"] = count
                    out[f"{name}_sum{ls}"] = total
                else:
                    out[f"{name}{ls}"] = child.value()
        return out
