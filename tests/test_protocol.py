import numpy as np
from repro.testing.hypo import given, st

from repro.core import protocol as P


class TestHeaderCodec:
    def test_magic_is_lb_port(self):
        # 'L'<<8|'B' == 0x4C42 == 19522 — the LB UDP service port.
        assert P.MAGIC == 0x4C42 == P.LB_SERVICE_PORT
        assert P.MAGIC.to_bytes(2, "big") == b"LB"

    def test_roundtrip_simple(self):
        ev = np.array([0, 1, 2**32 - 1, 2**32, 2**64 - 1], np.uint64)
        en = np.array([0, 1, 65535, 7, 42], np.uint32)
        words = P.encode_headers(ev, en)
        f = P.decode_fields(words)
        assert (np.asarray(f["entropy"]) == en).all()
        assert (P.join64(np.asarray(f["event_hi"]), np.asarray(f["event_lo"])) == ev).all()
        assert np.asarray(P.validate(words)).all()

    @given(
        ev=st.integers(min_value=0, max_value=2**64 - 1),
        en=st.integers(min_value=0, max_value=2**16 - 1),
    )
    def test_roundtrip_property(self, ev, en):
        h = P.LBHeader(event_number=ev, entropy=en)
        w = h.words()
        f = P.decode_fields(w[None])
        assert int(np.asarray(f["entropy"])[0]) == en
        assert int(P.join64(np.asarray(f["event_hi"]), np.asarray(f["event_lo"]))[0]) == ev
        assert int(np.asarray(f["magic"])[0]) == P.MAGIC

    def test_bad_magic_and_version_rejected(self):
        words = P.encode_headers(np.array([5], np.uint64), np.array([1], np.uint32))
        bad_magic = words.copy(); bad_magic[0, 0] ^= 0x00010000
        bad_ver = words.copy(); bad_ver[0, 0] ^= 0x00000100
        assert not np.asarray(P.validate(bad_magic))[0]
        assert not np.asarray(P.validate(bad_ver))[0]

    def test_slot_is_9_lsbs(self):
        lo = np.arange(2048, dtype=np.uint32)
        assert (np.asarray(P.event_slot(lo)) == lo % 512).all()

    def test_segment_payload_fits_9kb(self):
        assert P.MAX_SEGMENT_PAYLOAD + P.HEADER_BYTES + 28 <= 9000
