"""Observability stack: registry math, Prometheus text, /metrics over a
real socket, time-series JSONL, and the bench-trend gate + dashboard."""
import json
import math
import os
import subprocess
import sys
import urllib.request

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import trend  # noqa: E402
from repro.controld import ControlDaemon, ControldClient, InProcTransport  # noqa: E402
from repro.telemetry.export import (CONTENT_TYPE, TimeSeriesWriter,  # noqa: E402
                                    start_http_server)
from repro.telemetry.registry import (LATENCY_BUCKETS_S,  # noqa: E402
                                      MetricsRegistry, log_buckets)


class TestRegistry:
    def test_counter_and_labels(self):
        reg = MetricsRegistry()
        c = reg.counter("events_total", "events")
        c.inc()
        c.inc(3)
        assert c.value() == 4
        fam = reg.counter("by_kind_total", labelnames=("kind",))
        fam.labels(kind="a").inc()
        fam.labels(kind="a").inc()
        fam.labels(kind="b").inc(5)
        assert fam.labels(kind="a").value() == 2
        assert fam.labels(kind="b").value() == 5

    def test_get_or_create_idempotent_and_collisions(self):
        reg = MetricsRegistry()
        a = reg.counter("x_total")
        assert reg.counter("x_total") is a
        try:
            reg.gauge("x_total")
            assert False, "kind collision must raise"
        except ValueError:
            pass
        try:
            reg.counter("x_total", labelnames=("k",))
            assert False, "labelnames collision must raise"
        except ValueError:
            pass

    def test_labeled_family_rejects_bare_inc(self):
        reg = MetricsRegistry()
        fam = reg.counter("y_total", labelnames=("k",))
        try:
            fam.inc()
            assert False, "bare inc on a labeled family must raise"
        except ValueError:
            pass

    def test_callback_gauge_and_exception_nan(self):
        reg = MetricsRegistry()
        reg.gauge("live").set_function(lambda: 7.5)
        boom = reg.gauge("boom")
        boom.set_function(lambda: 1 / 0)
        assert reg.gauge("live").value() == 7.5
        assert math.isnan(reg.gauge("boom").value())  # scrape never crashes
        assert "boom NaN" in reg.render()

    def test_remove_labeled_child(self):
        reg = MetricsRegistry()
        g = reg.gauge("occ", labelnames=("token",))
        g.labels(token="t1").set(4)
        assert 'occ{token="t1"} 4' in reg.render()
        g.remove(token="t1")
        assert 'occ{token="t1"}' not in reg.render()


class TestHistogram:
    def test_bucket_edges_inclusive(self):
        # Prometheus le is inclusive: a sample AT an edge lands in that
        # bucket (bisect_left), not the next one up
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
        h.observe(2.0)
        counts, total, count = h._bound().value()
        assert counts == (0, 1, 0, 0)
        assert count == 1 and total == 2.0
        h.observe(5.0)  # past the last edge -> the +Inf slot
        counts, _, _ = h._bound().value()
        assert counts == (0, 1, 0, 1)

    def test_observe_many_equals_loop(self):
        vals = np.abs(np.random.default_rng(7).normal(1e-3, 2e-3, 500))
        reg = MetricsRegistry()
        one = reg.histogram("one", buckets=LATENCY_BUCKETS_S)
        many = reg.histogram("many", buckets=LATENCY_BUCKETS_S)
        for v in vals:
            one.observe(float(v))
        many.observe_many(vals)
        c1, s1, n1 = one._bound().value()
        c2, s2, n2 = many._bound().value()
        assert c1 == c2 and n1 == n2
        assert abs(s1 - s2) < 1e-9

    def test_latency_layout(self):
        assert LATENCY_BUCKETS_S[0] == 1e-6
        assert abs(LATENCY_BUCKETS_S[-1] - 10.0) < 1e-9
        assert len(LATENCY_BUCKETS_S) == 29  # 7 decades * 4 + 1
        assert log_buckets(1.0, 100.0, per_decade=1) == (1.0, 10.0, 100.0)


class TestRender:
    def test_prometheus_text_golden(self):
        reg = MetricsRegistry()
        c = reg.counter("req_total", "requests", labelnames=("kind",))
        c.labels(kind="get").inc(3)
        c.labels(kind="put").inc()
        reg.gauge("temp", "temperature").set(1.5)
        h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(2.0)
        assert reg.render() == (
            "# HELP lat_seconds latency\n"
            "# TYPE lat_seconds histogram\n"
            'lat_seconds_bucket{le="0.1"} 1\n'
            'lat_seconds_bucket{le="1"} 2\n'
            'lat_seconds_bucket{le="+Inf"} 3\n'
            "lat_seconds_sum 2.55\n"
            "lat_seconds_count 3\n"
            "# HELP req_total requests\n"
            "# TYPE req_total counter\n"
            'req_total{kind="get"} 3\n'
            'req_total{kind="put"} 1\n'
            "# HELP temp temperature\n"
            "# TYPE temp gauge\n"
            "temp 1.5\n")

    def test_label_escaping(self):
        reg = MetricsRegistry()
        g = reg.gauge("g", labelnames=("p",))
        g.labels(p='a"b\\c\nd').set(1)
        assert r'g{p="a\"b\\c\nd"} 1' in reg.render()

    def test_sample_flattens(self):
        reg = MetricsRegistry()
        reg.counter("c_total").inc(2)
        h = reg.histogram("h_seconds", buckets=(1.0,))
        h.observe(0.5)
        s = reg.sample()
        assert s == {"c_total": 2, "h_seconds_count": 1, "h_seconds_sum": 0.5}


class TestDaemonMetrics:
    def _driven_daemon(self):
        reg = MetricsRegistry()
        daemon = ControlDaemon(n_instances=1, lease_s=1e9, metrics=reg)
        client = ControldClient(InProcTransport(daemon))
        token = client.reserve(policy="pid")["token"]
        for m in range(3):
            client.register(token, member_id=m, node_id=m, lane_bits=1)
        client.tick(current_event=0)
        client.send_state_batch(token, [0, 1, 2], [0.9, 0.3, 0.3])
        return reg, daemon, client, token

    def test_counters_and_session_gauges(self):
        reg, daemon, client, token = self._driven_daemon()
        page = reg.render()
        assert 'controld_messages_total{kind="reserve"} 1' in page
        assert 'controld_messages_total{kind="register"} 3' in page
        assert 'controld_messages_total{kind="send_state_batch"} 1' in page
        assert "controld_heartbeats_total 3" in page
        assert f'controld_session_members{{token="{token}"}} 3' in page
        assert f'controld_session_mean_fill{{token="{token}"}} 0.5' in page
        assert "controld_sessions_active 1" in page
        assert 'controld_handle_seconds_count{kind="send_state_batch"} 1' \
            in page

    def test_reject_counted_and_free_drops_gauges(self):
        reg, daemon, client, token = self._driven_daemon()
        from repro.controld import messages as M
        reply = client.transport.call(
            M.SendState(token="bogus", member_id=0, fill=0.5))
        assert not reply.ok
        page = reg.render()
        assert 'controld_rejects_total{kind="send_state"} 1' in page
        client.free(token)
        page = reg.render()
        assert f'token="{token}"' not in page
        assert "controld_sessions_active 0" in page

    def test_replay_restores_gauges_without_counting(self):
        from repro.controld import Journal
        reg = MetricsRegistry()
        daemon = ControlDaemon(n_instances=1, lease_s=1e9, journal=Journal())
        client = ControldClient(InProcTransport(daemon))
        token = client.reserve(policy="pid")["token"]
        for m in range(2):
            client.register(token, member_id=m, node_id=m, lane_bits=1)
        client.send_state_batch(token, [0, 1], [0.4, 0.6])
        recovered = ControlDaemon.recover(daemon.journal, n_instances=1,
                                          lease_s=1e9, metrics=reg)
        assert recovered.state_digest() == daemon.state_digest()
        page = reg.render()
        # replayed traffic must NOT inflate counters...
        assert 'controld_messages_total{kind="reserve"} 0' in page
        assert "controld_heartbeats_total 0" in page
        # ...but recovered sessions keep their live occupancy gauges
        assert f'controld_session_members{{token="{token}"}} 2' in page


class TestMetricsEndpoint:
    def test_http_server_serves_render(self):
        reg = MetricsRegistry()
        reg.counter("hits_total").inc(7)
        server, port = start_http_server(reg, port=0)
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/metrics", timeout=5) as r:
                body = r.read().decode()
                assert r.headers["Content-Type"] == CONTENT_TYPE
            assert body == reg.render()
            req = urllib.request.Request(f"http://127.0.0.1:{port}/nope")
            try:
                urllib.request.urlopen(req, timeout=5)
                assert False, "want 404"
            except urllib.error.HTTPError as e:
                assert e.code == 404
        finally:
            server.shutdown()

    def test_run_controld_serve_exposes_daemon_metrics(self, tmp_path):
        """The acceptance path: spawn ``run_controld --serve --metrics-port
        0``, drive real socket traffic, scrape /metrics over HTTP."""
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
        proc = subprocess.Popen(
            [sys.executable, os.path.join(root, "scripts", "run_controld.py"),
             "--serve", "--port", "0", "--metrics-port", "0",
             "--journal", str(tmp_path / "journal.jsonl")],
            stdout=subprocess.PIPE, text=True, env=env)
        try:
            line1 = proc.stdout.readline()   # "controld serving on h:p ..."
            line2 = proc.stdout.readline()   # "metrics on http://h:mp/metrics"
            port = int(line1.split(" on ", 1)[1].split()[0].split(":")[1])
            url = line2.split(" on ", 1)[1].strip()

            from repro.controld import ControldClient, SocketClient
            client = ControldClient(SocketClient("127.0.0.1", port))
            token = client.reserve(policy="pid")["token"]
            for m in range(4):
                client.register(token, member_id=m, node_id=m, lane_bits=1)
            client.tick(current_event=0)
            client.send_state_batch(token, [0, 1, 2, 3], [0.5, 0.2, 0.2, 0.2])
            page = urllib.request.urlopen(url, timeout=10).read().decode()
            client.close()

            assert 'controld_messages_total{kind="send_state_batch"} 1' in page
            assert "controld_heartbeats_total 4" in page
            assert f'controld_session_members{{token="{token}"}} 4' in page
            assert "controld_socket_frames_total" in page
            assert "controld_handle_seconds_bucket" in page
            assert "controld_heartbeat_batch_size_bucket" in page
        finally:
            proc.terminate()
            proc.wait(timeout=10)


class TestTimeSeries:
    def test_writer_rows(self, tmp_path):
        reg = MetricsRegistry()
        c = reg.counter("n_total")
        path = tmp_path / "ts.jsonl"
        with TimeSeriesWriter(str(path), reg) as w:
            c.inc()
            w.write(step=0)
            c.inc(2)
            w.write(step=1, t_sim=1.5)
        rows = [json.loads(x) for x in path.read_text().splitlines()]
        assert rows[0] == {"step": 0, "metrics": {"n_total": 1}}
        assert rows[1] == {"step": 1, "t_sim": 1.5, "metrics": {"n_total": 3}}

    def test_simnet_emits_metrics(self, tmp_path):
        from repro.simnet import Simulator, get_scenario
        path = tmp_path / "sim.jsonl"
        scenario = get_scenario("baseline")
        cfg = scenario.build_config(steps=10, seed=0, metrics_every=2,
                                    metrics_path=str(path))
        report = Simulator(cfg, scenario).run()
        # metrics no longer force the host engine: the fused superblock's
        # returned arrays feed the same emission path
        assert report.engine == "fused"
        rows = [json.loads(x) for x in path.read_text().splitlines()]
        assert len(rows) == 5
        last = rows[-1]["metrics"]
        assert last["simnet_windows_total"] == 10
        assert last["simnet_packets_sent"] > 0
        assert last["simnet_e2e_latency_seconds_count"] > 0
        assert rows[0]["t_sim"] < rows[-1]["t_sim"]


class TestTrendGate:
    def _write_bench(self, d, value):
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "BENCH_demo.json"), "w") as f:
            json.dump({"bench": "demo", "unix_time": 0,
                       "metrics": {"rate": value}, "params": {}}, f)

    def _baseline(self, d, value=100.0):
        path = os.path.join(d, "baselines.json")
        with open(path, "w") as f:
            json.dump({"demo": {"rate": {"value": value,
                                         "better": "higher"}}}, f)
        return path

    def test_regression_fails_with_delta_and_machine_line(self, tmp_path,
                                                          capsys):
        cur = str(tmp_path / "cur")
        self._write_bench(cur, 50.0)
        base = self._baseline(str(tmp_path))
        rc = trend.main([cur, "--check", base])
        out = capsys.readouterr()
        assert rc == 1
        assert "-50.0% past the floor" in out.err
        assert "TREND-CHECK: FAIL n=1 metrics=demo.rate" in out.out

    def test_ok_path_machine_line(self, tmp_path, capsys):
        cur = str(tmp_path / "cur")
        self._write_bench(cur, 120.0)
        base = self._baseline(str(tmp_path))
        rc = trend.main([cur, "--check", base])
        out = capsys.readouterr()
        assert rc == 0
        assert "TREND-CHECK: OK" in out.out

    def test_missing_bench_and_zero_floor_fail(self, tmp_path):
        cur = str(tmp_path / "cur")
        self._write_bench(cur, 100.0)
        base = os.path.join(str(tmp_path), "baselines.json")
        with open(base, "w") as f:
            json.dump({"demo": {"rate": {"value": 0.0, "better": "higher"}},
                       "ghost": {"x": {"value": 1, "better": "higher"}}}, f)
        failures = trend.check_against_baseline(trend.load_dir(cur), base, 0.2)
        assert any("baseline value is 0" in x for x in failures)
        assert any("no BENCH_ghost.json" in x for x in failures)

    def test_history_append_prune_and_failure_trail(self, tmp_path, capsys):
        cur = str(tmp_path / "cur")
        hist = str(tmp_path / "hist")
        for i, v in enumerate([100.0, 90.0, 40.0]):
            self._write_bench(cur, v)
            trend.append_history(cur, hist, sha=f"sha{i:04d}aaaa",
                                 date=f"2026010{i + 1}T000000Z", keep=2)
        entries = trend.load_history(hist)
        assert len(entries) == 2  # pruned to keep=2
        assert trend.metric_series(entries, "demo", "rate") == [
            (entries[0]["stamp"], 90.0), (entries[1]["stamp"], 40.0)]
        base = self._baseline(str(tmp_path))
        rc = trend.main([cur, "--check", base, "--history", hist])
        out = capsys.readouterr()
        assert rc == 1
        assert "history(2 runs):" in out.err
        assert "90.00 @sha0001" in out.err

    def test_html_dashboard(self, tmp_path, capsys):
        cur = str(tmp_path / "cur")
        hist = str(tmp_path / "hist")
        for i, v in enumerate([110.0, 60.0]):
            self._write_bench(cur, v)
            trend.append_history(cur, hist, sha=f"deadbeef{i:04d}",
                                 date=f"2026010{i + 1}T000000Z")
        base = self._baseline(str(tmp_path))
        out_html = str(tmp_path / "dash.html")
        rc = trend.main([cur, "--history", hist, "--check", base,
                         "--html", out_html])
        assert rc == 1  # 60 < 100 floor: the gate still fails...
        doc = open(out_html).read()          # ...but the dashboard rendered
        assert doc.count("<svg") == 1        # one metric -> one chart
        assert doc.count("<circle") == 2     # one point per history run
        assert "var(--critical)" in doc      # regressed last point flagged
        assert "stroke-dasharray" in doc     # the baseline floor line
        assert "deadbeef0000: 110.00" in doc  # <title> hover tooltips
        assert "prefers-color-scheme: dark" in doc
