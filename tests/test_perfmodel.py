import pytest

from repro.analysis import perfmodel as PM
from repro.analysis.hlo import collective_stats
from repro.configs import get_config


class TestPerfModel:
    def test_param_counts_plausible(self):
        # name encodes rough scale; estimator must land in the right decade
        expect = {"arctic-480b": 480e9, "mixtral-8x22b": 141e9,
                  "granite-20b": 20e9, "yi-6b": 6e9, "stablelm-3b": 3e9,
                  "zamba2-2.7b": 2.7e9, "rwkv6-7b": 7e9}
        for arch, n in expect.items():
            total, active = get_config(arch).param_count()
            assert 0.4 * n < total < 2.6 * n, (arch, total)
            assert active <= total

    def test_moe_active_below_total(self):
        cfg = get_config("arctic-480b")
        total, active = cfg.param_count()
        assert active < 0.1 * total  # 2/128 experts + dense

    def test_train_flops_dominate_prefill(self):
        cfg = get_config("yi-6b")
        tr = PM.estimate(cfg, "train_4k", 256, 16, 16)
        pf = PM.estimate(cfg, "prefill_32k", 256, 16, 16)
        assert tr.flops > pf.flops

    def test_decode_memory_bound(self):
        cfg = get_config("yi-6b")
        d = PM.estimate(cfg, "decode_32k", 256, 16, 16)
        # bytes/flops ratio should be far above the v5e ridge (~240 flops/byte)
        assert d.flops / d.bytes_hbm < 240

    def test_swa_caps_mixer_flops(self):
        mix = get_config("mixtral-8x22b")
        full = mix.with_(swa_window=None)
        a = PM._mixer_flops_per_token(mix, 32_768)
        b = PM._mixer_flops_per_token(full, 32_768)
        assert a < b


class TestHLOParsing:
    def test_trip_count_multiplier(self):
        text = """
HloModule test, is_scheduled=true

%body.1 (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %ar = f32[128]{0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add.1
}

ENTRY %main (p0: f32[128]) -> f32[128] {
  %w = (s32[], f32[128]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"8"}}
  %ag = f32[256]{0} all-gather(%y), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
}
"""
        s = collective_stats(text)
        assert s.ops["all-reduce"] == 1
        assert s.dynamic_ops["all-reduce"] == 8.0
        # AR: 2 * 512B * 8 trips * 15/16 ; AG: 1024B * 15/16
        assert s.wire_bytes["all-reduce"] == pytest.approx(2 * 512 * 8 * 15 / 16)
        assert s.wire_bytes["all-gather"] == pytest.approx(1024 * 15 / 16)

    def test_group_size_parsing(self):
        text = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %a = f32[4]{0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
}
"""
        s = collective_stats(text)
        assert s.wire_bytes["all-reduce"] == pytest.approx(2 * 16 * 3 / 4)

    def test_done_ops_not_double_counted(self):
        text = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %s = (f32[128]{0}, f32[128]{0}) all-gather-start(%x), channel_id=1, replica_groups=[2,8]<=[16], dimensions={0}
  %d = f32[128]{0} all-gather-done(%s)
}
"""
        s = collective_stats(text)
        assert s.ops["all-gather"] == 1
        # tuple halved: (128+128)*4/2 = 512B payload
        assert s.payload_bytes["all-gather"] == pytest.approx(512)
