import numpy as np
import pytest
from repro.testing.hypo import given, st

from repro.core import calendar as C


class TestQuotas:
    def test_uniform(self):
        q = C.quotas_from_weights(np.ones(8))
        assert q.sum() == 512 and (q == 64).all()

    def test_weighted_2x(self):
        # paper fig 7c: CN-5 gets double weight
        w = np.ones(10); w[5] = 2.0
        q = C.quotas_from_weights(w)
        assert q.sum() == 512
        assert abs(q[5] / q[0] - 2.0) < 0.1

    def test_zero_weight_gets_no_slots(self):
        q = C.quotas_from_weights(np.array([1.0, 0.0, 1.0]))
        assert q[1] == 0 and q.sum() == 512

    def test_active_member_always_reachable(self):
        w = np.ones(100); w[0] = 1e-6
        q = C.quotas_from_weights(w)
        assert q[0] >= 1

    @given(st.lists(st.floats(0.01, 100.0), min_size=1, max_size=64))
    def test_proportionality(self, ws):
        w = np.asarray(ws)
        q = C.quotas_from_weights(w)
        assert q.sum() == 512
        ideal = w / w.sum() * 512
        assert (np.abs(q - ideal) <= np.maximum(1, 0.02 * 512)).all()


class TestCalendar:
    def test_all_slots_filled(self):
        cal = C.build_calendar(np.arange(7), np.ones(7))
        assert cal.shape == (512,)
        assert set(np.unique(cal)) == set(range(7))

    def test_exact_counts(self):
        w = np.array([3.0, 1.0])
        cal = C.build_calendar(np.array([10, 20]), w)
        counts = np.bincount(cal, minlength=21)
        assert counts[10] == 384 and counts[20] == 128

    def test_interleaving(self):
        # smooth WRR: a member with half the slots should never occupy a
        # long consecutive run
        cal = C.build_calendar(np.array([0, 1]), np.array([1.0, 1.0]))
        assert C.max_run_length(cal, 0) <= 2
        cal = C.build_calendar(np.arange(4), np.ones(4))
        for m in range(4):
            assert C.max_run_length(cal, m) <= 2

    def test_rejects_no_members(self):
        with pytest.raises(ValueError):
            C.quotas_from_weights(np.zeros(4))
