"""Hit-less reconfiguration invariants — the paper's central claim (fig 7c,
§III-C): epoch switches never split an event across members, never drop a
packet, and late (reordered) packets from the old epoch still route by the
old calendar."""
import numpy as np
import pytest
from repro.testing.hypo import given, settings, st

from repro.core import (EpochManager, MemberSpec, ReconfigurationError,
                        TableError, route, split64)


def _mk(n=4, max_members=64):
    em = EpochManager(max_members=max_members)
    members = {i: MemberSpec(node_id=i, lane_bits=1) for i in range(n)}
    em.initialize(members, {i: 1.0 for i in range(n)})
    return em


def _route_members(em, events):
    hi, lo = split64(np.asarray(events, np.uint64))
    ent = np.zeros(len(events), np.uint32)
    r = route(em.device_tables(), hi, lo, ent)
    return np.asarray(r.member), np.asarray(r.valid)


class TestInitialize:
    def test_wildcard_covers_everything(self):
        em = _mk()
        m, v = _route_members(em, [0, 123, 2**40, 2**64 - 1])
        assert v.all() and (m >= 0).all()

    def test_build_backwards_order(self):
        em = _mk()
        kinds = [a[0] for a in em.audit]
        assert kinds.index("member_insert") < kinds.index("calendar_insert") \
            < kinds.index("epoch_connect")

    def test_double_initialize_rejected(self):
        em = _mk()
        with pytest.raises(ReconfigurationError):
            em.initialize({0: MemberSpec(node_id=0)}, {0: 1.0})


class TestHitlessSwitch:
    def test_boundary_exact(self):
        em = _mk(4)
        before, _ = _route_members(em, range(2000))
        em.reconfigure({i: MemberSpec(node_id=i, lane_bits=1) for i in range(4, 10)},
                       {i: 1.0 for i in range(4, 10)}, boundary_event=1000)
        after, valid = _route_members(em, range(2000))
        assert valid.all()
        # pre-boundary: identical routing (old epoch pinned via LPM prefixes)
        assert (after[:1000] == before[:1000]).all()
        # post-boundary: only new members
        assert set(after[1000:]) <= set(range(4, 10))

    def test_event_atomicity_across_reorder(self):
        """Packets of one event arriving before AND after the switch (network
        reorder) must land on the same member."""
        em = _mk(4)
        ev = 900  # below the future boundary
        m1, _ = _route_members(em, [ev])
        em.reconfigure({i: MemberSpec(node_id=i) for i in range(2)},
                       {i: 1.0 for i in range(2)}, boundary_event=1000)
        m2, v2 = _route_members(em, [ev])  # late packet, same event
        assert v2.all() and m2[0] == m1[0]

    def test_reachable_epoch_immutable(self):
        em = _mk(2)
        with pytest.raises(TableError):
            em.state.insert_calendar(0, np.zeros(512, np.int32))

    def test_chained_epochs(self):
        em = _mk(3)
        em.reconfigure({i: MemberSpec(node_id=i) for i in range(3, 6)},
                       {i: 1.0 for i in range(3, 6)}, boundary_event=1000)
        em.reconfigure({i: MemberSpec(node_id=i) for i in range(6, 8)},
                       {i: 1.0 for i in range(6, 8)}, boundary_event=2000)
        m, v = _route_members(em, [500, 1500, 2500])
        assert v.all()
        assert m[0] in range(3) and m[1] in range(3, 6) and m[2] in range(6, 8)

    @given(boundary=st.integers(1, 4000), probe=st.integers(0, 5000))
    @settings(max_examples=30)
    def test_boundary_property(self, boundary, probe):
        em = _mk(4)
        before, _ = _route_members(em, [probe])
        em.reconfigure({i: MemberSpec(node_id=i) for i in range(4, 7)},
                       {i: 1.0 for i in range(4, 7)}, boundary_event=boundary)
        after, v = _route_members(em, [probe])
        assert v.all()
        if probe < boundary:
            assert after[0] == before[0]
        else:
            assert after[0] in range(4, 7)


class TestQuiesce:
    def test_quiesce_preserves_active_epoch(self):
        em = _mk(4)
        em.reconfigure({i: MemberSpec(node_id=i) for i in range(4, 8)},
                       {i: 1.0 for i in range(4, 8)}, boundary_event=1000)
        post_before, _ = _route_members(em, range(1000, 1512))
        em.quiesce(0)
        post_after, v = _route_members(em, range(1000, 1512))
        assert v.all() and (post_before == post_after).all()

    def test_quiesce_frees_members_and_rows(self):
        em = _mk(4)
        em.reconfigure({i: MemberSpec(node_id=i) for i in range(4, 8)},
                       {i: 1.0 for i in range(4, 8)}, boundary_event=1000)
        em.quiesce(0)
        assert set(em.state.members) == set(range(4, 8))
        assert 0 not in em.state.calendars

    def test_cannot_quiesce_active(self):
        em = _mk(2)
        with pytest.raises(ReconfigurationError):
            em.quiesce(0)

    def test_epoch_rows_recycle(self):
        """Many reconfigurations must not exhaust device calendar rows."""
        em = _mk(2)
        for k in range(12):
            b = 1000 * (k + 1)
            em.reconfigure({i: MemberSpec(node_id=i) for i in range(2)},
                           {i: 1.0 for i in range(2)}, boundary_event=b)
            if k >= 1:
                em.quiesce(em.records[k].epoch_id - 1)
        m, v = _route_members(em, [13_000])
        assert v.all()
