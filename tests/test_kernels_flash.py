"""Flash-attention Pallas kernel vs the pure-jnp oracle: shape/dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref


def _qkv(t, h, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.normal(size=(1, t, h, d)) * 0.3).astype(dtype)
    return mk(), mk(), mk()


class TestFlashAttention:
    @pytest.mark.parametrize("t,h,d", [(32, 2, 16), (64, 1, 32), (96, 2, 8),
                                       (130, 1, 16), (256, 1, 64)])
    def test_shape_sweep_causal(self, t, h, d):
        q, k, v = _qkv(t, h, d, jnp.float32, seed=t + d)
        got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
        want = jax.vmap(lambda qq, kk, vv: flash_attention_ref(
            qq, kk, vv, causal=True))(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_non_causal(self):
        q, k, v = _qkv(64, 2, 16, jnp.float32)
        got = flash_attention(q, k, v, causal=False, block_q=16, block_k=16,
                              interpret=True)
        want = jax.vmap(lambda qq, kk, vv: flash_attention_ref(
            qq, kk, vv, causal=False))(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                           (jnp.bfloat16, 2e-2)])
    def test_dtypes(self, dtype, tol):
        q, k, v = _qkv(64, 2, 32, dtype, seed=9)
        got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
        want = jax.vmap(lambda qq, kk, vv: flash_attention_ref(
            qq, kk, vv, causal=True))(q, k, v)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_block_invariance(self):
        q, k, v = _qkv(128, 1, 16, jnp.float32, seed=3)
        a = flash_attention(q, k, v, block_q=32, block_k=64, interpret=True)
        b = flash_attention(q, k, v, block_q=128, block_k=16, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)

    def test_matches_model_attention_path(self):
        """Same semantics as the jnp chunked attention used by the models."""
        from repro.models.layers import attention
        t, h, d = 48, 2, 16
        q, k, v = _qkv(t, h, d, jnp.float32, seed=5)
        pos = jnp.broadcast_to(jnp.arange(t)[None], (1, t))
        want = attention(q, k, v, qpos=pos, kpos=pos, causal=True,
                         q_chunk=16, k_chunk=16)
        got = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
