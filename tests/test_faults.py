"""Fault harness: schedule determinism, frozen clocks, frame fates with
idempotent resend, torn-write recovery, and the journal crash-point sweep
(kill the daemon at every write/rename step; prove recovery from what is
left on disk)."""
import os

import pytest

from repro.controld import (ControlDaemon, ControldClient, HACluster,
                            InProcTransport, Journal, NodeTransport,
                            TransportError)
from repro.controld import messages as M
from repro.testing.faults import (FaultInjector, FaultyTransport, FrozenClock,
                                  InjectedCrash, crash_sweep)

DKW = dict(n_instances=2, lease_s=1e9, epoch_horizon=64, max_members=16)


def _drive(inj):
    """A fixed call sequence over every injector facility."""
    try:
        inj.crashpoint("a")
    except InjectedCrash:
        pass
    inj.crashpoint("b")
    for _ in range(32):
        inj.frame_fate()
        inj.frame_delay()
    inj.torn_bytes("w", b"x" * 100)
    return inj.schedule()


def _injector(seed):
    return FaultInjector(seed=seed, crash_at={"a": 1}, torn_at={"w": 0.5},
                         drop_request=0.2, drop_reply=0.2, dup_request=0.2,
                         delay_s=0.01, delay_rate=0.5)


class TestDeterminism:
    def test_same_seed_same_schedule(self):
        assert _drive(_injector(7)) == _drive(_injector(7))

    def test_different_seed_different_schedule(self):
        assert _drive(_injector(0)) != _drive(_injector(1))

    def test_crashpoint_fires_on_exactly_the_scheduled_hit(self):
        inj = FaultInjector(seed=0, crash_at={"p": 3})
        inj.crashpoint("p")
        inj.crashpoint("p")
        with pytest.raises(InjectedCrash):
            inj.crashpoint("p")
        inj.crashpoint("p")  # hit 4: past the schedule, passes again
        assert [a for (_, _, a) in inj.log] == ["pass", "pass", "crash",
                                                "pass"]


class TestFrozenClock:
    def test_manual_advance_only(self):
        clk = FrozenClock(start=5.0)
        assert clk.now() == clk() == 5.0
        assert clk.advance(2.5) == 7.5
        assert clk() == 7.5

    def test_cannot_go_backwards(self):
        with pytest.raises(ValueError):
            FrozenClock().advance(-1.0)


class TestFaultyTransport:
    def test_dropped_request_never_reaches_the_daemon(self):
        d = ControlDaemon(clock=FrozenClock(), **DKW)
        t = FaultyTransport(InProcTransport(d),
                            FaultInjector(seed=0, drop_request=1.0))
        with pytest.raises(TransportError):
            t.call(M.Reserve())
        assert d.sessions == {}

    def test_dropped_reply_applied_once_and_resend_dedupes(self):
        d = ControlDaemon(clock=FrozenClock(), **DKW)
        faulty = FaultyTransport(InProcTransport(d),
                                 FaultInjector(seed=0, drop_reply=1.0))
        msg = M.Reserve(req="cli:1")
        with pytest.raises(TransportError):
            faulty.call(msg)
        # the daemon DID reserve; only the reply was lost
        assert len(d.sessions) == 1
        # the idempotent resend (same req id over a healthy path) returns
        # the cached reply instead of burning a second instance
        reply = InProcTransport(d).call(msg)
        assert reply.ok and len(d.sessions) == 1
        assert reply.data["token"] in d.sessions

    def test_duplicated_request_is_invisible_with_request_ids(self):
        d = ControlDaemon(clock=FrozenClock(), **DKW)
        t = FaultyTransport(InProcTransport(d),
                            FaultInjector(seed=0, dup_request=1.0))
        c = ControldClient(t, client_id="cli")
        r = c.reserve()
        # delivered twice (a retransmit racing the original): the req-id
        # cache makes the duplicate a no-op
        assert len(d.sessions) == 1 and r["token"] in d.sessions
        assert d._free_instances == [1]

    def test_delays_run_on_the_supplied_clock(self):
        clk = FrozenClock()
        d = ControlDaemon(clock=clk, **DKW)
        t = FaultyTransport(InProcTransport(d),
                            FaultInjector(seed=0, delay_s=0.25,
                                          delay_rate=1.0),
                            sleep=clk.advance)
        t.call(M.Status())
        assert clk() == 0.25


class TestTornWrites:
    def _grow(self, path, n, faults=None):
        j = (Journal.load(path) if os.path.exists(path)
             else Journal(path=path, retain=False))
        j.faults = faults
        for k in range(n):
            j.append("k", {"i": k, "now": 0.0})
        if faults is None:
            j.close()
        return j

    def test_torn_tail_dropped_then_journal_heals(self, tmp_path):
        path = str(tmp_path / "wal.jsonl")
        self._grow(path, 3)
        # a process killed inside write(2): only a prefix of line 4 lands
        inj = FaultInjector(seed=0, torn_at={"journal.append.write": 0.5})
        with pytest.raises(InjectedCrash):
            self._grow(path, 1, faults=inj)
        j = Journal.load(path)
        assert [e.seq for e in j.entries] == [0, 1, 2]
        # the rewrite purged the torn bytes: appends stay valid JSONL
        j.append("k", {"i": 3, "now": 0.0})
        j.close()
        j2 = Journal.load(path)
        assert [e.seq for e in j2.entries] == [0, 1, 2, 3]

    def test_crash_during_torn_tail_rewrite_keeps_the_good_prefix(
            self, tmp_path):
        path = str(tmp_path / "wal.jsonl")
        self._grow(path, 3)
        inj = FaultInjector(seed=0, torn_at={"journal.append.write": 0.5})
        with pytest.raises(InjectedCrash):
            self._grow(path, 1, faults=inj)
        # killed again DURING the load-time rewrite: the atomic
        # tmp-then-replace means the original (good prefix + torn tail)
        # is still on disk, so the next load succeeds identically
        rewrite = FaultInjector(seed=0, crash_at={"journal.load.rewrite": 1})
        with pytest.raises(InjectedCrash):
            Journal.load(path, faults=rewrite)
        j = Journal.load(path)
        assert [e.seq for e in j.entries] == [0, 1, 2]
        j.close()


class TestJournalCrashSweep:
    POINTS = ("journal.append.write", "journal.append.flush",
              "journal.snapshot.start", "journal.snapshot.entries",
              "journal.snapshot.manifest", "journal.snapshot.rename",
              "journal.compact.snapshotted", "journal.compact.truncated")

    def test_recovery_from_every_crash_point(self, tmp_path):
        state = {"n": 0}

        def run(inj):
            d = tmp_path / f"p{state['n']}"
            d.mkdir()
            state["n"] += 1
            state["path"] = str(d / "wal.jsonl")
            state["snaps"] = str(d / "snaps")
            j = Journal(path=state["path"], retain=False,
                        snapshot_dir=state["snaps"], compact_every=3)
            j.faults = inj
            daemon = ControlDaemon(clock=FrozenClock(), journal=j, **DKW)
            c = ControldClient(InProcTransport(daemon))
            token = c.reserve()["token"]
            for m in range(2):
                c.register(token, member_id=m, node_id=m, lane_bits=1)
            c.tick(current_event=0)
            for k in range(12):
                c.send_state(token, k % 2, fill=0.5)

        def check(point):
            # recover from exactly what the crash left on disk: latest
            # snapshot (if one completed) + live tail, else the tail alone
            if Journal.latest_snapshot(state["snaps"]) is not None:
                j = Journal.restore(state["snaps"], tail_path=state["path"])
            else:
                j = Journal.load(state["path"])
                j.close()
            seqs = [e.seq for e in j.entries]
            assert seqs == list(range(len(seqs))), (point, seqs)
            d = ControlDaemon.recover(j, clock=FrozenClock(), **DKW)
            assert d.state_digest()

        fired = crash_sweep(self.POINTS, run, check)
        assert fired == list(self.POINTS)


class TestReplicationCrashPoints:
    def test_lost_shipment_heals_via_backlog_stream(self):
        clk = FrozenClock()
        inj = FaultInjector(seed=0, crash_at={"replication.ship": 3})
        cluster = HACluster(n_nodes=2, clock=clk, term_s=1e9, faults=inj,
                            daemon_kwargs=DKW)
        leader = cluster.leader()
        c = ControldClient(NodeTransport(leader), client_id="t")
        token = c.reserve()["token"]
        c.register(token, member_id=0, node_id=0, lane_bits=1)
        # the third shipment crashes after the entry was journaled and
        # the outbox drained: that batch never reaches the standby
        with pytest.raises(InjectedCrash):
            c.register(token, member_id=1, node_id=1, lane_bits=1)
        (standby,) = cluster.standbys()
        assert standby.daemon.journal.seq == leader.daemon.journal.seq - 1
        # the next shipment exposes the gap; the standby's need_from ack
        # makes the leader stream the missing backlog before it
        c.tick(current_event=0)
        assert standby.daemon.journal.seq == leader.daemon.journal.seq
        assert (standby.daemon.state_digest()
                == leader.daemon.state_digest())
        assert leader.replicator.lag() == 0
