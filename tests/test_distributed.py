"""Multi-device behaviour on 8 fake CPU devices — run in a subprocess so the
main test process keeps its single-device view (the dry-run rule)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding

    from repro.core import EpochManager, MemberSpec, encode_headers
    from repro.core.router import make_redistribute, route
    from repro.core.protocol import decode_fields
    from repro.distributed import sharding as shd
    from repro.distributed.context import use_rules
    from repro.train import train_step as TS, optimizer as OPT
    from repro.configs import get_smoke_config

    out = {}
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    # --- all_to_all redistribution correctness --------------------------------
    em = EpochManager(max_members=16)
    em.initialize({i: MemberSpec(node_id=i) for i in range(4)},
                  {i: 1.0 for i in range(4)})
    tables = em.device_tables()
    rng = np.random.default_rng(0)
    B = 64
    ev = np.arange(B).astype(np.uint64)
    hdr = encode_headers(ev, np.zeros(B, np.uint32))
    f = decode_fields(jnp.asarray(hdr))
    r = route(tables, f["event_hi"], f["event_lo"], f["entropy"])
    payload = jnp.asarray(np.arange(B, dtype=np.float32)[:, None] * 10.0)
    redis = make_redistribute(mesh, ("data",), capacity_per_src=8)
    with mesh:
        recv, occ = jax.jit(redis)(payload, r.node)
    recv, occ = np.asarray(recv), np.asarray(occ)
    node = np.asarray(r.node)
    # every event landed on the shard the calendar chose
    got_by_member = {}
    shard = B // 4
    for m in range(4):
        rows = recv[m * (recv.shape[0] // 4):(m + 1) * (recv.shape[0] // 4)]
        o = occ[m * (occ.shape[0] // 4):(m + 1) * (occ.shape[0] // 4)]
        got_by_member[m] = sorted(float(v) for v in rows[o > 0, 0])
    want_by_member = {m: sorted(float(e * 10.0) for e in ev[node == m])
                      for m in range(4)}
    out["redistribute_exact"] = got_by_member == want_by_member

    # --- jitted, sharded train step with LB ingest -----------------------------
    cfg = get_smoke_config("yi_6b")
    tcfg = TS.TrainConfig(adamw=OPT.AdamWConfig(lr=1e-3), remat=False,
                          lb_ingest=True, q_chunk=8, k_chunk=8)
    rules = shd.logical_rules(mesh)
    with use_rules(rules):
        state = TS.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        batch_np = rng.integers(0, cfg.vocab, (16, 16)).astype(np.int32)
        headers = encode_headers(np.arange(16).astype(np.uint64),
                                 np.zeros(16, np.uint32))
        batch = {"tokens": jnp.asarray(batch_np),
                 "labels": jnp.asarray(batch_np),
                 "headers": jnp.asarray(headers)}
        shapes = {"params": jax.eval_shape(lambda: state["params"]),
                  "opt": jax.eval_shape(lambda: state["opt"]),
                  "batch": jax.eval_shape(lambda: batch), "tables": tables}
        step = TS.jit_train_step(cfg, tcfg, mesh, shapes, global_batch=16,
                                 donate=False)
        new_state, metrics = step(state, batch, tables)
        out["ingest_loss_finite"] = bool(np.isfinite(float(metrics["loss"])))
        out["ingest_occupancy"] = float(metrics["ingest_occupancy"])
        # ingest vs single-device no-ingest: occupancy <= 1, > 0.5
        new_state2, m2 = step(new_state, batch, tables)
        out["second_step_ok"] = bool(np.isfinite(float(m2["loss"])))

    # --- param shardings sanity -------------------------------------------------
    ps = shd.param_sharding(state["params"], mesh, cfg, min_fsdp_size=0)
    specs = jax.tree.leaves(jax.tree.map(lambda s: str(s.spec), ps))
    out["any_model_sharded"] = any("model" in s for s in specs)
    out["any_data_sharded"] = any("data" in s for s in specs)
    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


class TestMultiDevice:
    def test_redistribute_is_exact(self, results):
        assert results["redistribute_exact"]

    def test_ingest_train_step(self, results):
        assert results["ingest_loss_finite"] and results["second_step_ok"]
        assert 0.5 < results["ingest_occupancy"] <= 1.0

    def test_param_shardings(self, results):
        assert results["any_model_sharded"] and results["any_data_sharded"]
