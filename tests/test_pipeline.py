"""End-to-end pipeline: the fig-7 system test at unit scale.

5 DAQs -> segmentation -> WAN (reorder) -> LB -> 10 CNs with RSS lanes ->
reassembly. Asserts the paper's measured properties: event atomicity, zero
loss accounting, weighted fairness, epoch-switch coherence."""
import numpy as np
import pytest

from repro.core import EpochManager, MemberSpec
from repro.data.daq import DAQConfig
from repro.data.pipeline import StreamingPipeline, batches_from_bundles
from repro.data.transport import TransportConfig


def _pipeline(n_members=10, weights=None, reorder=32, loss=0.0, seed=0):
    em = EpochManager(max_members=64)
    weights = weights or {i: 1.0 for i in range(n_members)}
    em.initialize({i: MemberSpec(node_id=i, lane_bits=2) for i in weights}, weights)
    p = StreamingPipeline(
        DAQConfig(n_daqs=5, seq_len=64, mean_bundle_bytes=20_000, seed=seed),
        TransportConfig(reorder_window=reorder, loss_prob=loss, seed=seed),
        em,
    )
    return p, em


class TestEndToEnd:
    def test_event_atomicity(self):
        """fig 7b/c: all packets of an event land on ONE member, despite
        multi-DAQ sourcing and WAN reordering."""
        p, _ = _pipeline()
        p.pump(40)
        emap = p.event_member_map()
        assert emap and all(len(ms) == 1 for ms in emap.values())

    def test_zero_loss_accounting(self):
        p, _ = _pipeline(loss=0.0)
        done = p.pump(30)
        assert p.stats.n_discarded == 0
        assert p.stats.n_routed == p.stats.n_packets
        # every bundle completes: 30 triggers x 5 DAQs
        assert len(done) == 150

    def test_lane_affinity(self):
        """Same (event, entropy) => same lane; lanes spread across 2^bits."""
        p, _ = _pipeline()
        p.pump(40)
        lanes_used = {l for (_m, l) in p.stats.per_lane}
        assert len(lanes_used) > 1
        by_ev = {}
        for ev, m, l in p.routed_log:
            by_ev.setdefault(ev, set()).add((m, l))
        assert all(len(s) == 1 for s in by_ev.values())

    def test_weighted_fairness(self):
        """fig 7c final epoch: CN-5 at 2x weight receives ~2x the packets."""
        w = {i: 1.0 for i in range(10)}; w[5] = 2.0
        p, _ = _pipeline(weights=w, seed=3)
        p.pump(160)
        per = p.stats.per_member
        others = np.mean([per[i] for i in per if i != 5])
        assert per[5] / others == pytest.approx(2.0, rel=0.30)

    def test_epoch_switch_mid_stream(self):
        """fig 7c: 3 epochs live-switched; no event split, no discard."""
        p, em = _pipeline(n_members=1)
        p.pump(20)
        b1 = p.fleet.event_number + 50
        em.reconfigure({i: MemberSpec(node_id=i, lane_bits=2) for i in (4, 5, 6)},
                       {i: 1.0 for i in (4, 5, 6)}, boundary_event=b1)
        p.pump(40)
        b2 = p.fleet.event_number + 50
        em.reconfigure({i: MemberSpec(node_id=i, lane_bits=2) for i in range(10)},
                       {i: (2.0 if i == 5 else 1.0) for i in range(10)},
                       boundary_event=b2)
        p.pump(60)
        assert p.stats.n_discarded == 0
        emap = p.event_member_map()
        assert all(len(ms) == 1 for ms in emap.values())
        for ev, ms in emap.items():
            m = next(iter(ms))
            if ev < b1:
                assert m == 0
            elif ev < b2:
                assert m in (4, 5, 6)
            else:
                assert m in range(10)

    def test_bundles_decode_to_batches(self):
        p, _ = _pipeline()
        done = p.pump(40)
        batches = batches_from_bundles(done, seq_len=64, batch_size=8)
        assert batches and all(b.shape == (8, 64) for b in batches)
